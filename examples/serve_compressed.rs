//! Serving comparison: run the continuously batched inference server on the
//! dense model and on the COMPOT-compressed model, fire overlapping request
//! streams at each, and report latency/throughput — demonstrating that the
//! compressed model serves real traffic through the KV-cached incremental
//! runtime (prefill once, O(T) decode steps, sessions joining and leaving
//! the batch as they finish).
//!
//! Run after `make artifacts`:
//!   cargo run --release --example serve_compressed

use compot::compress::{CalibContext, MethodCall, StageConfig};
use compot::coordinator::pipeline::compress_with;
use compot::data::SynthLang;
use compot::model::decode::SamplerCfg;
use compot::model::Model;
use compot::runtime::artifacts::artifacts_dir;
use compot::serve::server::Client;
use compot::serve::{serve_blocking, BatchPolicy};
use compot::util::json::Json;
use compot::util::{Rng, Timer};
use std::sync::{mpsc, Arc};

const CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 6;
const MAX_NEW: usize = 16;

fn drive(model: Arc<Model>, label: &str) -> anyhow::Result<(f64, f64)> {
    let (addr_tx, addr_rx) = mpsc::channel();
    let m2 = model.clone();
    let label_owned = label.to_string();
    let server = std::thread::spawn(move || {
        let mut info = Json::obj();
        info.set("label", label_owned.as_str().into());
        serve_blocking(m2, "127.0.0.1:0", BatchPolicy::default(), info, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv()?;
    let lang = SynthLang::wiki(model.cfg.vocab);

    // Overlapping client streams — this is what exercises continuous
    // batching: sessions from different connections share decode rounds.
    let t = Timer::start();
    let mut workers = Vec::new();
    for c in 0..CLIENTS {
        let lang_prompts: Vec<Vec<u16>> = {
            let mut rng = Rng::new(3 + c as u64);
            (0..REQS_PER_CLIENT).map(|_| lang.gen(24, &mut rng)).collect()
        };
        workers.push(std::thread::spawn(move || -> anyhow::Result<(Vec<f64>, usize)> {
            let mut client = Client::connect(addr)?;
            let mut latencies = Vec::new();
            let mut tokens = 0usize;
            for p in &lang_prompts {
                let r = client.request(p, MAX_NEW)?;
                latencies.push(r.latency_ms);
                tokens += r.tokens.len();
            }
            Ok((latencies, tokens))
        }));
    }
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    for w in workers {
        let (l, n) = w.join().unwrap()?;
        latencies.extend(l);
        tokens += n;
    }
    let wall = t.secs();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let throughput = tokens as f64 / wall;

    // One sampled request shows the non-greedy path end to end.
    let mut client = Client::connect(addr)?;
    let sampled = client.request_with(
        &lang.gen(24, &mut Rng::new(77)),
        MAX_NEW,
        SamplerCfg { temperature: 0.8, top_k: 16, seed: 7 },
    )?;
    let stats = client.stats()?;
    println!(
        "{label:<22} p50 latency {p50:8.1} ms | throughput {throughput:7.1} tok/s | \
         {tokens} tokens in {wall:.1}s | {} decode steps | sampled {} tokens",
        stats.get("decode_steps").and_then(Json::as_usize).unwrap_or(0),
        sampled.tokens.len(),
    );
    client.shutdown()?;
    server.join().unwrap();
    Ok((p50, throughput))
}

fn main() -> anyhow::Result<()> {
    let path = artifacts_dir().join("llama-micro.bin");
    anyhow::ensure!(path.exists(), "run `make artifacts` first");
    let dense = Arc::new(Model::load(&path)?);

    println!("compressing at CR 0.4 (dynamic allocation)...");
    let lang = SynthLang::wiki(dense.cfg.vocab);
    let calib = lang.gen_batch(8, 96, &mut Rng::new(1));
    let ctx = CalibContext::build(&dense, &calib);
    let (compressed, report) = compress_with(
        &dense,
        &ctx,
        &MethodCall::new("compot"),
        &StageConfig::new(0.4, true),
    )?;
    println!("achieved model CR {:.3} in {:.1}s\n", report.model_cr, report.wall_secs);

    let (p50_d, tp_d) = drive(dense.clone(), "dense")?;
    let (p50_c, tp_c) = drive(Arc::new(compressed), "COMPOT CR 0.4")?;
    println!(
        "\ncompressed vs dense: {:.2}x latency, {:.2}x throughput",
        p50_c / p50_d,
        tp_c / tp_d
    );
    println!("(storage CR is the paper's target; runtime effect depends on the");
    println!(" compressed-native decode path — see README.md §Serving architecture.)");
    Ok(())
}
