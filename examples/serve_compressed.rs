//! Serving comparison: run the batched inference server on the dense model
//! and on the COMPOT-compressed model, fire a small request load at each,
//! and report latency/throughput — demonstrating that the compressed model
//! actually serves requests (the runtime deliverable).
//!
//! Run after `make artifacts`:
//!   cargo run --release --example serve_compressed

use compot::compress::{CalibContext, MethodCall, StageConfig};
use compot::coordinator::pipeline::compress_with;
use compot::data::SynthLang;
use compot::model::Model;
use compot::runtime::artifacts::artifacts_dir;
use compot::serve::server::Client;
use compot::serve::{serve_blocking, BatchPolicy};
use compot::util::json::Json;
use compot::util::{Rng, Timer};
use std::sync::{mpsc, Arc};

fn drive(model: Arc<Model>, label: &str) -> anyhow::Result<(f64, f64)> {
    let (addr_tx, addr_rx) = mpsc::channel();
    let m2 = model.clone();
    let label_owned = label.to_string();
    let server = std::thread::spawn(move || {
        let mut info = Json::obj();
        info.set("label", label_owned.as_str().into());
        serve_blocking(m2, "127.0.0.1:0", BatchPolicy::default(), info, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv()?;
    let lang = SynthLang::wiki(model.cfg.vocab);
    let mut rng = Rng::new(3);
    let prompts: Vec<Vec<u16>> = (0..12).map(|_| lang.gen(24, &mut rng)).collect();

    let t = Timer::start();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut client = Client::connect(addr)?;
    for p in &prompts {
        let r = client.request(p, 16)?;
        latencies.push(r.latency_ms);
        tokens += r.tokens.len();
    }
    let wall = t.secs();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let throughput = tokens as f64 / wall;
    println!(
        "{label:<22} p50 latency {p50:8.1} ms | throughput {throughput:7.1} tok/s | {tokens} tokens in {wall:.1}s"
    );
    client.shutdown()?;
    server.join().unwrap();
    Ok((p50, throughput))
}

fn main() -> anyhow::Result<()> {
    let path = artifacts_dir().join("llama-micro.bin");
    anyhow::ensure!(path.exists(), "run `make artifacts` first");
    let dense = Arc::new(Model::load(&path)?);

    println!("compressing at CR 0.4 (dynamic allocation)...");
    let lang = SynthLang::wiki(dense.cfg.vocab);
    let calib = lang.gen_batch(8, 96, &mut Rng::new(1));
    let ctx = CalibContext::build(&dense, &calib);
    let (compressed, report) = compress_with(
        &dense,
        &ctx,
        &MethodCall::new("compot"),
        &StageConfig::new(0.4, true),
    )?;
    println!("achieved model CR {:.3} in {:.1}s\n", report.model_cr, report.wall_secs);

    let (p50_d, tp_d) = drive(dense.clone(), "dense")?;
    let (p50_c, tp_c) = drive(Arc::new(compressed), "COMPOT CR 0.4")?;
    println!(
        "\ncompressed vs dense: {:.2}x latency, {:.2}x throughput",
        p50_c / p50_d,
        tp_c / tp_d
    );
    println!("(storage CR is the paper's target; runtime effect depends on the");
    println!(" sparse-apply path — see README.md.)");
    Ok(())
}
