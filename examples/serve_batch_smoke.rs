//! Serve smoke for cross-session batched decode — what CI runs to prove
//! the continuous-batching worker's one-GEMM-per-layer rounds end to end:
//! it builds a tiny target + rtn4 draft in-process (CPT2 round-tripped like
//! a real launch), drives the server first sequentially and then with 12
//! concurrent mixed-tier requests, and asserts every concurrent response is
//! token-identical to its sequential twin — batching must never change a
//! continuation — while `stats` shows real multi-session GEMM rounds (exit
//! code is the assertion).
//!
//! Run: cargo run --release --example serve_batch_smoke

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::serve::server::Client;
use compot::serve::{serve_blocking_tiers, BatchPolicy};
use compot::util::json::Json;
use compot::util::Rng;
use std::sync::{mpsc, Arc};
use std::time::Duration;

const DRAFT_PLAN: &str = "rtn4";
const DRAFT_K: usize = 4;
const N_REQUESTS: usize = 12;
const MAX_NEW: usize = 8;
const TIERS: [&str; 3] = ["full", "spec", "draft"];

fn main() -> anyhow::Result<()> {
    // --- one network, two fidelity points: dense target + rtn4 draft ---
    let target = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(51));
    let lang = SynthLang::wiki(target.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(52));
    let plan = CompressionPlan::parse(DRAFT_PLAN, &StageConfig::new(0.25, false))?;
    let (draft, _) = plan.run(&target, &calib)?;
    let tdir = std::env::temp_dir();
    let target_path = tdir.join("compot_batch_smoke_target.cpt2");
    let draft_path = tdir.join("compot_batch_smoke_draft.cpt2");
    target.save_compressed(&target_path, None)?;
    draft.save_compressed(&draft_path, Some(DRAFT_PLAN))?;
    let (target, _) = Model::load_compressed_mmap(&target_path)?;
    let (draft, _) = Model::load_compressed_mmap(&draft_path)?;

    // Mixed-tier request mix over mixed-length prompts: heterogeneous cache
    // positions inside every batched round.
    let prompts: Vec<Vec<u16>> = {
        let mut rng = Rng::new(53);
        (0..N_REQUESTS).map(|i| lang.gen(6 + i % 7, &mut rng)).collect()
    };

    // --- one process; max_batch 8 with a wide admission window so the 12
    // concurrent requests actually stack into multi-session rounds ---
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = {
        let target = Arc::new(target);
        let draft = Arc::new(draft);
        std::thread::spawn(move || {
            serve_blocking_tiers(
                target,
                Some(draft),
                DRAFT_K,
                "127.0.0.1:0",
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(25) },
                Json::obj(),
                |a| {
                    addr_tx.send(a).unwrap();
                },
            )
            .unwrap();
        })
    };
    let addr = addr_rx.recv()?;

    // --- reference pass: every request alone, one at a time ---
    let mut client = Client::connect(addr)?;
    let mut sequential: Vec<Vec<u16>> = Vec::with_capacity(N_REQUESTS);
    for (i, p) in prompts.iter().enumerate() {
        let r = client.request_tier(p, MAX_NEW, TIERS[i % TIERS.len()])?;
        anyhow::ensure!(r.tokens.len() == MAX_NEW, "sequential request {i} truncated");
        sequential.push(r.tokens);
    }

    // --- concurrent pass: all 12 at once, mixed tiers ---
    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let p = p.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, Vec<u16>)> {
            let mut c = Client::connect(addr)?;
            let r = c.request_tier(&p, MAX_NEW, TIERS[i % TIERS.len()])?;
            Ok((i, r.tokens))
        }));
    }
    for h in handles {
        let (i, tokens) = h.join().expect("request thread panicked")?;
        anyhow::ensure!(
            tokens == sequential[i],
            "concurrent request {i} ({} tier) diverged from sequential serve: {tokens:?} vs {:?}",
            TIERS[i % TIERS.len()],
            sequential[i]
        );
    }

    // --- the worker must have actually batched: occupancy metrics live ---
    let stats = client.stats()?;
    let gemm = stats.get("gemm_rounds").and_then(Json::as_usize).unwrap_or(0);
    let matvec = stats.get("matvec_rounds").and_then(Json::as_usize).unwrap_or(0);
    let spec = stats.get("spec_rounds").and_then(Json::as_usize).unwrap_or(0);
    let steps = stats.get("decode_steps").and_then(Json::as_usize).unwrap_or(0);
    let maxb = stats.get("max_batch_rows").and_then(Json::as_usize).unwrap_or(0);
    let avg = stats.get("avg_batch_rows").and_then(Json::as_f64).unwrap_or(0.0);
    anyhow::ensure!(
        gemm + matvec + spec == steps,
        "round classes must partition decode_steps: {gemm} + {matvec} + {spec} != {steps}"
    );
    anyhow::ensure!(
        gemm >= 1,
        "12 concurrent requests against a 25ms admission window produced no GEMM round"
    );
    anyhow::ensure!((2..=8).contains(&maxb), "max_batch_rows out of range: {maxb}");
    anyhow::ensure!(avg >= 1.0, "avg_batch_rows out of range: {avg}");
    client.shutdown()?;
    server.join().unwrap();
    std::fs::remove_file(&target_path).ok();
    std::fs::remove_file(&draft_path).ok();
    println!(
        "batch serve smoke ok: {N_REQUESTS} concurrent mixed-tier requests token-identical to \
         sequential serve ({gemm} GEMM rounds, max batch {maxb}, avg rows {avg:.2})"
    );
    Ok(())
}
