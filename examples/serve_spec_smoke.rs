//! Serve smoke for speculative decoding — what CI runs to prove
//! `compot serve --load-compressed <target> --draft <draft>` end to end
//! without needing `make artifacts`: it builds a tiny model in-process,
//! saves it dense as the target and rtn4-compressed as the draft, serves
//! both from one process, and asserts every spec-tier response is
//! token-identical to the full-tier response from the same server (exit
//! code is the assertion).
//!
//! Run: cargo run --release --example serve_spec_smoke

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::serve::server::Client;
use compot::serve::{serve_blocking_tiers, BatchPolicy};
use compot::util::json::Json;
use compot::util::Rng;
use std::sync::{mpsc, Arc};

const DRAFT_PLAN: &str = "rtn4";
const DRAFT_K: usize = 4;

fn main() -> anyhow::Result<()> {
    // --- one network, two fidelity points: dense target + rtn4 draft ---
    let target = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(41));
    let lang = SynthLang::wiki(target.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(42));
    let plan = CompressionPlan::parse(DRAFT_PLAN, &StageConfig::new(0.25, false))?;
    let (draft, _) = plan.run(&target, &calib)?;
    // Round-trip both through CPT2 the way a real `--draft` launch would.
    let tdir = std::env::temp_dir();
    let target_path = tdir.join("compot_spec_smoke_target.cpt2");
    let draft_path = tdir.join("compot_spec_smoke_draft.cpt2");
    target.save_compressed(&target_path, None)?;
    draft.save_compressed(&draft_path, Some(DRAFT_PLAN))?;
    let (target, _) = Model::load_compressed_mmap(&target_path)?;
    let (draft, _) = Model::load_compressed_mmap(&draft_path)?;

    // --- one process, three tiers ---
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = {
        let target = Arc::new(target);
        let draft = Arc::new(draft);
        std::thread::spawn(move || {
            serve_blocking_tiers(
                target,
                Some(draft),
                DRAFT_K,
                "127.0.0.1:0",
                BatchPolicy::default(),
                Json::obj(),
                |a| {
                    addr_tx.send(a).unwrap();
                },
            )
            .unwrap();
        })
    };
    let addr = addr_rx.recv()?;
    let mut client = Client::connect(addr)?;
    let info = client.info()?;
    anyhow::ensure!(
        info.get("tier_default").and_then(Json::as_str) == Some("spec"),
        "a --draft server must default to the spec tier, got {info:?}"
    );

    // --- spec tier must be token-identical to full tier, per prompt ---
    let prompts: Vec<Vec<u16>> = {
        let mut rng = Rng::new(43);
        (0..6).map(|_| lang.gen(12, &mut rng)).collect()
    };
    for p in &prompts {
        let full = client.request_tier(p, 8, "full")?;
        let spec = client.request_tier(p, 8, "spec")?;
        anyhow::ensure!(full.tier == "full" && spec.tier == "spec", "tier tags wrong");
        anyhow::ensure!(
            spec.tokens == full.tokens,
            "spec-tier continuation diverged from full tier for {p:?}: {:?} vs {:?}",
            spec.tokens,
            full.tokens
        );
        // the draft tier answers too (its own fidelity — no parity claim)
        let draft_r = client.request_tier(p, 8, "draft")?;
        anyhow::ensure!(draft_r.tokens.len() == 8, "draft tier truncated its response");
    }

    // --- acceptance metrics must be live in stats ---
    let stats = client.stats()?;
    let rounds = stats.get("spec_rounds").and_then(Json::as_usize).unwrap_or(0);
    let rate = stats.get("acceptance_rate").and_then(Json::as_f64).unwrap_or(-1.0);
    anyhow::ensure!(rounds >= prompts.len(), "expected spec rounds in stats, got {rounds}");
    anyhow::ensure!((0.0..=1.0).contains(&rate), "acceptance_rate out of range: {rate}");
    client.shutdown()?;
    server.join().unwrap();
    std::fs::remove_file(&target_path).ok();
    std::fs::remove_file(&draft_path).ok();
    println!(
        "spec serve smoke ok: {} prompts spec==full from one server (acceptance {rate:.3}, \
         {rounds} verify rounds)",
        prompts.len()
    );
    Ok(())
}
