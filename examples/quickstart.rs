//! Quickstart: compress a single projection matrix with COMPOT and compare
//! against the SVD baselines under the same calibration data.
//!
//! Run: `cargo run --release --example quickstart`

use compot::compress::compot::{Compot, CompotConfig};
use compot::compress::svd_baselines::TruncatedSvd;
use compot::compress::svd_llm::SvdLlm;
use compot::compress::whitening::CalibStats;
use compot::compress::Compressor;
use compot::linalg::{gemm, Mat};
use compot::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // A synthetic "projection weight": low-rank structure + noise, like a
    // trained transformer projection.
    let (m, n) = (96, 256);
    let w = gemm::matmul(
        &Mat::randn(&mut rng, m, 40, 1.0),
        &Mat::randn(&mut rng, 40, n, 1.0),
    )
    .scale(1.0 / (m as f32).sqrt())
    .add(&Mat::randn(&mut rng, m, n, 0.05));

    // Calibration activations with anisotropic statistics (what whitening
    // exploits).
    let mut x = Mat::randn(&mut rng, 512, m, 1.0);
    for i in 0..x.rows() {
        for j in 0..m {
            x[(i, j)] *= 1.0 + 3.0 * (j as f32 / m as f32);
        }
    }
    let stats = CalibStats::from_activations(&x);

    println!("compressing a {m}x{n} projection at CR 0.2 .. 0.4\n");
    println!("{:<10} {:>6} {:>12} {:>14}", "method", "CR", "weight err", "functional err");
    for &cr in &[0.2, 0.3, 0.4] {
        for compressor in [
            Box::new(TruncatedSvd) as Box<dyn Compressor>,
            Box::new(SvdLlm),
            Box::new(Compot { cfg: CompotConfig::default() }),
        ] {
            let layer = compressor.compress(&w, &stats, cr, &mut rng)?;
            println!(
                "{:<10} {:>6.2} {:>12.3} {:>14.3}",
                layer.method,
                layer.cr,
                layer.weight_err,
                layer.func_err.unwrap()
            );
        }
        println!();
    }
    println!("COMPOT should achieve the lowest functional (calibration) error.");
    Ok(())
}
