//! Audio transfer demo (Table 9 analogue): compress the decoder of the
//! Whisper-like encoder–decoder and report WER vs the dense model.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example audio_whisperlike

use compot::compress::compot::Compot;
use compot::compress::svd_llm::SvdLlm;
use compot::compress::Compressor;
use compot::data::audio::sample_utterance;
use compot::data::SynthLang;
use compot::eval::wer::wer;
use compot::model::encdec::EncDecModel;
use compot::model::transformer::Capture;
use compot::model::weights::TensorFile;
use compot::runtime::artifacts::artifacts_dir;
use compot::util::Rng;

fn main() -> anyhow::Result<()> {
    let path = artifacts_dir().join("encdec-micro.bin");
    anyhow::ensure!(path.exists(), "run `make artifacts` first");
    let model = EncDecModel::from_tensor_file(&TensorFile::load(&path)?)?;
    let lang = SynthLang::wiki(model.cfg.vocab);
    let mut rng = Rng::new(5);

    let utts: Vec<_> =
        (0..16).map(|_| sample_utterance(&lang, &model.codebook, 14, &mut rng)).collect();
    let eval = |m: &EncDecModel| {
        let pairs: Vec<_> = utts
            .iter()
            .map(|u| {
                (m.transcribe(&u.frames, u.transcript.len(), u16::MAX), u.transcript.clone())
            })
            .collect();
        wer(&pairs)
    };

    println!("dense WER: {:.2}%", eval(&model));

    // calibrate the decoder
    let mut cap = Capture::default();
    for u in utts.iter().take(8) {
        let enc = model.encode(&u.frames);
        let mut toks = vec![0u16];
        toks.extend_from_slice(&u.transcript);
        model.decode(&enc, &toks, Some(&mut cap));
    }

    for &cr in &[0.2, 0.3] {
        for compot in [false, true] {
            let mut m2 = model.clone();
            for layer in 0..m2.cfg.n_layers {
                for p in EncDecModel::DECODER_PROJS {
                    let w = m2.dec_proj(layer, p).to_dense();
                    let stats = &cap.stats[&(layer, p)];
                    let mut r = Rng::new(9 ^ ((layer as u64) << 4) ^ p as u64);
                    let out = if compot {
                        Compot::default().compress(&w, stats, cr, &mut r)?
                    } else {
                        SvdLlm.compress(&w, stats, cr, &mut r)?
                    };
                    *m2.dec_proj_mut(layer, p) = out.weight;
                }
            }
            println!(
                "{} @ CR {:.1}: WER {:.2}%",
                if compot { "COMPOT " } else { "SVD-LLM" },
                cr,
                eval(&m2)
            );
        }
    }
    println!("\nExpected shape (paper Table 9): COMPOT stays near the dense WER");
    println!("while SVD-LLM degrades quickly with CR.");
    Ok(())
}
