//! Serve smoke for the sharded pipeline path — what CI runs to prove
//! `compot serve --load-compressed <index> --stages LO..HI [--next ...]`
//! end to end without needing `make artifacts`: it builds a tiny model
//! in-process, compresses it, saves a **2-shard** CPT2 set, loads each
//! stage range as its own partial model (head owned, tail mmap — both
//! loader paths cross the shard boundary), wires a head → tail pipeline
//! over loopback TCP, and asserts every served continuation is
//! token-identical to single-host decode (exit code is the assertion).
//!
//! Run: cargo run --release --example serve_pipeline_smoke

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::serve::server::Client;
use compot::serve::{serve_pipeline_head, serve_pipeline_tail, BatchPolicy};
use compot::util::json::Json;
use compot::util::Rng;
use std::sync::{mpsc, Arc};

const PLAN: &str = "rtn4";

fn main() -> anyhow::Result<()> {
    // --- build + compress + shard a tiny model ---
    let model = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(41));
    let lang = SynthLang::wiki(model.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(42));
    let plan = CompressionPlan::parse(PLAN, &StageConfig::new(0.25, false))?;
    let (compressed, _) = plan.run(&model, &calib)?;
    let dir = std::env::temp_dir();
    let path = dir.join("compot_serve_pipeline_smoke.cpt2");
    compressed.save_compressed_sharded(&path, Some(PLAN), 2)?;
    let n = compressed.stages.len();
    let split = n / 2;

    // --- one partial model per pipeline stage ---
    let (head, hinfo) = Model::load_stage_range(&path, 0..split, false)?;
    let (tail, tinfo) = Model::load_stage_range(&path, split..n, true)?;
    anyhow::ensure!(hinfo.source == "owned", "head source tag wrong: {}", hinfo.source);
    anyhow::ensure!(tinfo.source.starts_with("mmap"), "tail source tag wrong: {}", tinfo.source);
    anyhow::ensure!(head.lm_head.rows() == 0, "head partial must not carry the LM head");
    anyhow::ensure!(tail.embed.rows() == 0, "tail partial must not carry the embedding");
    println!(
        "sharded load: head stages 0..{split} ({} resident B) | tail stages {split}..{n} \
         ({} resident + {} mapped B)",
        head.resident_weight_bytes(),
        tail.resident_weight_bytes(),
        tail.mapped_weight_bytes()
    );
    let prompts: Vec<Vec<u16>> = {
        let mut rng = Rng::new(43);
        (0..6).map(|_| lang.gen(12, &mut rng)).collect()
    };
    let expected: Vec<Vec<u16>> = prompts.iter().map(|p| compressed.greedy_decode(p, 8)).collect();

    // --- tail first (it must be listening before the head dials it) ---
    let (tail_tx, tail_rx) = mpsc::channel();
    let tail_thread = {
        let tail = Arc::new(tail);
        std::thread::spawn(move || {
            serve_pipeline_tail(tail, "127.0.0.1:0", |a| {
                tail_tx.send(a).unwrap();
            })
            .unwrap();
        })
    };
    let tail_addr = tail_rx.recv()?;

    // --- head: prefill + KV cache + relay to the tail ---
    let (head_tx, head_rx) = mpsc::channel();
    let head_thread = {
        let head = Arc::new(head);
        let next = tail_addr.to_string();
        std::thread::spawn(move || {
            serve_pipeline_head(
                head,
                "127.0.0.1:0",
                &next,
                BatchPolicy::default(),
                Json::obj(),
                |a| {
                    head_tx.send(a).unwrap();
                },
            )
            .unwrap();
        })
    };
    let head_addr = head_rx.recv()?;

    // --- serve through the pipeline, assert token-identical responses ---
    let mut client = Client::connect(head_addr)?;
    let info = client.info()?;
    anyhow::ensure!(
        info.get("pipeline_role").and_then(Json::as_str) == Some("head"),
        "head must report pipeline_role \"head\", got {info:?}"
    );
    for (p, want) in prompts.iter().zip(expected.iter()) {
        let got = client.request(p, 8)?.tokens;
        anyhow::ensure!(
            &got == want,
            "pipeline-served continuation diverged from single-host decode for {p:?}"
        );
    }
    client.shutdown()?;
    head_thread.join().unwrap();
    tail_thread.join().unwrap();
    std::fs::remove_file(&path).ok();
    for i in 0..2 {
        std::fs::remove_file(dir.join(format!("compot_serve_pipeline_smoke.shard{i}.cpt2"))).ok();
    }
    println!(
        "pipeline smoke ok: {} prompts served token-identically through the 2-stage pipeline",
        prompts.len()
    );
    Ok(())
}
