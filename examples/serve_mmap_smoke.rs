//! Serve smoke for the zero-copy checkpoint path — what CI runs to prove
//! `compot serve --load-compressed <ckpt> --mmap` end to end without
//! needing `make artifacts`: it builds a tiny model in-process, compresses
//! it with the Table-7 plan, saves a CPT2 checkpoint, then serves the
//! **mmap-loaded** model and asserts every served continuation is
//! token-identical to the owned-load path (exit code is the assertion).
//!
//! Run: cargo run --release --example serve_mmap_smoke

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::serve::server::Client;
use compot::serve::{serve_blocking, BatchPolicy};
use compot::util::json::Json;
use compot::util::Rng;
use std::sync::{mpsc, Arc};

const PLAN: &str = "compot@0.25+gptq4";

fn main() -> anyhow::Result<()> {
    // --- build + compress + checkpoint a tiny model ---
    let model = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(31));
    let lang = SynthLang::wiki(model.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(32));
    let plan = CompressionPlan::parse(PLAN, &StageConfig::new(0.25, false))?;
    let (compressed, _) = plan.run(&model, &calib)?;
    let path = std::env::temp_dir().join("compot_serve_mmap_smoke.cpt2");
    compressed.save_compressed(&path, Some(PLAN))?;

    // --- owned-load reference vs zero-copy load ---
    let (owned, oinfo) = Model::load_compressed(&path)?;
    let (mapped, minfo) = Model::load_compressed_mmap(&path)?;
    anyhow::ensure!(oinfo.source == "owned", "owned source tag wrong: {}", oinfo.source);
    anyhow::ensure!(minfo.source.starts_with("mmap"), "mmap source tag wrong: {}", minfo.source);
    // On a host whose filesystem cannot mmap, the loader takes its
    // documented heap fallback — parity below must still hold, but the
    // page-sharing assertions only apply to a true mapping.
    let true_mmap = minfo.source == "mmap";
    if true_mmap {
        anyhow::ensure!(
            mapped.mapped_weight_bytes() > 0
                && mapped.resident_weight_bytes() < owned.resident_weight_bytes(),
            "mmap load did not keep weight bytes in the mapping"
        );
    } else {
        eprintln!("note: mmap fallback in effect — page-sharing checks skipped");
    }
    println!(
        "loaded {PLAN} checkpoint twice: owned {} resident B | mmap {} resident + {} mapped B",
        owned.resident_weight_bytes(),
        mapped.resident_weight_bytes(),
        mapped.mapped_weight_bytes()
    );
    let prompts: Vec<Vec<u16>> = {
        let mut rng = Rng::new(33);
        (0..6).map(|_| lang.gen(12, &mut rng)).collect()
    };
    let expected: Vec<Vec<u16>> = prompts.iter().map(|p| owned.greedy_decode(p, 8)).collect();

    // --- serve the mmap-loaded model, assert token-identical responses ---
    let (addr_tx, addr_rx) = mpsc::channel();
    let served = Arc::new(mapped);
    let server = {
        let served = served.clone();
        std::thread::spawn(move || {
            serve_blocking(served, "127.0.0.1:0", BatchPolicy::default(), Json::obj(), |a| {
                addr_tx.send(a).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv()?;
    let mut client = Client::connect(addr)?;
    let info = client.info()?;
    if true_mmap {
        anyhow::ensure!(
            info.get("weights_source").and_then(Json::as_str) == Some("mmap"),
            "server must report weights_source \"mmap\", got {info:?}"
        );
        anyhow::ensure!(
            info.get("mapped_weight_bytes").and_then(Json::as_usize).unwrap_or(0) > 0,
            "server must report a non-zero mapped_weight_bytes"
        );
    }
    for (p, want) in prompts.iter().zip(expected.iter()) {
        let got = client.request(p, 8)?.tokens;
        anyhow::ensure!(
            &got == want,
            "mmap-served continuation diverged from the owned-load path for {p:?}"
        );
    }
    client.shutdown()?;
    server.join().unwrap();
    std::fs::remove_file(&path).ok();
    println!(
        "serve smoke ok: {} prompts served token-identically from the mmap-loaded checkpoint",
        prompts.len()
    );
    Ok(())
}
