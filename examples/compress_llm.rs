//! End-to-end driver (the repository's E2E validation, see README.md):
//! load the build-time-pretrained LM, calibrate on the shared synthetic
//! corpus, run the full COMPOT pipeline (dynamic allocation) next to
//! SVD-LLM and CoSpaDi at CR 0.2, and report perplexity + zero-shot
//! accuracy for each — the paper's headline comparison on a real (small)
//! workload, exercising the L3 pipeline over L2/L1-trained weights.
//!
//! Run after `make artifacts`:
//!   cargo run --release --example compress_llm [preset] [cr]

use compot::compress::MethodCall;
use compot::eval::harness::{baseline_row, run_method, EvalSetup};
use compot::model::Model;
use compot::runtime::artifacts::artifacts_dir;
use compot::util::Timer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("llama-micro");
    let cr: f64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(0.2);

    let path = artifacts_dir().join(format!("{preset}.bin"));
    anyhow::ensure!(path.exists(), "missing {path:?}: run `make artifacts` first");
    let model = Model::load(&path)?;
    println!(
        "loaded {preset}: d={} L={} heads={}/{} ff={} ({} projection params)",
        model.cfg.d_model,
        model.cfg.n_layers,
        model.cfg.n_heads,
        model.cfg.n_kv_heads,
        model.cfg.d_ff,
        model.cfg.compressible_params()
    );

    let setup = EvalSetup::standard(model.cfg.vocab, 8, 96, 24, 42);
    let base = baseline_row(&model, &setup, "original");
    println!(
        "\n{:<14} {:>6} {:>8} {:>9} {:>9} {:>9}",
        "method", "CR", "avg acc", "wiki ppl", "c4 ppl", "time"
    );
    println!(
        "{:<14} {:>6} {:>8.1} {:>9.2} {:>9.2} {:>9}",
        "original", "-", base.avg_acc, base.ppl_wiki, base.ppl_c4, "-"
    );

    for (name, method, dynamic) in [
        ("SVD-LLM", "svd-llm", false),
        ("CoSpaDi", "cospadi", false),
        ("COMPOT-static", "compot", false),
        ("COMPOT", "compot", true),
    ] {
        let t = Timer::start();
        let row = run_method(&model, &setup, &MethodCall::new(method), cr, dynamic)?;
        println!(
            "{:<14} {:>6.2} {:>8.1} {:>9.2} {:>9.2} {:>8.1}s",
            name, row.model_cr, row.avg_acc, row.ppl_wiki, row.ppl_c4, t.secs()
        );
    }

    println!("\nExpected shape (paper Tables 3/10): COMPOT >= CoSpaDi > SVD-LLM on");
    println!("accuracy, the reverse ordering on perplexity; dynamic >= static.");
    Ok(())
}
