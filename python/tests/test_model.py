"""L2 model correctness: shapes, causality, loss behaviour, the CPT1 weight
format roundtrip, and the corpus mirror's statistics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.corpus import COPY_LAG, SynthLang
from compile.weights_io import load_cpt1, save_cpt1


@pytest.fixture(scope="module")
def tiny():
    cfg = M.Config("t", 64, 32, 2, 4, 2, 64, 64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_forward_shapes_and_finite(tiny):
    cfg, params = tiny
    toks = jnp.zeros((3, 10), dtype=jnp.int32)
    logits = M.forward(params, cfg, toks)
    assert logits.shape == (3, 10, 64)
    assert bool(jnp.isfinite(logits).all())


def test_causality(tiny):
    cfg, params = tiny
    a = jnp.asarray([[1, 2, 3, 4, 5, 6]], dtype=jnp.int32)
    b = a.at[0, 5].set(60)
    la = M.forward(params, cfg, a)
    lb = M.forward(params, cfg, b)
    np.testing.assert_allclose(la[0, :5], lb[0, :5], atol=1e-5)
    assert not np.allclose(la[0, 5], lb[0, 5])


def test_loss_decreases_under_one_grad_step(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 64, (4, 24)).astype(np.int32))
    loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, toks)
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = M.lm_loss(params2, cfg, toks)
    assert float(loss2) < float(loss)


def test_rope_relative_property():
    q = jnp.ones((1, 1, 8))
    k = jnp.ones((1, 1, 8))
    def dot_at(pi, pj):
        qq = M.rope(q, 8, 100.0, pos0=pi)
        kk = M.rope(k, 8, 100.0, pos0=pj)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(3, 1) - dot_at(4, 1)) > 1e-6


def test_cpt1_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    path = tmp_path / "m.bin"
    save_cpt1(path, cfg.to_json_dict(), {k: np.asarray(v) for k, v in params.items()})
    config, tensors = load_cpt1(path)
    assert config["d_model"] == 32
    for k, v in params.items():
        want = np.asarray(v)
        if want.ndim == 1:
            want = want[None, :]
        np.testing.assert_allclose(tensors[k], want, rtol=1e-7)


def test_encdec_and_vlm_shapes():
    cfg = M.PRESETS["encdec-micro"]
    p = M.init_encdec_params(cfg, jax.random.PRNGKey(1))
    frames = jnp.zeros((2, 8, cfg.d_input))
    toks = jnp.zeros((2, 5), dtype=jnp.int32)
    logits = M.encdec_forward(p, cfg, frames, toks)
    assert logits.shape == (2, 5, cfg.vocab)

    vcfg = M.PRESETS["vlm-micro"]
    vp = M.init_vlm_params(vcfg, jax.random.PRNGKey(2))
    patches = jnp.zeros((2, 4, vcfg.d_input))
    vl = M.vlm_forward(vp, vcfg, patches, toks)
    assert vl.shape == (2, 5, vcfg.vocab)


def test_corpus_statistics_match_design():
    lang = SynthLang.wiki(256)
    rng = np.random.default_rng(0)
    seq = lang.gen(8000, rng)
    # top-successor rate far above chance
    hits = sum(1 for a, b in zip(seq, seq[1:]) if lang.successors(int(a))[0] == int(b))
    assert hits / len(seq) > 0.25
    # copy-lag structure present
    lag = sum(1 for t in range(COPY_LAG, len(seq)) if seq[t] == seq[t - COPY_LAG])
    assert lag / (len(seq) - COPY_LAG) > 0.08
    # tokens in range
    assert seq.max() < 256


def test_pallas_forward_matches_jnp_forward():
    # The AOT-exported Pallas-backed forward must agree with the training
    # forward (single sequence).
    cfg = M.Config("t", 64, 32, 2, 4, 2, 64, 64)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    toks = jnp.asarray([1, 5, 9, 2, 7], dtype=jnp.int32)
    a = M.forward(params, cfg, toks[None])[0]
    b = M.forward_pallas(params, cfg, toks)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
