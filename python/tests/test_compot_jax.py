"""L2 COMPOT graph correctness: the jitted alternating-minimization pieces
vs numpy references, and the Newton–Schulz Procrustes vs exact SVD."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.compot_jax import compot_factorize, compot_iter, newton_schulz
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@st.composite
def iter_case(draw):
    m = draw(st.integers(8, 48))
    n = draw(st.integers(8, 48))
    k = draw(st.integers(2, m))
    s = draw(st.integers(1, k))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, n, k, s, seed


@given(iter_case())
def test_compot_iter_matches_ref(case):
    m, n, k, s, seed = case
    rng = np.random.default_rng(seed)
    wt = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    d = jnp.asarray(np.linalg.qr(rng.standard_normal((m, k)))[0].astype(np.float32))
    s_got, d_next = compot_iter(wt, d, s)
    s_want, m_want = ref.compot_iter_ref(wt, d, s)
    np.testing.assert_allclose(s_got, s_want, rtol=1e-4, atol=1e-4)
    # D_next maximizes Tr(DᵀM) — when M is rank-deficient (small s) the
    # maximizer is not unique, so compare *objectives*, not factors.
    d_want = ref.procrustes_ref(m_want)
    tr_got = float(jnp.trace(d_next.T @ m_want))
    tr_want = float(jnp.trace(d_want.T @ m_want))
    assert tr_got > tr_want - 5e-2 * abs(tr_want) - 1e-4, (
        f"procrustes objective mismatch {tr_got} vs {tr_want}"
    )


@given(st.integers(0, 2**31 - 1))
def test_newton_schulz_is_orthogonal_and_optimal(seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.standard_normal((24, 10)).astype(np.float32))
    d = newton_schulz(m, 20)
    gram = np.asarray(d.T @ d)
    np.testing.assert_allclose(gram, np.eye(10), atol=5e-3)
    # trace objective: must match the SVD solution
    d_svd = ref.procrustes_ref(m)
    tr_ns = float(jnp.trace(d.T @ m))
    tr_svd = float(jnp.trace(d_svd.T @ m))
    assert tr_ns > tr_svd - 1e-2 * abs(tr_svd)


def test_factorize_reduces_error_and_stays_orthogonal():
    rng = np.random.default_rng(3)
    wt = jnp.asarray(rng.standard_normal((32, 48)).astype(np.float32))
    d0 = jnp.asarray(np.linalg.qr(rng.standard_normal((32, 16)))[0].astype(np.float32))
    errs = []
    for iters in [1, 5, 15]:
        d, s_dense = compot_factorize(wt, d0, 8, iters)
        errs.append(float(jnp.linalg.norm(wt - d @ s_dense)))
    assert errs[2] <= errs[0] + 1e-4, f"no improvement: {errs}"
    d, _ = compot_factorize(wt, d0, 8, 10)
    gram = np.asarray(d.T @ d)
    np.testing.assert_allclose(gram, np.eye(16), atol=2e-2)


def test_factorize_error_identity():
    # ‖W̃ − D·S‖² == ‖W̃‖² − ‖S‖² under orthonormal D and S = H_s(DᵀW̃)
    rng = np.random.default_rng(4)
    wt = jnp.asarray(rng.standard_normal((20, 30)).astype(np.float32))
    d = jnp.asarray(np.linalg.qr(rng.standard_normal((20, 10)))[0].astype(np.float32))
    s_dense, _ = compot_iter(wt, d, 5)
    lhs = float(jnp.linalg.norm(wt - d @ s_dense) ** 2)
    rhs = float(jnp.linalg.norm(wt) ** 2 - jnp.linalg.norm(s_dense) ** 2)
    assert abs(lhs - rhs) / max(abs(rhs), 1e-9) < 1e-3
