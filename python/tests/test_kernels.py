"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles, swept over
shapes and dtypes with hypothesis. This is the core build-time correctness
signal for the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hard_threshold import hard_threshold
from compile.kernels.matmul import matmul
from compile.kernels.sparse_apply import sparse_apply

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@st.composite
def ht_case(draw):
    k = draw(st.integers(2, 48))
    n = draw(st.integers(1, 40))
    s = draw(st.integers(1, k))
    seed = draw(st.integers(0, 2**31 - 1))
    return k, n, s, seed


@given(ht_case())
def test_hard_threshold_matches_ref(case):
    k, n, s, seed = case
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = hard_threshold(z, s)
    want = ref.hard_threshold_ref(z, s)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(ht_case())
def test_hard_threshold_keeps_exactly_s_nonzeros(case):
    k, n, s, seed = case
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    out = np.asarray(hard_threshold(z, s))
    # continuous data: no ties, exactly s nonzeros per column
    nz = (out != 0).sum(axis=0)
    assert (nz == s).all()


def test_hard_threshold_is_projection():
    # H_s(H_s(z)) == H_s(z)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    once = hard_threshold(z, 4)
    twice = hard_threshold(once, 4)
    np.testing.assert_allclose(once, twice)


@st.composite
def mm_case(draw):
    m = draw(st.integers(1, 100))
    k = draw(st.integers(1, 100))
    n = draw(st.integers(1, 100))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, seed


@given(mm_case())
def test_matmul_matches_ref(case):
    m, k, n, seed = case
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((33, 40))).astype(dtype)
    b = jnp.asarray(rng.standard_normal((40, 17))).astype(dtype)
    got = matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    want = ref.matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_block_boundaries():
    # exact multiples and off-by-one around the 128 tile
    for m, k, n in [(128, 128, 128), (129, 127, 130), (1, 256, 1), (256, 1, 256)]:
        rng = np.random.default_rng(m * 1000 + n)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        np.testing.assert_allclose(
            matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )


@st.composite
def sa_case(draw):
    b = draw(st.integers(1, 8))
    k = draw(st.integers(2, 32))
    s = draw(st.integers(1, 8))
    n = draw(st.integers(1, 24))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, k, min(s, k), n, seed


@given(sa_case())
def test_sparse_apply_matches_dense(case):
    b, k, s, n, seed = case
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.standard_normal((b, k)).astype(np.float32))
    # distinct indices per column to avoid double-count ambiguity
    idx = np.stack([rng.permutation(k)[:s] for _ in range(n)], axis=1).astype(np.int32)
    val = rng.standard_normal((s, n)).astype(np.float32)
    dense = np.zeros((k, n), np.float32)
    for si in range(s):
        for j in range(n):
            dense[idx[si, j], j] = val[si, j]
    got = sparse_apply(t, jnp.asarray(idx), jnp.asarray(val))
    want = ref.sparse_apply_ref(t, jnp.asarray(dense))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kernels_lower_without_custom_calls():
    # The AOT contract: interpret-mode Pallas lowers to plain HLO ops the
    # pinned xla_extension CPU runtime can execute — no Mosaic custom-calls.
    from compile.aot import to_hlo_text

    spec = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    lowered = jax.jit(lambda z: hard_threshold(z, 5)).lower(spec)
    hlo = to_hlo_text(lowered)
    assert "custom-call" not in hlo, "Mosaic custom-call leaked into the artifact"
