"""Synthetic language — semantic mirror of rust/src/data/corpus.rs.

The *distribution* is shared with the Rust side through deterministic
arithmetic (successor tables, copy rule, Zipf inverse-transform), not through
shared PRNG state: the build-time pretraining here and the Rust-side
evaluation both sample from the same process. See DESIGN.md §3.
"""

from __future__ import annotations

import numpy as np

COPY_LAG = 16
COPY_PROB = 0.10
SUCC_PROBS = np.array([0.40, 0.25, 0.15, 0.10])
ZIPF_ALPHA = 1.3


def zipf_harmonic(n: int, alpha: float = ZIPF_ALPHA) -> float:
    if abs(alpha - 1.0) < 1e-9:
        return float(np.log(n))
    return float((n ** (1.0 - alpha) - 1.0) / (1.0 - alpha) + 1.0)


class SynthLang:
    """vocab-sized language with fixed successor structure."""

    def __init__(self, vocab: int, noise: float):
        self.vocab = vocab
        self.noise = noise
        self.h = zipf_harmonic(vocab)

    @classmethod
    def wiki(cls, vocab: int) -> "SynthLang":
        return cls(vocab, 0.10)

    @classmethod
    def c4(cls, vocab: int) -> "SynthLang":
        return cls(vocab, 0.18)

    def successors(self, t: int) -> list[int]:
        v = self.vocab
        return [(7 * t + 1) % v, (13 * t + 5) % v, (29 * t + 11) % v, (5 * t + 3) % v]

    def zipf(self, rng: np.random.Generator) -> int:
        """Same inverse-transform as rust Rng::zipf."""
        u = rng.random() * self.h
        alpha = ZIPF_ALPHA
        base = (1.0 - alpha) * u + 1.0
        # base can underflow to <= 0 at the distribution tail; both sides
        # map that to the most frequent token (see rust util::rng::zipf).
        m = base ** (1.0 / (1.0 - alpha)) if base > 0.0 else 1.0
        return min(max(int(m), 1) - 1, self.vocab - 1)

    def next(self, history: list[int], rng: np.random.Generator) -> int:
        if len(history) >= COPY_LAG and rng.random() < COPY_PROB:
            return history[-COPY_LAG]
        if rng.random() < self.noise:
            return self.zipf(rng)
        last = history[-1] if history else 0
        succ = self.successors(last)
        r = rng.random() * SUCC_PROBS.sum()
        acc = 0.0
        for tok, p in zip(succ, SUCC_PROBS):
            acc += p
            if r <= acc:
                return tok
        return succ[-1]

    def gen(self, length: int, rng: np.random.Generator) -> np.ndarray:
        seq = [self.zipf(rng)]
        while len(seq) < length:
            seq.append(self.next(seq, rng))
        return np.array(seq, dtype=np.uint16)

    def gen_batch(self, count: int, length: int, rng: np.random.Generator) -> np.ndarray:
        return np.stack([self.gen(length, rng) for _ in range(count)])


def write_corpus_bins(out_dir, vocab: int = 256, seqs: int = 64, seq_len: int = 128) -> None:
    """Write the corpus artifacts the Rust evaluation loads."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    for split, lang_cls, seed in [
        ("train", SynthLang.wiki, 1),
        ("valid", SynthLang.wiki, 2),
        ("wiki", SynthLang.wiki, 3),
        ("c4", SynthLang.c4, 4),
    ]:
        lang = lang_cls(vocab)
        rng = np.random.default_rng(seed)
        toks = lang.gen_batch(seqs, seq_len, rng).reshape(-1)
        toks.astype("<u2").tofile(os.path.join(out_dir, f"corpus_{split}.bin"))
