"""L2: the transformer forward/backward in JAX.

Architecture mirrors `rust/src/model/transformer.rs` exactly (RMSNorm with
eps 1e-6, RoPE with (i, i+half) pairing, GQA with contiguous head layout,
SwiGLU MLP, uncompressed embeddings/lm_head) so the weights trained here at
build time (`pretrain.py`) load into the Rust runtime bit-for-bit, and a
parity artifact cross-checks the two forward passes numerically.

Training uses the plain-jnp path (autodiff-friendly); the AOT-exported
inference graphs route their GEMMs through the L1 Pallas kernels
(`use_pallas=True`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    # encoder (enc-dec / vlm models); None for decoder-only
    enc_layers: int | None = None
    d_input: int | None = None

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json_dict(self) -> dict:
        d = {
            "name": self.name,
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "n_kv_heads": self.n_kv_heads,
            "d_ff": self.d_ff,
            "max_seq": self.max_seq,
            "rope_theta": self.rope_theta,
        }
        if self.enc_layers is not None:
            d["encoder"] = {"n_layers": self.enc_layers, "d_input": self.d_input}
        return d


# Presets mirroring rust/src/model/config.rs (scaled-down paper models).
PRESETS = {
    "qwen-nano": Config("qwen-nano", 256, 64, 3, 4, 2, 192, 128),
    "llama-micro": Config("llama-micro", 256, 96, 3, 6, 2, 256, 128),
    "llama-mini": Config("llama-mini", 256, 128, 4, 8, 8, 344, 128),
    "llama-small": Config("llama-small", 256, 160, 5, 10, 5, 432, 128),
    "llama-wide": Config("llama-wide", 256, 192, 6, 12, 12, 512, 128),
    "qwen-micro": Config("qwen-micro", 256, 144, 4, 8, 4, 400, 128),
    "encdec-micro": Config("encdec-micro", 256, 96, 3, 6, 6, 256, 192, enc_layers=2, d_input=32),
    "vlm-micro": Config("vlm-micro", 256, 96, 3, 6, 3, 256, 160, enc_layers=0, d_input=32),
}


def rmsnorm(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * g


def rope(x, head_dim, theta, pos0=0):
    """x: (..., T, H*hd); rotate (i, i+half) pairs per head."""
    *lead, t, width = x.shape
    half = head_dim // 2
    pos = jnp.arange(t) + pos0  # (T,)
    i = jnp.arange(half)
    freq = theta ** (-2.0 * i / head_dim)  # (half,)
    ang = pos[:, None] * freq[None, :]  # (T, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xh = x.reshape(*lead, t, width // head_dim, head_dim)
    a = xh[..., :half]
    b = xh[..., half:]
    sin = sin[:, None, :]
    cos = cos[:, None, :]
    ra = a * cos - b * sin
    rb = a * sin + b * cos
    return jnp.concatenate([ra, rb], axis=-1).reshape(*lead, t, width)


def init_params(cfg: Config, key) -> dict:
    std = 0.6 / jnp.sqrt(cfg.d_model)
    params = {}
    keys = jax.random.split(key, 4 + cfg.n_layers * 8)
    params["embed"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 1.0
    params["lm_head"] = jax.random.normal(keys[1], (cfg.d_model, cfg.vocab)) * std
    params["final_norm"] = jnp.ones((1, cfg.d_model))
    kv = cfg.n_kv_heads * cfg.head_dim
    shapes = {
        "q_proj": (cfg.d_model, cfg.d_model),
        "k_proj": (cfg.d_model, kv),
        "v_proj": (cfg.d_model, kv),
        "o_proj": (cfg.d_model, cfg.d_model),
        "gate_proj": (cfg.d_model, cfg.d_ff),
        "up_proj": (cfg.d_model, cfg.d_ff),
        "down_proj": (cfg.d_ff, cfg.d_model),
    }
    ki = 2
    for layer in range(cfg.n_layers):
        params[f"blocks.{layer}.attn_norm"] = jnp.ones((1, cfg.d_model))
        params[f"blocks.{layer}.mlp_norm"] = jnp.ones((1, cfg.d_model))
        for nm, shp in shapes.items():
            params[f"blocks.{layer}.{nm}"] = (
                jax.random.normal(keys[ki % len(keys)], shp) * std
            )
            ki += 1
    return params


def attention(q, k, v, n_heads, n_kv, head_dim, causal=True):
    """q: (B,T,H*hd), k/v: (B,Tk,KV*hd) → (B,T,H*hd)."""
    b, t, _ = q.shape
    tk = k.shape[1]
    qh = q.reshape(b, t, n_heads, head_dim)
    kh = k.reshape(b, tk, n_kv, head_dim)
    vh = v.reshape(b, tk, n_kv, head_dim)
    rep = n_heads // n_kv
    kh = jnp.repeat(kh, rep, axis=2)
    vh = jnp.repeat(vh, rep, axis=2)
    scores = jnp.einsum("bthd,bshd->bhts", qh, kh) / jnp.sqrt(head_dim)
    if causal:
        mask = jnp.tril(jnp.ones((t, tk), dtype=bool), k=tk - t)
        scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, vh)
    return out.reshape(b, t, n_heads * head_dim)


def block_forward(p, prefix, x, cfg: Config, causal=True, use_rope=True):
    xn = rmsnorm(x, p[f"{prefix}.attn_norm"])
    q = xn @ p[f"{prefix}.q_proj"]
    k = xn @ p[f"{prefix}.k_proj"]
    v = xn @ p[f"{prefix}.v_proj"]
    if use_rope:
        q = rope(q, cfg.head_dim, cfg.rope_theta)
        k = rope(k, cfg.head_dim, cfg.rope_theta)
    att = attention(q, k, v, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, causal)
    x = x + att @ p[f"{prefix}.o_proj"]
    xn = rmsnorm(x, p[f"{prefix}.mlp_norm"])
    g = xn @ p[f"{prefix}.gate_proj"]
    u = xn @ p[f"{prefix}.up_proj"]
    h = jax.nn.silu(g) * u
    return x + h @ p[f"{prefix}.down_proj"]


def forward(params, cfg: Config, tokens):
    """tokens (B,T) int32 → logits (B,T,V)."""
    x = params["embed"][tokens]
    for layer in range(cfg.n_layers):
        x = block_forward(params, f"blocks.{layer}", x, cfg)
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"]


def lm_loss(params, cfg: Config, tokens):
    logits = forward(params, cfg, tokens)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------- encoder–decoder (Whisper-like) ----------------

def init_encdec_params(cfg: Config, key) -> dict:
    p = init_params(cfg, key)
    # rename decoder blocks + add encoder / cross tensors
    out = {}
    std = 0.6 / float(jnp.sqrt(cfg.d_model))
    keys = jax.random.split(key, 64)
    ki = 0

    def nrm(shape):
        return jnp.ones((1, shape))

    def rnd(shape):
        nonlocal ki
        ki += 1
        return jax.random.normal(keys[ki % 64], shape) * std

    out["embed"] = p["embed"]
    out["lm_head"] = p["lm_head"]
    out["final_norm"] = p["final_norm"]
    out["enc_norm"] = nrm(cfg.d_model)
    out["input_proj"] = jax.random.normal(keys[0], (cfg.d_input, cfg.d_model)) * (
        1.0 / jnp.sqrt(cfg.d_input)
    )
    out["codebook"] = jax.random.normal(keys[1], (cfg.vocab, cfg.d_input))
    kv = cfg.n_kv_heads * cfg.head_dim
    shapes = {
        "q_proj": (cfg.d_model, cfg.d_model),
        "k_proj": (cfg.d_model, kv),
        "v_proj": (cfg.d_model, kv),
        "o_proj": (cfg.d_model, cfg.d_model),
        "gate_proj": (cfg.d_model, cfg.d_ff),
        "up_proj": (cfg.d_model, cfg.d_ff),
        "down_proj": (cfg.d_ff, cfg.d_model),
    }
    for e in range(cfg.enc_layers or 0):
        out[f"enc.{e}.attn_norm"] = nrm(cfg.d_model)
        out[f"enc.{e}.mlp_norm"] = nrm(cfg.d_model)
        for nm, shp in shapes.items():
            out[f"enc.{e}.{nm}"] = rnd(shp)
    for d in range(cfg.n_layers):
        out[f"dec.{d}.attn_norm"] = nrm(cfg.d_model)
        out[f"dec.{d}.mlp_norm"] = nrm(cfg.d_model)
        out[f"dec.{d}.cross_norm"] = nrm(cfg.d_model)
        for nm, shp in shapes.items():
            out[f"dec.{d}.{nm}"] = rnd(shp)
        out[f"dec.{d}.cross_q_proj"] = rnd((cfg.d_model, cfg.d_model))
        out[f"dec.{d}.cross_k_proj"] = rnd((cfg.d_model, kv))
        out[f"dec.{d}.cross_v_proj"] = rnd((cfg.d_model, kv))
        out[f"dec.{d}.cross_o_proj"] = rnd((cfg.d_model, cfg.d_model))
    return out


def encdec_forward(params, cfg: Config, frames, tokens):
    """frames (B,Tf,d_input), tokens (B,T) → logits (B,T,V)."""
    x = frames @ params["input_proj"]
    for e in range(cfg.enc_layers or 0):
        x = block_forward(params, f"enc.{e}", x, cfg, causal=False)
    enc = rmsnorm(x, params["enc_norm"])

    y = params["embed"][tokens]
    for d in range(cfg.n_layers):
        pref = f"dec.{d}"
        # self-attention (causal)
        yn = rmsnorm(y, params[f"{pref}.attn_norm"])
        q = rope(yn @ params[f"{pref}.q_proj"], cfg.head_dim, cfg.rope_theta)
        k = rope(yn @ params[f"{pref}.k_proj"], cfg.head_dim, cfg.rope_theta)
        v = yn @ params[f"{pref}.v_proj"]
        att = attention(q, k, v, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, True)
        y = y + att @ params[f"{pref}.o_proj"]
        # cross-attention (no rope)
        yn = rmsnorm(y, params[f"{pref}.cross_norm"])
        q = yn @ params[f"{pref}.cross_q_proj"]
        k = enc @ params[f"{pref}.cross_k_proj"]
        v = enc @ params[f"{pref}.cross_v_proj"]
        att = attention(q, k, v, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False)
        y = y + att @ params[f"{pref}.cross_o_proj"]
        # mlp
        yn = rmsnorm(y, params[f"{pref}.mlp_norm"])
        h = jax.nn.silu(yn @ params[f"{pref}.gate_proj"]) * (yn @ params[f"{pref}.up_proj"])
        y = y + h @ params[f"{pref}.down_proj"]
    y = rmsnorm(y, params["final_norm"])
    return y @ params["lm_head"]


def encdec_loss(params, cfg: Config, frames, tokens):
    # teacher forcing: predict tokens[1:] from tokens[:-1]
    logits = encdec_forward(params, cfg, frames, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------- prefix VLM ----------------

def init_vlm_params(cfg: Config, key) -> dict:
    p = init_params(cfg, key)
    k1, k2 = jax.random.split(key)
    p["patch_proj"] = jax.random.normal(k1, (cfg.d_input, cfg.d_model)) * (
        1.0 / jnp.sqrt(cfg.d_input)
    )
    p["codebook"] = jax.random.normal(k2, (cfg.vocab, cfg.d_input))
    return p


def vlm_forward(params, cfg: Config, patches, tokens):
    """patches (B,P,d_input), tokens (B,T) → caption logits (B,T,V)."""
    prefix = patches @ params["patch_proj"]
    tok = params["embed"][tokens]
    x = jnp.concatenate([prefix, tok], axis=1)
    for layer in range(cfg.n_layers):
        x = block_forward(params, f"blocks.{layer}", x, cfg)
    x = rmsnorm(x, params["final_norm"])
    p = patches.shape[1]
    return x[:, p:] @ params["lm_head"]


def vlm_loss(params, cfg: Config, patches, tokens):
    # predict token t from prefix+tokens[..t-1]: logits row (t-1) ← token t;
    # and token 0 from the final patch row: include it by shifting inputs.
    logits = vlm_forward(params, cfg, patches, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------- Pallas-backed inference projection ----------------

def forward_pallas(params, cfg: Config, tokens):
    """Single-sequence inference forward whose projection GEMMs go through
    the L1 Pallas matmul kernel — this is the variant `aot.py` exports, so
    the L1 kernels lower into the shipped HLO artifacts."""
    from .kernels.matmul import matmul as pl_matmul

    x = params["embed"][tokens]  # (T, d)

    def proj(h, w):
        return pl_matmul(h, w)

    for layer in range(cfg.n_layers):
        pref = f"blocks.{layer}"
        xn = rmsnorm(x, params[f"{pref}.attn_norm"])
        q = rope(proj(xn, params[f"{pref}.q_proj"])[None], cfg.head_dim, cfg.rope_theta)[0]
        k = rope(proj(xn, params[f"{pref}.k_proj"])[None], cfg.head_dim, cfg.rope_theta)[0]
        v = proj(xn, params[f"{pref}.v_proj"])
        att = attention(
            q[None], k[None], v[None], cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, True
        )[0]
        x = x + proj(att, params[f"{pref}.o_proj"])
        xn = rmsnorm(x, params[f"{pref}.mlp_norm"])
        h = jax.nn.silu(proj(xn, params[f"{pref}.gate_proj"])) * proj(
            xn, params[f"{pref}.up_proj"]
        )
        x = x + proj(h, params[f"{pref}.down_proj"])
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"]


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_jit(params, cfg: Config, tokens):
    return forward(params, cfg, tokens)
