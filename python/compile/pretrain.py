"""Build-time pretraining of the evaluation models (JAX, CPU, runs once
under `make artifacts`; never on the request path).

Trains each scaled-down preset on the synthetic language for a few hundred
Adam steps — enough to sit far above chance on the benchmark suite, giving
the compression comparisons headroom (DESIGN.md §3) — then writes CPT1
weight files plus the corpus bins and a forward-parity artifact that the
Rust integration tests check against.

Usage: python -m compile.pretrain --out ../artifacts [--steps 200] [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .corpus import SynthLang, write_corpus_bins
from .weights_io import save_cpt1


# ----- minimal Adam (optax unavailable offline) -----

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(grads, state, params, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new = jax.tree.map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train_lm(cfg: M.Config, steps: int, batch: int, seq: int, seed: int):
    lang = SynthLang.wiki(cfg.vocab)
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    state = adam_init(params)

    @jax.jit
    def step(params, state, tokens):
        loss, grads = jax.value_and_grad(M.lm_loss)(params, cfg, tokens)
        params, state = adam_update(grads, state, params)
        return params, state, loss

    t0 = time.time()
    for i in range(steps):
        toks = jnp.asarray(lang.gen_batch(batch, seq, rng).astype(np.int32))
        params, state, loss = step(params, state, toks)
        if i % 50 == 0 or i == steps - 1:
            print(f"  [{cfg.name}] step {i:4d} loss {float(loss):.3f} ({time.time()-t0:.0f}s)")
    return params


def train_encdec(cfg: M.Config, steps: int, batch: int, seq: int, seed: int):
    from .audio_data import emit_frames_np

    lang = SynthLang.wiki(cfg.vocab)
    rng = np.random.default_rng(seed)
    params = M.init_encdec_params(cfg, jax.random.PRNGKey(seed))
    codebook = np.asarray(params["codebook"])
    state = adam_init(params)

    @jax.jit
    def step(params, state, frames, tokens):
        loss, grads = jax.value_and_grad(M.encdec_loss)(params, cfg, frames, tokens)
        params, state = adam_update(grads, state, params)
        return params, state, loss

    t0 = time.time()
    for i in range(steps):
        toks = lang.gen_batch(batch, seq, rng)
        frames = np.stack([emit_frames_np(codebook, t, rng) for t in toks])
        # BOS-prefix the transcripts (token 0), matching Rust transcribe().
        bos = np.zeros((batch, 1), dtype=toks.dtype)
        toks_in = np.concatenate([bos, toks], axis=1)
        params, state, loss = step(
            params, state, jnp.asarray(frames), jnp.asarray(toks_in.astype(np.int32))
        )
        if i % 50 == 0 or i == steps - 1:
            print(f"  [{cfg.name}] step {i:4d} loss {float(loss):.3f} ({time.time()-t0:.0f}s)")
    return params


def train_vlm(cfg: M.Config, steps: int, batch: int, seed: int):
    from .audio_data import N_PATCHES, PATCH_NOISE

    lang = SynthLang.wiki(cfg.vocab)
    rng = np.random.default_rng(seed)
    params = M.init_vlm_params(cfg, jax.random.PRNGKey(seed))
    codebook = np.asarray(params["codebook"])
    state = adam_init(params)

    @jax.jit
    def step(params, state, patches, tokens):
        loss, grads = jax.value_and_grad(M.vlm_loss)(params, cfg, patches, tokens)
        params, state = adam_update(grads, state, params)
        return params, state, loss

    t0 = time.time()
    filler = 12
    for i in range(steps):
        concepts = np.stack(
            [rng.permutation(cfg.vocab)[:N_PATCHES].astype(np.uint16) for _ in range(batch)]
        )
        patches = codebook[concepts.astype(int)] + PATCH_NOISE * rng.standard_normal(
            (batch, N_PATCHES, codebook.shape[1])
        ).astype(np.float32)
        caps = []
        for b in range(batch):
            cont = lang.gen(filler, rng)
            caps.append(np.concatenate([concepts[b], cont]))
        caps = np.stack(caps)
        params, state, loss = step(
            params, state, jnp.asarray(patches, dtype=jnp.float32), jnp.asarray(caps.astype(np.int32))
        )
        if i % 50 == 0 or i == steps - 1:
            print(f"  [{cfg.name}] step {i:4d} loss {float(loss):.3f} ({time.time()-t0:.0f}s)")
    return params


def save_params(path, cfg: M.Config, params) -> None:
    tensors = {k: np.asarray(v) for k, v in params.items()}
    save_cpt1(path, cfg.to_json_dict(), tensors)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=220)
    ap.add_argument("--fast", action="store_true", help="tiny budget (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    steps = 30 if args.fast else args.steps
    print("writing corpus bins ...")
    write_corpus_bins(args.out)

    lm_models = [
        ("qwen-nano", steps, 12, 48),
        ("llama-micro", steps, 12, 48),
        ("llama-mini", steps, 8, 48),
        ("llama-small", max(steps // 2, 30), 8, 48),
        ("qwen-micro", max(steps // 2, 30), 8, 48),
        ("llama-wide", max(steps // 3, 20), 6, 48),
    ]
    trained = {}
    for name, st, batch, seq in lm_models:
        path = os.path.join(args.out, f"{name}.bin")
        if os.path.exists(path):
            print(f"{name}: cached")
            continue
        print(f"training {name} ({st} steps)")
        cfg = M.PRESETS[name]
        params = train_lm(cfg, st, batch, seq, seed=hash(name) % 2**31)
        save_params(path, cfg, params)
        trained[name] = params

    # enc-dec (audio) and VLM
    for name, trainer in [("encdec-micro", "encdec"), ("vlm-micro", "vlm")]:
        path = os.path.join(args.out, f"{name}.bin")
        if os.path.exists(path):
            print(f"{name}: cached")
            continue
        cfg = M.PRESETS[name]
        st = max(steps // 2, 30)
        print(f"training {name} ({st} steps)")
        if trainer == "encdec":
            params = train_encdec(cfg, st, 6, 24, seed=77)
        else:
            params = train_vlm(cfg, st, 12, seed=78)
        save_params(path, cfg, params)

    # Forward-parity artifact: tokens + JAX logits for llama-micro; the Rust
    # integration test loads the weights and asserts allclose.
    parity_path = os.path.join(args.out, "parity.json")
    if not os.path.exists(parity_path):
        from .weights_io import load_cpt1

        cfg = M.PRESETS["llama-micro"]
        _, tensors = load_cpt1(os.path.join(args.out, "llama-micro.bin"))
        params = {k: jnp.asarray(v if v.shape[0] > 1 or k not in ("final_norm",) else v)
                  for k, v in tensors.items()}
        # norms are stored 1×n — model code broadcasts fine.
        lang = SynthLang.wiki(cfg.vocab)
        rng = np.random.default_rng(123)
        toks = lang.gen(32, rng)
        logits = M.forward(params, cfg, jnp.asarray(toks.astype(np.int32))[None])[0]
        with open(parity_path, "w") as f:
            json.dump(
                {
                    "model": "llama-micro",
                    "tokens": [int(t) for t in toks],
                    "logits_last": [float(x) for x in np.asarray(logits[-1])],
                },
                f,
            )
    print("pretraining complete")


if __name__ == "__main__":
    main()
