"""AOT export: lower the L2/L1 graphs to HLO **text** and write the
artifact manifest.

HLO text (not `.serialize()`): jax ≥ 0.5 emits protos with 64-bit
instruction ids which the pinned xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Exported artifacts:
- `compot_iter_{m}x{n}_k{k}_s{s}.hlo.txt` — one COMPOT alternating
  iteration (Pallas GEMM + Pallas hard-threshold + Newton–Schulz
  Procrustes) for every projection shape of the shipped model presets at
  the default CR grid. Inputs: W̃ (m×n), D (m×k); outputs: (S_dense k×n,
  D_next m×k). Driven by rust `runtime::compot_exec`.
- `matmul_demo.hlo.txt` — the Pallas tiled GEMM alone (smoke/bench).
- `manifest.json` — name → file, input/output shapes.

Usage: python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .compot_jax import compot_iter
from .kernels.matmul import matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def ks_for_cr(m: int, n: int, cr: float, ratio: float = 2.0):
    """Mirror of rust compress::ks_for_cr (Eq. 11 solved for s, k = ratio·s)."""
    budget = (1.0 - cr) * 16 * m * n
    per_s = 16 * m * ratio + 16 * n + ratio * n
    s = max(int(budget / per_s), 1)
    k = max(int(round(s * ratio)), s)
    if k > m:
        k = m
        fixed = 16 * m * k + k * n
        s = max(min(int((budget - fixed) / (16 * n)), k), 1)
    return k, min(s, k)


def export_compot_iters(out_dir: str, preset: str, crs) -> list[dict]:
    cfg = M.PRESETS[preset]
    kv = cfg.n_kv_heads * cfg.head_dim
    shapes = sorted(
        {
            (cfg.d_model, cfg.d_model),
            (cfg.d_model, kv),
            (cfg.d_model, cfg.d_ff),
            (cfg.d_ff, cfg.d_model),
        }
    )
    entries = []
    for m, n in shapes:
        for cr in crs:
            k, s = ks_for_cr(m, n, cr)
            name = f"compot_iter_{m}x{n}_k{k}_s{s}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            if not os.path.exists(path):
                wt_spec = jax.ShapeDtypeStruct((m, n), jnp.float32)
                d_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
                lowered = jax.jit(lambda wt, d: compot_iter(wt, d, s)).lower(wt_spec, d_spec)
                with open(path, "w") as f:
                    f.write(to_hlo_text(lowered))
            entries.append(
                {
                    "name": name,
                    "path": os.path.basename(path),
                    "kind": "compot_iter",
                    "m": m,
                    "n": n,
                    "k": k,
                    "s": s,
                    "inputs": [[m, n], [m, k]],
                    "outputs": [[k, n], [m, k]],
                }
            )
    return entries


def export_matmul_demo(out_dir: str) -> dict:
    path = os.path.join(out_dir, "matmul_demo.hlo.txt")
    m, k, n = 96, 96, 256
    if not os.path.exists(path):
        a = jax.ShapeDtypeStruct((m, k), jnp.float32)
        b = jax.ShapeDtypeStruct((k, n), jnp.float32)
        lowered = jax.jit(matmul).lower(a, b)
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
    return {
        "name": "matmul_demo",
        "path": "matmul_demo.hlo.txt",
        "kind": "matmul",
        "inputs": [[m, k], [k, n]],
        "outputs": [[m, n]],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="llama-micro")
    ap.add_argument("--crs", default="0.2,0.3,0.4")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    crs = [float(x) for x in args.crs.split(",")]
    entries = export_compot_iters(args.out, args.preset, crs)
    entries.append(export_matmul_demo(args.out))

    manifest = {
        "preset": args.preset,
        "artifacts": entries,
        "models": [
            f for f in sorted(os.listdir(args.out)) if f.endswith(".bin") and "corpus" not in f
        ],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"exported {len(entries)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
