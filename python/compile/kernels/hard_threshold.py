"""L1 Pallas kernel: column-wise hard thresholding H_s (Eq. 9).

The sparse-coding step of COMPOT is a per-column top-s selection over
Z = D_Oᵀ·W̃ (k×n). On TPU this is vector-unit work: we tile the *columns*
across the grid so each program instance holds a (k × BLOCK_N) panel in
VMEM, computes the per-column s-th magnitude with a sort along the
(sublane) k axis, and masks. `interpret=True` everywhere — the CPU PJRT
plugin cannot execute Mosaic lowerings (see DESIGN.md §7 for the estimated
VMEM footprint: k·BLOCK_N·4 B ≤ 96·128·4 B ≈ 48 KiB per panel, far under
the ~16 MiB VMEM budget).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _kernel(z_ref, out_ref, *, s: int):
    z = z_ref[...]  # (k, bn)
    mags = jnp.abs(z)
    # s-th largest magnitude per column: sort ascending, index k-s.
    kth = jnp.sort(mags, axis=0)[z.shape[0] - s, :][None, :]
    out_ref[...] = jnp.where(mags >= kth, z, 0.0)


@functools.partial(jax.jit, static_argnames=("s",))
def hard_threshold(z: jnp.ndarray, s: int) -> jnp.ndarray:
    """H_s(z) column-wise, Pallas (interpret) implementation."""
    k, n = z.shape
    bn = min(BLOCK_N, n)
    # Pad columns to a multiple of the block.
    n_pad = (-n) % bn
    zp = jnp.pad(z, ((0, 0), (0, n_pad)))
    grid = (zp.shape[1] // bn,)
    out = pl.pallas_call(
        functools.partial(_kernel, s=s),
        out_shape=jax.ShapeDtypeStruct(zp.shape, z.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((k, bn), lambda j: (0, j)),
        interpret=True,
    )(zp)
    return out[:, :n]
