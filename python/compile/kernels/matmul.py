"""L1 Pallas kernel: MXU-tiled matmul — the GEMM hot spot of the COMPOT
inner loop (Z = DᵀW̃ and M = W̃Sᵀ) and of the compressed-layer apply.

The paper's reference implementation leans on cuBLAS; the TPU adaptation
expresses the HBM↔VMEM schedule explicitly with BlockSpecs: (BM×BK) and
(BK×BN) panels stream into VMEM, a (BM×BN) f32 accumulator persists across
the k-grid dimension, and `jnp.dot(..., preferred_element_type=f32)`
targets the MXU systolic array (bf16-friendly). Footprint per program:
(BM·BK + BK·BN + BM·BN)·4 B = 3·128²·4 B ≈ 192 KiB ≪ 16 MiB VMEM; see
DESIGN.md §7. interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BK, BN = 128, 128, 128


def _kernel(a_ref, b_ref, out_ref):
    # The out block's index map ignores the k grid axis, so the same (BM×BN)
    # f32 tile persists in VMEM across the contraction steps — accumulate
    # into it directly (init on the first step).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@jax.jit
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a (m,k) @ b (k,n) with explicit tiling; pads to tile multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bk, bn = min(BM, m), min(BK, k), min(BN, n)
    mp, kp, np_ = (-m) % bm, (-k) % bk, (-n) % bn
    ap = jnp.pad(a, ((0, mp), (0, kp)))
    bp = jnp.pad(b, ((0, kp), (0, np_)))
    k_steps = ap.shape[1] // bk
    grid = (ap.shape[0] // bm, bp.shape[1] // bn, k_steps)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
