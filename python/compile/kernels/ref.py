"""Pure-jnp oracles for the Pallas kernels — the correctness contract.

Every kernel in this package has a reference here; `python/tests` sweeps
shapes/dtypes with hypothesis and asserts allclose agreement.
"""

import jax.numpy as jnp


def hard_threshold_ref(z: jnp.ndarray, s: int) -> jnp.ndarray:
    """Keep the s largest-|z| entries per column (threshold rule: ties at the
    s-th magnitude are all kept — measure-zero for continuous data)."""
    mags = jnp.abs(z)
    kth = jnp.sort(mags, axis=0)[z.shape[0] - s, :][None, :]
    return jnp.where(mags >= kth, z, 0.0)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def sparse_apply_ref(t: jnp.ndarray, s_dense: jnp.ndarray) -> jnp.ndarray:
    """Factorized-layer tail: (x·A)·S with S given densely."""
    return jnp.dot(t, s_dense, preferred_element_type=jnp.float32)


def compot_iter_ref(wt: jnp.ndarray, d: jnp.ndarray, s: int):
    """One COMPOT alternating iteration (Eq. 9 + Eq. 10 inputs):
    returns (S_dense, M = W̃·Sᵀ)."""
    z = d.T @ wt
    s_mat = hard_threshold_ref(z, s)
    m = wt @ s_mat.T
    return s_mat, m


def procrustes_ref(m: jnp.ndarray) -> jnp.ndarray:
    """Polar/Procrustes factor via full SVD (host reference)."""
    u, _, vt = jnp.linalg.svd(m, full_matrices=False)
    return u @ vt
