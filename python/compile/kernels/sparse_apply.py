"""L1 Pallas kernel: factorized-layer apply tail — out = T·S with S the
column-s-sparse code matrix given as (indices, values).

On TPU the s-sparse structure maps to a gather over the k axis of the
(B × k) activation panel held in VMEM, followed by a weighted reduction —
no HBM round-trip for the dense k×n S. Here the per-program footprint is
(B·k + s·BLOCK_N·2 + B·BLOCK_N)·4 B, comfortably inside VMEM for the
shipped model shapes (DESIGN.md §7). interpret=True for CPU-PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _kernel(t_ref, idx_ref, val_ref, out_ref):
    t = t_ref[...]  # (B, k)
    idx = idx_ref[...]  # (s, bn)
    val = val_ref[...]  # (s, bn)
    # gathered: (B, s, bn) = t[:, idx]
    gathered = jnp.take(t, idx, axis=1)
    out_ref[...] = jnp.einsum("bsn,sn->bn", gathered, val)


@jax.jit
def sparse_apply(t: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """t (B,k) · S where column j of S has nonzeros (idx[:, j], val[:, j])."""
    b, k = t.shape
    s, n = idx.shape
    assert val.shape == (s, n)
    bn = min(BLOCK_N, n)
    n_pad = (-n) % bn
    idx_p = jnp.pad(idx, ((0, 0), (0, n_pad)))
    val_p = jnp.pad(val, ((0, 0), (0, n_pad)))
    grid = (idx_p.shape[1] // bn,)
    out = pl.pallas_call(
        functools.partial(_kernel),
        out_shape=jax.ShapeDtypeStruct((b, idx_p.shape[1]), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((s, bn), lambda j: (0, j)),
            pl.BlockSpec((s, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j: (0, j)),
        interpret=True,
    )(t, idx_p, val_p)
    return out[:, :n]
