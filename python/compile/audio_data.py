"""Synthetic audio/vision emission — mirror of rust/src/data/{audio,vlm}.rs
(shared constants and emission rules; the codebook itself is stored in the
model weight file so both sides use identical embeddings)."""

import numpy as np

FRAMES_PER_TOKEN = 2
NOISE_STD = 0.3
N_PATCHES = 4
PATCH_NOISE = 0.25


def emit_frames_np(codebook: np.ndarray, transcript: np.ndarray, rng) -> np.ndarray:
    d = codebook.shape[1]
    t_len = len(transcript)
    frames = np.zeros((t_len * FRAMES_PER_TOKEN, d), dtype=np.float32)
    for t, tok in enumerate(transcript):
        cur = codebook[int(tok)]
        nxt = codebook[int(transcript[min(t + 1, t_len - 1)])]
        frames[2 * t] = cur + NOISE_STD * rng.standard_normal(d)
        frames[2 * t + 1] = 0.5 * (cur + nxt) + NOISE_STD * rng.standard_normal(d)
    return frames
