"""L2: the COMPOT alternating-minimization graph in JAX.

One iteration = the L1 kernels composed:

    Zᵀ = matmul(W̃ᵀ, D)            (Pallas tiled GEMM → MXU)
    S  = hard_threshold(Zᵀᵀ, s)     (Pallas column top-s → VPU)
    M  = matmul(W̃, Sᵀ)             (Pallas tiled GEMM)
    D  = newton_schulz(M)           (pure matmuls — see below)

**Hardware adaptation of the Procrustes step** (DESIGN.md §7): the paper
computes `D = P·Qᵀ` by a thin SVD on the GPU host path. SVD lowers to a
LAPACK custom-call that neither a TPU core nor the pinned xla_extension
0.5.1 CPU runtime can execute inside the graph — so the AOT artifact uses
the *Newton–Schulz polar iteration* instead: the orthogonal Procrustes
solution is exactly the orthogonal polar factor of M, and Newton–Schulz
converges to it using only matmuls (MXU-native, systolic-friendly):

    X₀ = M / ‖M‖_F,   X_{t+1} = 1.5·X_t − 0.5·X_t·X_tᵀ·X_t

The Rust engine keeps the exact Jacobi-SVD Procrustes; the two are
cross-checked in python/tests and in the Rust integration test.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.hard_threshold import hard_threshold
from .kernels.matmul import matmul

NS_ITERS = 16


def newton_schulz(m: jnp.ndarray, iters: int = NS_ITERS) -> jnp.ndarray:
    """Orthogonal polar factor of m (tall m×k, full rank) by Newton–Schulz."""
    norm = jnp.sqrt(jnp.sum(m * m)) + 1e-12
    x = m / norm

    def body(_, x):
        xtx = x.T @ x
        return 1.5 * x - 0.5 * x @ xtx

    return jax.lax.fori_loop(0, iters, body, x)


@functools.partial(jax.jit, static_argnames=("s",))
def compot_iter(wt: jnp.ndarray, d: jnp.ndarray, s: int):
    """One full COMPOT iteration: returns (S_dense, D_next).

    This is the function AOT-exported per projection shape
    (`compot_iter_{m}x{n}x{k}_s{s}.hlo.txt`) and driven from the Rust
    runtime's `compot_exec`.
    """
    zt = matmul(wt.T, d)  # (n, k)
    s_dense = hard_threshold(zt.T, s)  # (k, n)
    m = matmul(wt, s_dense.T)  # (m, k)
    d_next = newton_schulz(m)
    return s_dense, d_next


@functools.partial(jax.jit, static_argnames=("s", "iters"))
def compot_factorize(wt: jnp.ndarray, d0: jnp.ndarray, s: int, iters: int = 20):
    """Full alternating minimization with the iteration count baked in."""

    def body(_, d):
        _, d_next = compot_iter(wt, d, s)
        return d_next

    d = jax.lax.fori_loop(0, iters - 1, body, d0)
    s_dense, _ = compot_iter(wt, d, s)
    return d, s_dense


def factorize_error(wt: jnp.ndarray, d: jnp.ndarray, s_dense: jnp.ndarray) -> jnp.ndarray:
    """‖W̃ − D·S‖_F (diagnostics)."""
    return jnp.linalg.norm(wt - d @ s_dense)
