"""CPT1 weight-file writer/reader — mirror of rust/src/model/weights.rs.

Layout: b"CPT1" | u32 header_len | header JSON | f32-LE data.
Vector tensors are stored 1×n. Header tensor offsets are in f32 elements.
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"CPT1"


def save_cpt1(path, config_json: dict, tensors: dict[str, np.ndarray]) -> None:
    names = sorted(tensors)  # BTreeMap order on the Rust side
    entries = []
    offset = 0
    mats = []
    for name in names:
        a = np.asarray(tensors[name], dtype=np.float32)
        if a.ndim == 1:
            a = a[None, :]
        assert a.ndim == 2, f"{name} must be 2-D"
        entries.append(
            {"name": name, "rows": int(a.shape[0]), "cols": int(a.shape[1]), "offset": offset}
        )
        offset += a.size
        mats.append(a)
    header = json.dumps({"config": config_json, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for a in mats:
            f.write(a.astype("<f4").tobytes())


def load_cpt1(path):
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        data = np.frombuffer(f.read(), dtype="<f4")
    tensors = {}
    for t in header["tensors"]:
        o, r, c = t["offset"], t["rows"], t["cols"]
        tensors[t["name"]] = data[o : o + r * c].reshape(r, c).copy()
    return header["config"], tensors
