fn main() -> anyhow::Result<()> {
    let model = compot::model::Model::load(std::path::Path::new("/tmp/parity_tiny.bin"))?;
    let j = compot::util::json::Json::parse(&std::fs::read_to_string("/tmp/parity_tiny.json")?).unwrap();
    let tokens: Vec<u16> = j.get("tokens").unwrap().as_arr().unwrap().iter().map(|x| x.as_usize().unwrap() as u16).collect();
    let expect: Vec<f32> = j.get("logits_last").unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32).collect();
    let logits = model.forward(&tokens);
    let last = logits.row(logits.rows()-1);
    let mut max_err = 0f32;
    for (a, b) in last.iter().zip(expect.iter()) { max_err = max_err.max((a-b).abs()); }
    println!("max_err = {max_err}");
    assert!(max_err < 2e-3, "parity failed");
    println!("PARITY OK");
    Ok(())
}
