#!/usr/bin/env python3
"""Bench-regression gate: diff freshly generated BENCH_*.json against the
committed baselines and fail on meaningful regressions.

Usage: bench_gate.py <baseline_dir> <fresh_dir> [--only BENCH_x.json]

``--only`` restricts the gate to a single bench file (used by CI jobs that
run one bench, e.g. the aarch64 kernel-parity job gating BENCH_quant.json);
a missing fresh file for the other benches is then not an error.

Rules (applied per matching JSON key, only when the baseline value is a
positive number — "pending" placeholder baselines with zeros gate nothing):

- throughput keys (``prefill_tok_s`` or any key starting with
  ``decode_tok_s`` — including the cross-session batched-decode keys
  ``decode_tok_s_batch{1,8,32}``): fresh must be >= (1 - TOLERANCE) *
  baseline;
- size keys (any key containing ``resident_bytes`` or equal to
  ``checkpoint_file_bytes``): fresh must not exceed the baseline — packed
  bytes growing is a regression regardless of speed;
- speedup-floor keys (any key ending in ``_speedup``): fresh must be >=
  the baseline. These are machine-independent invariants (cached decode
  beats uncached, cold load beats recompress, mmap load beats the copying
  load, the batch-32 batched decode round holds its floor against 32
  per-row steps measured on the same run — ``batch_gemm_speedup``), so a
  committed floor gates on every machine;
- ratio-ceiling keys (any key containing ``_ratio``): fresh must be <=
  the baseline (packed bytes vs dense, per-step cost scaling) — again
  machine-independent, so a real ceiling can be committed without running
  the bench on CI hardware first;
- acceptance-rate floor keys (any key ending in ``acceptance_rate``):
  fresh must be >= the baseline. The speculative self-draft rate is an
  exact machine-independent invariant (1.0 — the draft IS the target), so
  its committed floor gates everywhere; measured draft rates become gates
  once a baseline is committed;
- boolean gate keys (parity / round-trip flags): a baseline of true must
  stay true.

A fresh file that is missing while its baseline exists is an error: the CI
bench step was supposed to produce it.
"""

import json
import os
import sys

TOLERANCE = 0.30
BENCHES = [
    "BENCH_decode.json",
    "BENCH_quant.json",
    "BENCH_checkpoint.json",
    "BENCH_spec.json",
    "BENCH_shard.json",
]


def is_throughput(key):
    return key == "prefill_tok_s" or key.startswith("decode_tok_s")


def is_size(key):
    return "resident_bytes" in key or key == "checkpoint_file_bytes"


def is_speedup_floor(key):
    return key.endswith("_speedup")


def is_ratio_ceiling(key):
    return "_ratio" in key


def is_acceptance_floor(key):
    return key.endswith("acceptance_rate")


def compare(name, base, fresh):
    failures = []
    checked = 0
    for key, bval in base.items():
        if key not in fresh:
            continue
        fval = fresh[key]
        if isinstance(bval, bool):
            if bval:  # a false baseline is a pending placeholder
                checked += 1
                if not fval:
                    failures.append(f"{name}: gate '{key}' flipped true -> false")
            continue
        if not isinstance(bval, (int, float)) or bval <= 0:
            continue  # pending placeholder or non-numeric: nothing to gate
        if is_throughput(key):
            checked += 1
            floor = bval * (1.0 - TOLERANCE)
            if fval < floor:
                failures.append(
                    f"{name}: '{key}' regressed {bval:.1f} -> {fval:.1f} tok/s "
                    f"(> {TOLERANCE:.0%} drop)"
                )
        elif is_speedup_floor(key):
            checked += 1
            if fval < bval:
                failures.append(
                    f"{name}: '{key}' fell below its committed floor "
                    f"({fval:.3f} < {bval:.3f})"
                )
        elif is_ratio_ceiling(key):
            checked += 1
            if fval > bval:
                failures.append(
                    f"{name}: '{key}' exceeded its committed ceiling "
                    f"({fval:.3f} > {bval:.3f})"
                )
        elif is_acceptance_floor(key):
            checked += 1
            if fval < bval:
                failures.append(
                    f"{name}: '{key}' fell below its committed floor "
                    f"({fval:.3f} < {bval:.3f})"
                )
        elif is_size(key):
            checked += 1
            if fval > bval:
                failures.append(f"{name}: '{key}' grew {bval} -> {fval} bytes")
    return checked, failures


def main():
    args = sys.argv[1:]
    only = None
    if "--only" in args:
        i = args.index("--only")
        if i + 1 >= len(args):
            sys.exit(__doc__)
        only = args[i + 1]
        del args[i : i + 2]
    if len(args) != 2:
        sys.exit(__doc__)
    baseline_dir, fresh_dir = args
    benches = BENCHES
    if only is not None:
        if only not in BENCHES:
            sys.exit(f"--only {only}: unknown bench (expected one of {BENCHES})")
        benches = [only]
    all_failures = []
    for bench in benches:
        base_path = os.path.join(baseline_dir, bench)
        fresh_path = os.path.join(fresh_dir, bench)
        if not os.path.exists(base_path):
            print(f"{bench}: no committed baseline, skipping")
            continue
        if not os.path.exists(fresh_path):
            all_failures.append(f"{bench}: fresh result missing from {fresh_dir}")
            continue
        with open(base_path) as f:
            base = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        bmodel, fmodel = base.get("model"), fresh.get("model")
        if bmodel not in (None, "pending") and bmodel != fmodel:
            # Comparing different model configs would make the byte gates
            # vacuous and the tok/s gates meaningless — demand a matching
            # baseline instead of pretending to gate.
            print(
                f"{bench}: baseline model '{bmodel}' != fresh model '{fmodel}' — "
                "incomparable, skipping (commit a baseline generated at the CI "
                "bench settings to enable this gate)"
            )
            continue
        checked, failures = compare(bench, base, fresh)
        status = "FAIL" if failures else "ok"
        print(f"{bench}: {checked} gated keys, {len(failures)} failures [{status}]")
        all_failures.extend(failures)
    if all_failures:
        print("\nbench regression gate FAILED:")
        for f in all_failures:
            print(f"  - {f}")
        sys.exit(1)
    print("bench regression gate passed")


if __name__ == "__main__":
    main()
