//! Decode-throughput benchmark: prefill tok/s, KV-cached vs uncached decode
//! tok/s, direct evidence that per-token decode cost is O(T) with the
//! cache (a step at position 2N is nowhere near 2× a step at position N,
//! while the uncached full forward scales ~quadratically), and
//! cross-session batched decode throughput at batch 1/8/32 — the serve
//! worker's round kernel (`decode_step_batch`: one GEMM per projection per
//! layer for the whole batch) vs stepping every session through its own
//! matvecs, measured on the same run (`batch_gemm_speedup`).
//!
//! Run: `cargo bench --bench decode` (add `-- --tiny` for the CI smoke run
//! on the test-tiny config). Writes the numbers to `BENCH_decode.json`
//! (override the path with `BENCH_DECODE_OUT`).

use compot::model::config::ModelConfig;
use compot::model::decode::{argmax, DecodeSession, SamplerCfg};
use compot::model::{KvCache, Model};
use compot::util::json::Json;
use compot::util::timer::{bench, humanize};
use compot::util::{Rng, Timer};

/// Median seconds of one decode step taken from the session's current
/// position, sampled over fresh clones so the position never advances.
fn step_cost(model: &Model, at: &DecodeSession, reps: usize) -> f64 {
    let mut samples: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut s = at.clone();
        let t = Timer::start();
        s.step(model);
        samples.push(t.secs());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Step a session forward until `target` tokens are cached.
fn advance_to(model: &Model, s: &mut DecodeSession, target: usize) {
    while s.position() < target && s.step(model).is_some() {}
}

/// Prefilled starting state for a batch of B sessions with mixed prompt
/// lengths (heterogeneous cache positions, like a real serve round): each
/// entry is a cache plus the greedy next-input token.
fn batch_base(model: &Model, bsize: usize) -> Vec<(KvCache, u16)> {
    (0..bsize)
        .map(|i| {
            let prompt: Vec<u16> = (0..4 + i % 5)
                .map(|t| ((t * 7 + i * 3 + 1) % model.cfg.vocab) as u16)
                .collect();
            let mut cache = model.new_cache();
            let logits = model.prefill(&mut cache, &prompt);
            let tok = argmax(logits.row(logits.rows() - 1));
            (cache, tok)
        })
        .collect()
}

/// Run `rounds` greedy decode rounds over clones of `base` — one
/// `decode_step_batch` per round when `batched`, else one `decode_step` per
/// session per round — and return the final token of every session.
fn run_rounds(model: &Model, base: &[(KvCache, u16)], rounds: usize, batched: bool) -> Vec<u16> {
    let mut caches: Vec<KvCache> = base.iter().map(|(c, _)| c.clone()).collect();
    let mut toks: Vec<u16> = base.iter().map(|&(_, t)| t).collect();
    for _ in 0..rounds {
        if batched {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = model.decode_step_batch(&mut refs, &toks);
            for (i, t) in toks.iter_mut().enumerate() {
                *t = argmax(logits.row(i));
            }
        } else {
            for (c, t) in caches.iter_mut().zip(toks.iter_mut()) {
                let logits = model.decode_step(c, *t);
                *t = argmax(&logits);
            }
        }
    }
    toks
}

/// Batched (or per-session sequential) decode throughput over `rounds`
/// rounds from the prefilled base state. The per-iteration cache clone is
/// identical in both modes, so the batched/sequential ratio isolates the
/// dispatch difference.
fn batch_tok_s(
    model: &Model,
    base: &[(KvCache, u16)],
    rounds: usize,
    budget: f64,
    batched: bool,
) -> f64 {
    let st = bench(
        || {
            std::hint::black_box(run_rounds(model, base, rounds, batched));
        },
        budget,
        200,
    );
    (base.len() * rounds) as f64 / st.median_s
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget = std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len, n_pos) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize, 16usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32, 32)
    };
    let mut rng = Rng::new(99);
    let model = Model::random(&cfg, &mut rng);
    let prompt: Vec<u16> = (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();

    // --- prefill throughput ---
    let st_prefill = bench(
        || {
            let mut cache = model.new_cache();
            std::hint::black_box(model.prefill(&mut cache, &prompt));
        },
        budget,
        2000,
    );
    let prefill_tok_s = prompt_len as f64 / st_prefill.median_s;
    println!("{}", st_prefill.format(&format!("prefill {prompt_len} tokens ({})", cfg.name)));

    // --- end-to-end generation: KV-cached sessions vs full re-forward ---
    let st_cached = bench(
        || {
            std::hint::black_box(model.greedy_decode(&prompt, gen_len));
        },
        budget,
        500,
    );
    let st_full = bench(
        || {
            std::hint::black_box(model.greedy_decode_full(&prompt, gen_len));
        },
        budget,
        500,
    );
    let cached_tok_s = gen_len as f64 / st_cached.median_s;
    let full_tok_s = gen_len as f64 / st_full.median_s;
    println!("{}", st_cached.format(&format!("generate {gen_len} cached (incremental)")));
    println!("{}", st_full.format(&format!("generate {gen_len} uncached (full fwd)")));
    println!(
        "decode throughput: {cached_tok_s:.0} tok/s cached vs {full_tok_s:.0} tok/s uncached \
         ({:.2}x speedup)",
        cached_tok_s / full_tok_s
    );

    // --- O(T) scaling: step cost at position N vs position 2N ---
    // The acceptance bar: generating token 2N from an N-token prompt must
    // not cost ~2× token N+1. With the cache, a step is dominated by the
    // (position-independent) projections plus O(T) attention.
    let reps = 60;
    let mut session = DecodeSession::start(
        &model,
        &prompt[..n_pos.min(prompt_len)],
        usize::MAX,
        SamplerCfg::greedy(),
    );
    advance_to(&model, &mut session, n_pos);
    let step_n = step_cost(&model, &session, reps);
    advance_to(&model, &mut session, 2 * n_pos);
    let step_2n = step_cost(&model, &session, reps);
    let ratio = step_2n / step_n;
    println!(
        "step cost @T={n_pos}: {} | @T={}: {} | ratio {ratio:.2} (O(T²) would be ≥2)",
        humanize(step_n),
        2 * n_pos,
        humanize(step_2n)
    );
    if ratio >= 2.0 {
        eprintln!("WARNING: step-cost ratio {ratio:.2} ≥ 2 — cache not amortizing");
    }

    // --- cross-session batched decode: one GEMM per layer per round ---
    // B sessions at heterogeneous positions, stepped together through
    // decode_step_batch vs one at a time through decode_step, same run,
    // same starting caches. Parity is asserted before timing: batching
    // must never change a continuation.
    let batch_rounds = 8usize;
    let mut batch_tok: Vec<(usize, f64)> = Vec::new();
    let mut seq32_tok_s = 0.0f64;
    for bsize in [1usize, 8, 32] {
        let base = batch_base(&model, bsize);
        assert_eq!(
            run_rounds(&model, &base, batch_rounds, true),
            run_rounds(&model, &base, batch_rounds, false),
            "batched decode diverged from per-session stepping at batch {bsize}"
        );
        let batched = batch_tok_s(&model, &base, batch_rounds, budget, true);
        println!("batched decode @B={bsize}: {batched:.0} tok/s");
        if bsize == 32 {
            seq32_tok_s = batch_tok_s(&model, &base, batch_rounds, budget, false);
            println!(
                "sequential decode @B=32: {seq32_tok_s:.0} tok/s ({:.2}x GEMM speedup)",
                batched / seq32_tok_s
            );
        }
        batch_tok.push((bsize, batched));
    }
    let batch_gemm_speedup = batch_tok.last().map(|&(_, t)| t / seq32_tok_s).unwrap_or(0.0);

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "decode".into())
        .set("model", cfg.name.as_str().into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("prefill_tok_s", prefill_tok_s.into())
        .set("decode_tok_s_cached", cached_tok_s.into())
        .set("decode_tok_s_uncached", full_tok_s.into())
        .set("cached_speedup", (cached_tok_s / full_tok_s).into())
        .set("step_s_at_n", step_n.into())
        .set("step_s_at_2n", step_2n.into())
        .set("step_cost_ratio_2n_vs_n", ratio.into())
        .set("o_t_scaling_ok", Json::Bool(ratio < 2.0))
        .set("batch_rounds", batch_rounds.into());
    for &(bsize, tok_s) in &batch_tok {
        j.set(&format!("decode_tok_s_batch{bsize}"), tok_s.into());
    }
    j.set("decode_tok_s_batch32_sequential", seq32_tok_s.into())
        // batch-32 batched round vs 32 per-row steps, same run, same caches
        .set("batch_gemm_speedup", batch_gemm_speedup.into());
    let out = std::env::var("BENCH_DECODE_OUT").unwrap_or_else(|_| "BENCH_decode.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
