//! Speculative-decoding benchmark: greedy parity (spec output vs the target
//! alone — the correctness gate), acceptance rate for a 4-bit draft of the
//! same network, and tok/s for the three serving tiers (draft-only,
//! target-only, speculative).
//!
//! Run: `cargo bench --bench spec_decode` (add `-- --tiny` for the CI smoke
//! run on the test-tiny config). Writes `BENCH_spec.json` (override the
//! path with `BENCH_SPEC_OUT`).

use compot::compress::LinearWeight;
use compot::linalg::QuantMat;
use compot::model::config::{ModelConfig, ProjKind};
use compot::model::transformer::Stage;
use compot::model::Model;
use compot::serve::SpeculativeSession;
use compot::util::json::Json;
use compot::util::timer::bench;
use compot::util::Rng;

/// 4-bit-pack every dense projection: the cheap same-network draft the
/// speculative tier is designed around (compare `rtn4` in the plan DSL).
fn rtn4_draft(target: &Model) -> Model {
    let mut d = target.clone();
    for stage in d.stages.iter_mut() {
        if let Stage::Block(b) = stage {
            for p in ProjKind::DECODER_SET {
                let packed = match b.proj(p) {
                    LinearWeight::Dense(w) => Some(QuantMat::quantize_from(w, 4)),
                    _ => None,
                };
                if let Some(q) = packed {
                    *b.proj_mut(p) = LinearWeight::QuantDense(q);
                }
            }
        }
    }
    d
}

fn spec_generate(target: &Model, draft: &Model, prompt: &[u16], gen: usize, k: usize) -> (Vec<u16>, u64, u64, u64) {
    let mut s = SpeculativeSession::start(target, draft, prompt, gen, k);
    while s.round(target, draft).is_some() {}
    (s.generated().to_vec(), s.draft_proposed(), s.draft_accepted(), s.verify_rounds())
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget = std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32)
    };
    let draft_k = 4usize;
    let mut rng = Rng::new(77);
    let target = Model::random(&cfg, &mut rng);
    let draft = rtn4_draft(&target);
    let prompt: Vec<u16> =
        (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();

    // --- correctness gate: greedy spec output must be token-identical to
    // the target alone, for the quantized draft AND a self-draft ---
    let mut parity = true;
    let mut proposed = 0u64;
    let mut accepted = 0u64;
    let mut rounds = 0u64;
    for p0 in 0..4u16 {
        let p: Vec<u16> = prompt.iter().map(|&t| (t + p0) % cfg.vocab as u16).collect();
        let want = target.greedy_decode(&p, gen_len);
        let (got, pr, ac, ro) = spec_generate(&target, &draft, &p, gen_len, draft_k);
        parity &= got == want;
        proposed += pr;
        accepted += ac;
        rounds += ro;
    }
    let acceptance = if proposed == 0 { 0.0 } else { accepted as f64 / proposed as f64 };
    let tokens_per_forward = if rounds == 0 { 0.0 } else { accepted as f64 / rounds as f64 };
    let (_, sp, sa, _) = spec_generate(&target, &target, &prompt, gen_len, draft_k);
    let self_acceptance = if sp == 0 { 0.0 } else { sa as f64 / sp as f64 };
    println!(
        "parity {} | rtn4-draft acceptance {acceptance:.3} ({accepted}/{proposed}, \
         {tokens_per_forward:.2} accepted tok/verify) | self-draft acceptance {self_acceptance:.3}",
        if parity { "OK" } else { "FAILED" }
    );

    // --- tier throughputs ---
    let st_target = bench(
        || {
            std::hint::black_box(target.greedy_decode(&prompt, gen_len));
        },
        budget,
        500,
    );
    let st_draft = bench(
        || {
            std::hint::black_box(draft.greedy_decode(&prompt, gen_len));
        },
        budget,
        500,
    );
    let st_spec = bench(
        || {
            std::hint::black_box(spec_generate(&target, &draft, &prompt, gen_len, draft_k));
        },
        budget,
        500,
    );
    let target_tok_s = gen_len as f64 / st_target.median_s;
    let draft_tok_s = gen_len as f64 / st_draft.median_s;
    let spec_tok_s = gen_len as f64 / st_spec.median_s;
    println!("{}", st_target.format(&format!("full tier: {gen_len} tokens ({})", cfg.name)));
    println!("{}", st_draft.format(&format!("draft tier: {gen_len} tokens (rtn4)")));
    println!("{}", st_spec.format(&format!("spec tier: {gen_len} tokens (k={draft_k})")));
    println!(
        "tier throughput: {target_tok_s:.0} full | {draft_tok_s:.0} draft | {spec_tok_s:.0} \
         spec tok/s"
    );

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "spec_decode".into())
        .set("model", cfg.name.as_str().into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("draft_k", draft_k.into())
        .set("spec_parity", Json::Bool(parity))
        .set("acceptance_rate", acceptance.into())
        .set("self_draft_acceptance_rate", self_acceptance.into())
        .set("draft_tokens_per_target_forward", tokens_per_forward.into())
        .set("decode_tok_s_target_only", target_tok_s.into())
        .set("decode_tok_s_draft_only", draft_tok_s.into())
        .set("decode_tok_s_spec", spec_tok_s.into());
    let out = std::env::var("BENCH_SPEC_OUT").unwrap_or_else(|_| "BENCH_spec.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
    if !parity {
        eprintln!("spec_parity FAILED: speculative output diverged from the target");
        std::process::exit(1);
    }
}
