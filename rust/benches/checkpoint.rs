//! Checkpoint benchmark: cold-loading a CPT2 compressed checkpoint — via
//! the copying loader *and* the zero-copy mmap loader — vs recompressing
//! from the dense model at startup. These are the numbers that decide
//! whether serve restarts scale with compressed size or with model size,
//! and whether `--mmap` is pulling its weight.
//!
//! Gates (the process exits non-zero if any fails):
//! - round trip is lossless: the reloaded model greedy-decodes
//!   **token-identically** to the in-memory compressed model and reports
//!   **equal** `resident_weight_bytes()`;
//! - the mmap load is **token-identical** too, keeps its weight bytes in
//!   the mapping (resident < copying load), and is **strictly faster**
//!   than the copying cold load;
//! - cold load is **strictly faster** than the recompress path
//!   (calibration + plan run) on the bench model.
//!
//! Run: `cargo bench --bench checkpoint` (add `-- --tiny` for the CI
//! round-trip smoke run). Writes `BENCH_checkpoint.json` (override with
//! `BENCH_CHECKPOINT_OUT`).

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::util::json::Json;
use compot::util::timer::{bench, humanize};
use compot::util::Rng;

const PLAN: &str = "compot@0.25+gptq4";

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget: f64 =
        std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32)
    };
    let mut rng = Rng::new(171);
    let model = Model::random(&cfg, &mut rng);
    let lang = SynthLang::wiki(cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(172));
    let prompt: Vec<u16> =
        (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();
    let plan = CompressionPlan::parse(PLAN, &StageConfig::new(0.25, false)).expect("plan");

    // --- recompress path: what a serve restart costs without a checkpoint ---
    let st_recompress = bench(
        || {
            std::hint::black_box(plan.run(&model, &calib).expect("plan run"));
        },
        budget,
        50,
    );
    println!("{}", st_recompress.format(&format!("recompress ({PLAN}, {})", cfg.name)));
    let (compressed, report) = plan.run(&model, &calib).expect("plan run");

    // --- save, then cold-load the checkpoint ---
    let path = std::env::temp_dir().join(format!("compot_bench_{}.cpt2", cfg.name));
    compressed.save_compressed(&path, Some(&plan.describe())).expect("save_compressed");
    let file_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let st_load = bench(
        || {
            std::hint::black_box(Model::load_compressed(&path).expect("load_compressed"));
        },
        budget,
        200,
    );
    println!("{}", st_load.format("cold-load CPT2 checkpoint"));
    let speedup = st_recompress.median_s / st_load.median_s;
    println!(
        "cold load {} vs recompress {} — {speedup:.1}x faster restart ({file_bytes} B on disk)",
        humanize(st_load.median_s),
        humanize(st_recompress.median_s)
    );

    // --- zero-copy mmap cold load ---
    let st_mmap = bench(
        || {
            std::hint::black_box(
                Model::load_compressed_mmap(&path).expect("load_compressed_mmap"),
            );
        },
        budget,
        200,
    );
    println!("{}", st_mmap.format("mmap cold-load CPT2 checkpoint"));
    let mmap_vs_copy = st_load.median_s / st_mmap.median_s;
    println!(
        "mmap load {} vs copying load {} — {mmap_vs_copy:.1}x",
        humanize(st_mmap.median_s),
        humanize(st_load.median_s)
    );
    let (mmapped, mmap_info) = Model::load_compressed_mmap(&path).expect("load_compressed_mmap");
    let mmap_tokens_match =
        mmapped.greedy_decode(&prompt, gen_len) == compressed.greedy_decode(&prompt, gen_len);
    println!(
        "mmap round trip: source '{}' | greedy decode {} | {} resident + {} mapped bytes",
        mmap_info.source,
        if mmap_tokens_match { "token-identical" } else { "DIVERGED" },
        mmapped.resident_weight_bytes(),
        mmapped.mapped_weight_bytes()
    );

    // --- round-trip losslessness ---
    let (reloaded, info) = Model::load_compressed(&path).expect("load_compressed");
    let bytes_match = reloaded.resident_weight_bytes() == compressed.resident_weight_bytes();
    let tokens_match =
        reloaded.greedy_decode(&prompt, gen_len) == compressed.greedy_decode(&prompt, gen_len);
    println!(
        "round trip: resident bytes {} | greedy decode {} | recorded plan '{}'",
        if bytes_match { "equal" } else { "DIFFER" },
        if tokens_match { "token-identical" } else { "DIVERGED" },
        info.plan.as_deref().unwrap_or("?")
    );
    let loaded_tok_s = {
        let st = bench(
            || {
                std::hint::black_box(reloaded.greedy_decode(&prompt, gen_len));
            },
            budget,
            500,
        );
        gen_len as f64 / st.median_s
    };
    println!("decode through the reloaded checkpoint: {loaded_tok_s:.0} tok/s");
    std::fs::remove_file(&path).ok();

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "checkpoint".into())
        .set("model", cfg.name.as_str().into())
        .set("plan", PLAN.into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("checkpoint_file_bytes", (file_bytes as usize).into())
        .set("resident_bytes", compressed.resident_weight_bytes().into())
        .set("composed_cr", report.composed_cr.into())
        .set("cold_load_s", st_load.median_s.into())
        .set("recompress_s", st_recompress.median_s.into())
        .set("cold_load_speedup", speedup.into())
        .set("mmap_load_s", st_mmap.median_s.into())
        .set("mmap_vs_copy_speedup", mmap_vs_copy.into())
        .set("mmap_resident_bytes", mmapped.resident_weight_bytes().into())
        .set("mmap_mapped_bytes", mmapped.mapped_weight_bytes().into())
        .set("decode_tok_s_loaded", loaded_tok_s.into())
        .set("roundtrip_tokens_identical", Json::Bool(tokens_match))
        .set("roundtrip_bytes_equal", Json::Bool(bytes_match))
        .set("mmap_tokens_identical", Json::Bool(mmap_tokens_match));
    let out =
        std::env::var("BENCH_CHECKPOINT_OUT").unwrap_or_else(|_| "BENCH_checkpoint.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    // --- hard gates (after the JSON so CI still records the numbers) ---
    assert!(tokens_match, "reloaded checkpoint decode diverged from the in-memory model");
    assert!(bytes_match, "reloaded checkpoint resident bytes differ from the in-memory model");
    assert!(
        st_load.median_s < st_recompress.median_s,
        "cold load ({}) must beat recompression ({})",
        humanize(st_load.median_s),
        humanize(st_recompress.median_s)
    );
    assert!(mmap_tokens_match, "mmap-loaded checkpoint decode diverged from the in-memory model");
    // Page-sharing accounting only applies to a true mapping — on a host
    // whose filesystem cannot mmap, the loader's documented heap fallback
    // ("mmap-fallback") correctly reports the bytes as resident instead.
    if mmap_info.source == "mmap" {
        assert!(
            mmapped.mapped_weight_bytes() > 0
                && mmapped.resident_weight_bytes() < reloaded.resident_weight_bytes(),
            "mmap load must keep weight bytes in the mapping, not the heap"
        );
    } else {
        eprintln!("note: mmap fallback in effect — page-sharing gate skipped");
    }
    assert!(
        st_mmap.median_s < st_load.median_s,
        "mmap cold load ({}) must beat the copying load ({})",
        humanize(st_mmap.median_s),
        humanize(st_load.median_s)
    );
}
