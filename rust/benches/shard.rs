//! Sharded-checkpoint + pipeline-serving benchmark: save a compressed
//! model as a 2-shard CPT2 set, reload it whole and as per-stage partials,
//! and run a 2-process-shaped (2-thread, loopback TCP) pipeline — head
//! holds the embedding and the first stages, tail holds the rest plus the
//! LM head — comparing its served tokens against single-host greedy
//! decode.
//!
//! Gates (the process exits non-zero if any fails):
//! - the sharded save reloads **bit-identically** through the full stage
//!   range (token-identical greedy decode, equal resident bytes), owned
//!   and mmap;
//! - the head + tail partial models **partition** the full model's
//!   resident weight bytes exactly (nothing duplicated, nothing dropped);
//! - the loopback pipeline serves tokens **identical** to single-host
//!   greedy decode.
//!
//! Also measured: sharded vs monolithic full cold-load time, the head
//! partial's resident-byte share (`stage0_resident_ratio`, committed as a
//! machine-independent ceiling in `BENCH_shard.json`), and pipeline vs
//! in-process decode throughput.
//!
//! Run: `cargo bench --bench shard` (add `-- --tiny` for the CI smoke
//! run). Writes `BENCH_shard.json` (override with `BENCH_SHARD_OUT`).

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::serve::{serve_pipeline_head, serve_pipeline_tail, BatchPolicy, Client};
use compot::util::json::Json;
use compot::util::timer::bench;
use compot::util::{Rng, Timer};
use std::sync::{mpsc, Arc};

const PLAN: &str = "rtn4";
const N_SHARDS: usize = 2;

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget: f64 =
        std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32)
    };
    let mut rng = Rng::new(201);
    let model = Model::random(&cfg, &mut rng);
    let lang = SynthLang::wiki(cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(202));
    let prompt: Vec<u16> =
        (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();
    let plan = CompressionPlan::parse(PLAN, &StageConfig::new(0.25, false)).expect("plan");
    let (compressed, _) = plan.run(&model, &calib).expect("plan run");
    let n_stages = compressed.stages.len();
    let split = n_stages / 2;
    let want = compressed.greedy_decode(&prompt, gen_len);

    // --- save once sharded, once monolithic, and time the full reloads ---
    let dir = std::env::temp_dir();
    let sharded_path = dir.join(format!("compot_bench_shard_{}.cpt2", cfg.name));
    let mono_path = dir.join(format!("compot_bench_shard_{}_mono.cpt2", cfg.name));
    compressed
        .save_compressed_sharded(&sharded_path, Some(PLAN), N_SHARDS)
        .expect("save_compressed_sharded");
    compressed.save_compressed(&mono_path, Some(PLAN)).expect("save_compressed");
    let st_shard = bench(
        || {
            std::hint::black_box(
                Model::load_stage_range(&sharded_path, 0..n_stages, false).expect("shard load"),
            );
        },
        budget,
        200,
    );
    let st_mono = bench(
        || {
            std::hint::black_box(Model::load_compressed(&mono_path).expect("mono load"));
        },
        budget,
        200,
    );
    println!("{}", st_shard.format(&format!("full load from {N_SHARDS}-shard set")));
    println!("{}", st_mono.format("full load from monolithic checkpoint"));

    // --- sharded round trip: full range, owned and mmap ---
    let mut manifest_parity = true;
    for mmap in [false, true] {
        let (full, info) =
            Model::load_stage_range(&sharded_path, 0..n_stages, mmap).expect("full range");
        let ok = full.greedy_decode(&prompt, gen_len) == want
            && full.resident_weight_bytes() + full.mapped_weight_bytes()
                == compressed.resident_weight_bytes();
        println!(
            "sharded full reload (source '{}'): {}",
            info.source,
            if ok { "token-identical, bytes equal" } else { "DIVERGED" }
        );
        manifest_parity &= ok;
    }

    // --- stage partials: byte partition + the head's share ---
    let (head, _) = Model::load_stage_range(&sharded_path, 0..split, false).expect("head range");
    let (tail, _) =
        Model::load_stage_range(&sharded_path, split..n_stages, false).expect("tail range");
    let full_bytes = compressed.resident_weight_bytes();
    let (head_bytes, tail_bytes) = (head.resident_weight_bytes(), tail.resident_weight_bytes());
    let partition_exact = head_bytes + tail_bytes == full_bytes;
    let stage0_ratio = head_bytes as f64 / full_bytes as f64;
    println!(
        "partials (split {split}/{n_stages}): head {head_bytes} B ({stage0_ratio:.3}x) + \
         tail {tail_bytes} B = full {full_bytes} B partition {}",
        if partition_exact { "exact" } else { "BROKEN" }
    );

    // --- loopback pipeline: tail thread, head thread, one client ---
    let (tail_tx, tail_rx) = mpsc::channel();
    let tail_model = Arc::new(tail);
    let tail_t = std::thread::spawn(move || {
        serve_pipeline_tail(tail_model, "127.0.0.1:0", move |a| {
            tail_tx.send(a).unwrap();
        })
    });
    let tail_addr = tail_rx.recv().expect("tail ready");
    let (head_tx, head_rx) = mpsc::channel();
    let head_model = Arc::new(head);
    let next = tail_addr.to_string();
    let head_t = std::thread::spawn(move || {
        serve_pipeline_head(
            head_model,
            "127.0.0.1:0",
            &next,
            BatchPolicy::default(),
            Json::obj(),
            move |a| {
                head_tx.send(a).unwrap();
            },
        )
    });
    let head_addr = head_rx.recv().expect("head ready");
    let mut c = Client::connect(head_addr).expect("connect");
    let served = c.request(&prompt, gen_len).expect("pipeline request").tokens;
    let pipeline_parity = served == want;
    println!(
        "pipeline decode vs single-host greedy: {}",
        if pipeline_parity { "token-identical" } else { "DIVERGED" }
    );

    // --- throughput: pipeline rounds (loopback TCP) vs in-process decode ---
    let iters = if tiny { 4 } else { 8 };
    let t = Timer::start();
    for _ in 0..iters {
        c.request(&prompt, gen_len).expect("pipeline request");
    }
    let pipeline_tok_s = (iters * gen_len) as f64 / t.secs();
    let st_single = bench(
        || {
            std::hint::black_box(compressed.greedy_decode(&prompt, gen_len));
        },
        budget,
        500,
    );
    let single_tok_s = gen_len as f64 / st_single.median_s;
    println!(
        "decode tok/s ({}): pipeline {pipeline_tok_s:.0} | single-host {single_tok_s:.0}",
        cfg.name
    );
    c.shutdown().expect("shutdown");
    head_t.join().expect("head thread").expect("head serve");
    tail_t.join().expect("tail thread").expect("tail serve");
    std::fs::remove_file(&mono_path).ok();
    std::fs::remove_file(&sharded_path).ok();
    for i in 0..N_SHARDS {
        let stem = sharded_path.file_stem().unwrap_or_default().to_string_lossy().into_owned();
        std::fs::remove_file(dir.join(format!("{stem}.shard{i}.cpt2"))).ok();
    }

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "shard".into())
        .set("model", cfg.name.as_str().into())
        .set("plan", PLAN.into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("n_shards", N_SHARDS.into())
        .set("shard_load_s", st_shard.median_s.into())
        .set("mono_load_s", st_mono.median_s.into())
        .set("stage0_resident_ratio", stage0_ratio.into())
        .set("decode_tok_s_pipeline", pipeline_tok_s.into())
        .set("decode_tok_s_single", single_tok_s.into())
        .set("shard_manifest_parity", Json::Bool(manifest_parity))
        .set("shard_partition_exact", Json::Bool(partition_exact))
        .set("pipeline_parity", Json::Bool(pipeline_parity));
    let out = std::env::var("BENCH_SHARD_OUT").unwrap_or_else(|_| "BENCH_shard.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    // --- hard gates (after the JSON so CI still records the numbers) ---
    assert!(manifest_parity, "sharded full reload diverged from the in-memory model");
    assert!(
        partition_exact,
        "head + tail partials must partition the full model's resident bytes \
         ({head_bytes} + {tail_bytes} != {full_bytes})"
    );
    assert!(pipeline_parity, "pipeline decode diverged from single-host greedy decode");
}
