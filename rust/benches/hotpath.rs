//! Hot-path micro-benchmarks (perf-pass instrumentation; the in-tree bench
//! harness replaces criterion in this offline environment).
//!
//! Run: `cargo bench --bench hotpath` — prints median/mean/min per op and
//! GFLOP/s where meaningful. Results are logged in EXPERIMENTS.md §Perf.

use compot::compress::compot::{factorize, CompotConfig, DictInit};
use compot::compress::cospadi::{ksvd_factorize, omp_column, CospadiConfig};
use compot::compress::sparse::ColumnSparse;
use compot::linalg::{cholesky, gemm, qr, svd, Mat};
use compot::util::timer::bench;
use compot::util::Rng;

fn header() {
    println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "median", "mean", "min");
    println!("{}", "-".repeat(96));
}

fn report_with_flops(name: &str, st: compot::util::timer::BenchStats, flops: f64) {
    let gfs = flops / st.median_s / 1e9;
    println!("{}  [{gfs:6.2} GFLOP/s]", st.format(name));
}

fn main() {
    let budget = std::env::var("BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.4);
    let mut rng = Rng::new(99);
    header();

    // --- GEMM (the dominant op in the COMPOT inner loop) ---
    for &(m, k, n) in &[(96usize, 96usize, 256usize), (256, 96, 256), (512, 512, 512)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, k, n, 1.0);
        let st = bench(
            || {
                std::hint::black_box(gemm::matmul(&a, &b));
            },
            budget,
            10_000,
        );
        report_with_flops(&format!("gemm {m}x{k}x{n}"), st, 2.0 * (m * k * n) as f64);
    }

    // --- Jacobi SVD (Procrustes inner solve) ---
    for &(m, k) in &[(96usize, 40usize), (256, 62), (256, 128)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let st = bench(
            || {
                std::hint::black_box(svd::svd_thin(&a));
            },
            budget,
            1000,
        );
        println!("{}", st.format(&format!("jacobi_svd {m}x{k}")));
    }

    // --- Procrustes (thin SVD + product) ---
    let mmat = Mat::randn(&mut rng, 256, 62, 1.0);
    let st = bench(
        || {
            std::hint::black_box(svd::procrustes(&mmat));
        },
        budget,
        1000,
    );
    println!("{}", st.format("procrustes 256x62"));

    // --- Hard threshold (sparse coding step) ---
    for &(k, n, s) in &[(70usize, 256usize, 35usize), (128, 1024, 32)] {
        let zt = Mat::randn(&mut rng, n, k, 1.0);
        let st = bench(
            || {
                std::hint::black_box(ColumnSparse::hard_threshold_zt(&zt, s));
            },
            budget,
            5000,
        );
        println!("{}", st.format(&format!("hard_threshold k={k} n={n} s={s}")));
    }

    // --- OMP column (CoSpaDi's sparse coding — the cost COMPOT removes) ---
    let dict = qr::random_orthonormal(&mut rng, 96, 70);
    let norms: Vec<f64> = vec![1.0; 70];
    let y: Vec<f32> = (0..96).map(|_| rng.gauss32()).collect();
    let st = bench(
        || {
            std::hint::black_box(omp_column(&dict, &norms, &y, 35));
        },
        budget,
        5000,
    );
    println!("{}", st.format("omp_column m=96 k=70 s=35"));

    // --- Full factorization: COMPOT vs K-SVD at equal iteration count ---
    let wt = Mat::randn(&mut rng, 96, 256, 1.0);
    let cfg = CompotConfig { iters: 5, init: DictInit::Svd, ..Default::default() };
    let st = bench(
        || {
            let mut r = Rng::new(1);
            std::hint::black_box(factorize(&wt, 70, 35, &cfg, &mut r));
        },
        budget.max(1.0),
        100,
    );
    println!("{}", st.format("compot_factorize 96x256 k=70 s=35 T=5"));
    let kcfg = CospadiConfig { iters: 5, ..Default::default() };
    let st = bench(
        || {
            let mut r = Rng::new(1);
            std::hint::black_box(ksvd_factorize(&wt, 70, 35, &kcfg, &mut r));
        },
        budget.max(1.0),
        20,
    );
    println!("{}", st.format("ksvd_factorize   96x256 k=70 s=35 T=5"));

    // --- Cholesky + whitening ---
    let x = Mat::randn(&mut rng, 512, 96, 1.0);
    let g = gemm::matmul_tn(&x, &x);
    let st = bench(
        || {
            std::hint::black_box(cholesky::cholesky(&g).unwrap());
        },
        budget,
        2000,
    );
    println!("{}", st.format("cholesky 96x96"));

    // --- Sparse apply (compressed-layer forward tail) vs dense ---
    let t = Mat::randn(&mut rng, 64, 70, 1.0);
    let z = Mat::randn(&mut rng, 70, 256, 1.0);
    let cs = ColumnSparse::hard_threshold(&z, 35);
    let dense_s = cs.to_dense();
    let st1 = bench(
        || {
            std::hint::black_box(cs.apply_after(&t));
        },
        budget,
        5000,
    );
    println!("{}", st1.format("sparse_apply 64x70 x (70x256, s=35)"));
    let st2 = bench(
        || {
            std::hint::black_box(gemm::matmul(&t, &dense_s));
        },
        budget,
        5000,
    );
    println!("{}", st2.format("dense_apply  64x70 x 70x256"));
    println!("sparse/dense apply ratio: {:.2}x", st1.median_s / st2.median_s);
}
