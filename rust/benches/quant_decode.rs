//! Quantized-storage benchmark: resident weight bytes (measured from the
//! actual packed buffers) and decode throughput through the packed-native
//! `apply_row` kernels, for a 4-bit RTN plan and the Table-7
//! `compot@0.25+gptq4` composition.
//!
//! Gates (the process exits non-zero if any fails):
//! - a 4-bit quantized model's resident weight bytes are **< 0.5×** the
//!   dense f32 model's;
//! - greedy decode through the packed path is **token-identical** to the
//!   fake-quant f32 reference model;
//! - the same model re-encoded row-sequentially decodes token-identically
//!   to the planar default (layout parity).
//!
//! Also measured: the planar-vs-row-seq unpack speedup and the fused int8
//! matvec speedup on a synthetic packed matrix, plus the active SIMD
//! kernel name — `rtn4_unpack_speedup` carries a committed CI floor in
//! `BENCH_quant.json` (see the note there) — and a group-size sweep
//! (64/128/256) recording resident bytes and perplexity per group size
//! (`group{g}_resident_bytes` / `group{g}_ppl`, recorded not gated).
//!
//! Run: `cargo bench --bench quant_decode` (add `-- --tiny` for the CI
//! smoke run). Writes `BENCH_quant.json` (override with `BENCH_QUANT_OUT`).

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::linalg::{simd, Mat, QuantLayout, QuantMat};
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::util::json::Json;
use compot::util::timer::bench;
use compot::util::Rng;

fn decode_tok_s(model: &Model, prompt: &[u16], gen_len: usize, budget: f64) -> f64 {
    let st = bench(
        || {
            std::hint::black_box(model.greedy_decode(prompt, gen_len));
        },
        budget,
        500,
    );
    gen_len as f64 / st.median_s
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget = std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32)
    };
    let mut rng = Rng::new(77);
    let model = Model::random(&cfg, &mut rng);
    let lang = SynthLang::wiki(cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(78));
    let prompt: Vec<u16> =
        (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();
    let dense_bytes = model.resident_weight_bytes();
    let defaults = StageConfig::new(0.25, false);

    // --- 4-bit RTN: the resident-bytes acceptance gate ---
    let plan4 = CompressionPlan::parse("rtn4", &defaults).expect("rtn4 plan");
    let (q4, _) = plan4.run(&model, &calib).expect("rtn4 run");
    let q4_bytes = q4.resident_weight_bytes();
    let ratio = q4_bytes as f64 / dense_bytes as f64;
    println!("resident weight bytes: dense {dense_bytes} | rtn4 packed {q4_bytes} ({ratio:.3}x)");

    // --- packed decode parity vs the fake-quant f32 reference ---
    let reference = q4.dequantize_projections();
    let packed_out = q4.greedy_decode(&prompt, gen_len);
    let reference_out = reference.greedy_decode(&prompt, gen_len);
    let parity = packed_out == reference_out;
    println!(
        "packed decode parity vs fake-quant reference: {}",
        if parity { "token-identical" } else { "DIVERGED" }
    );

    // --- decode throughput: dense vs packed vs dequantized reference ---
    let dense_tok_s = decode_tok_s(&model, &prompt, gen_len, budget);
    let packed_tok_s = decode_tok_s(&q4, &prompt, gen_len, budget);
    let reference_tok_s = decode_tok_s(&reference, &prompt, gen_len, budget);
    println!(
        "decode tok/s ({}): dense {dense_tok_s:.0} | rtn4 packed {packed_tok_s:.0} | \
         dequantized reference {reference_tok_s:.0}",
        cfg.name
    );

    // --- planar vs row-sequential unpack, same weights, same run ---
    // The same model re-encoded row-sequentially decodes through the legacy
    // scalar unpack; the ratio is the code-planar + SIMD kernel speedup and
    // is measured within one run, so it gates machine-independently.
    let kernel = simd::active().name();
    let rowseq_model = q4.with_quant_layout(QuantLayout::RowSeq);
    let rowseq_tok_s = decode_tok_s(&rowseq_model, &prompt, gen_len, budget);
    let layout_parity = rowseq_model.greedy_decode(&prompt, gen_len) == packed_out;
    let (rows, cols) = if tiny { (64, 256) } else { (256, 1024) };
    let wsynth = Mat::randn(&mut Rng::new(79), rows, cols, 1.0);
    let qm = QuantMat::quantize_from_grouped(&wsynth, 4, 128);
    let qm_rowseq = qm.with_layout(QuantLayout::RowSeq);
    let x: Vec<f32> = (0..rows).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 - 0.5).collect();
    let t_planar = bench(
        || {
            std::hint::black_box(qm.apply_row(&x));
        },
        budget,
        500,
    );
    let t_rowseq = bench(
        || {
            std::hint::black_box(qm_rowseq.apply_row(&x));
        },
        budget,
        500,
    );
    let t_i8 = bench(
        || {
            std::hint::black_box(qm.apply_row_i8(&x));
        },
        budget,
        500,
    );
    let unpack_speedup = t_rowseq.median_s / t_planar.median_s;
    let i8_speedup = t_planar.median_s / t_i8.median_s;
    println!(
        "unpack kernels ({kernel}, {rows}x{cols} @4b g128): planar {unpack_speedup:.2}x over \
         row-seq | int8 fused {i8_speedup:.2}x over f32 | rowseq decode {rowseq_tok_s:.0} tok/s \
         | layout parity {}",
        if layout_parity { "ok" } else { "DIVERGED" }
    );

    // --- Table 7 composition: factorize then 4-bit GPTQ the factors ---
    let plan_t7 = CompressionPlan::parse("compot@0.25+gptq4", &defaults).expect("t7 plan");
    let (t7, report) = plan_t7.run(&model, &calib).expect("t7 run");
    let t7_bytes = t7.resident_weight_bytes();
    let t7_tok_s = decode_tok_s(&t7, &prompt, gen_len, budget);
    let t7_reference = t7.dequantize_projections();
    let t7_parity =
        t7.greedy_decode(&prompt, gen_len) == t7_reference.greedy_decode(&prompt, gen_len);
    println!(
        "compot@0.25+gptq4: composed CR {:.3} | {t7_bytes} resident bytes ({:.3}x) | \
         {t7_tok_s:.0} tok/s | parity {}",
        report.composed_cr,
        t7_bytes as f64 / dense_bytes as f64,
        if t7_parity { "ok" } else { "DIVERGED" }
    );

    // --- group-size sweep: perplexity vs scale overhead ---
    // One u16 scale per group adds 16/g bits on top of the 4 payload bits
    // per weight (4.25 / 4.125 / 4.0625 bits at g = 64 / 128 / 256), while
    // a tighter group tracks the local weight distribution more closely —
    // this sweep records both sides of that trade so the README table has
    // measured numbers behind the analytic overhead column.
    let (n_eval, eval_len) = if tiny { (2, 32) } else { (4, 64) };
    let eval_seqs = lang.gen_batch(n_eval, eval_len, &mut Rng::new(80));
    let dense_ppl = compot::eval::perplexity(&model, &eval_seqs);
    println!("group-size sweep (rtn4, dense ppl {dense_ppl:.3}):");
    let mut sweep: Vec<(usize, usize, f64)> = Vec::new();
    for g in [64usize, 128, 256] {
        let plan = CompressionPlan::parse(&format!("rtn4,group_size={g}"), &defaults)
            .expect("rtn4 group plan");
        let (qg, _) = plan.run(&model, &calib).expect("rtn4 group run");
        let bytes = qg.resident_weight_bytes();
        let ppl = compot::eval::perplexity(&qg, &eval_seqs);
        println!(
            "  g={g:<3} {bytes} resident bytes ({:.3}x dense, {:.4} bits/weight analytic) \
             | ppl {ppl:.3}",
            bytes as f64 / dense_bytes as f64,
            4.0 + 16.0 / g as f64,
        );
        sweep.push((g, bytes, ppl));
    }

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "quant_decode".into())
        .set("model", cfg.name.as_str().into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("dense_resident_bytes", dense_bytes.into())
        .set("rtn4_resident_bytes", q4_bytes.into())
        .set("rtn4_bytes_ratio", ratio.into())
        .set("decode_tok_s_dense", dense_tok_s.into())
        .set("decode_tok_s_rtn4_packed", packed_tok_s.into())
        .set("decode_tok_s_rtn4_rowseq", rowseq_tok_s.into())
        .set("decode_tok_s_dequant_reference", reference_tok_s.into())
        .set("simd_kernel", kernel.into())
        .set("rtn4_unpack_speedup", unpack_speedup.into())
        .set("rtn4_i8_matvec_speedup", i8_speedup.into())
        .set("rtn4_layout_parity", Json::Bool(layout_parity))
        .set("rtn4_parity_vs_reference", Json::Bool(parity))
        .set("t7_composed_cr", report.composed_cr.into())
        .set("t7_resident_bytes", t7_bytes.into())
        .set("decode_tok_s_t7_packed", t7_tok_s.into())
        .set("t7_parity_vs_reference", Json::Bool(t7_parity))
        .set("dense_ppl", dense_ppl.into());
    for (g, bytes, ppl) in &sweep {
        j.set(format!("group{g}_resident_bytes").as_str(), (*bytes).into())
            .set(format!("group{g}_ppl").as_str(), (*ppl).into());
    }
    let out = std::env::var("BENCH_QUANT_OUT").unwrap_or_else(|_| "BENCH_quant.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    // --- hard gates (after the JSON so CI still records the numbers) ---
    assert!(
        ratio < 0.5,
        "4-bit packed model must be < 0.5x dense resident bytes, got {ratio:.3}"
    );
    assert!(parity, "packed rtn4 decode diverged from the fake-quant f32 reference");
    assert!(layout_parity, "row-seq re-encode diverged from the planar decode");
    assert!(t7_parity, "packed compot+gptq4 decode diverged from its reference");
}
