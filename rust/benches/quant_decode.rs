//! Quantized-storage benchmark: resident weight bytes (measured from the
//! actual packed buffers) and decode throughput through the packed-native
//! `apply_row` kernels, for a 4-bit RTN plan and the Table-7
//! `compot@0.25+gptq4` composition.
//!
//! Gates (the process exits non-zero if either fails):
//! - a 4-bit quantized model's resident weight bytes are **< 0.5×** the
//!   dense f32 model's;
//! - greedy decode through the packed path is **token-identical** to the
//!   fake-quant f32 reference model.
//!
//! Run: `cargo bench --bench quant_decode` (add `-- --tiny` for the CI
//! smoke run). Writes `BENCH_quant.json` (override with `BENCH_QUANT_OUT`).

use compot::compress::StageConfig;
use compot::coordinator::plan::CompressionPlan;
use compot::data::SynthLang;
use compot::model::config::ModelConfig;
use compot::model::Model;
use compot::util::json::Json;
use compot::util::timer::bench;
use compot::util::Rng;

fn decode_tok_s(model: &Model, prompt: &[u16], gen_len: usize, budget: f64) -> f64 {
    let st = bench(
        || {
            std::hint::black_box(model.greedy_decode(prompt, gen_len));
        },
        budget,
        500,
    );
    gen_len as f64 / st.median_s
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let budget = std::env::var("BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.4);
    let (cfg, prompt_len, gen_len) = if tiny {
        (ModelConfig::test_tiny(), 12usize, 12usize)
    } else {
        (ModelConfig::llama_micro(), 32, 32)
    };
    let mut rng = Rng::new(77);
    let model = Model::random(&cfg, &mut rng);
    let lang = SynthLang::wiki(cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(78));
    let prompt: Vec<u16> =
        (0..prompt_len as u16).map(|i| (i * 7 + 1) % cfg.vocab as u16).collect();
    let dense_bytes = model.resident_weight_bytes();
    let defaults = StageConfig::new(0.25, false);

    // --- 4-bit RTN: the resident-bytes acceptance gate ---
    let plan4 = CompressionPlan::parse("rtn4", &defaults).expect("rtn4 plan");
    let (q4, _) = plan4.run(&model, &calib).expect("rtn4 run");
    let q4_bytes = q4.resident_weight_bytes();
    let ratio = q4_bytes as f64 / dense_bytes as f64;
    println!("resident weight bytes: dense {dense_bytes} | rtn4 packed {q4_bytes} ({ratio:.3}x)");

    // --- packed decode parity vs the fake-quant f32 reference ---
    let reference = q4.dequantize_projections();
    let packed_out = q4.greedy_decode(&prompt, gen_len);
    let reference_out = reference.greedy_decode(&prompt, gen_len);
    let parity = packed_out == reference_out;
    println!(
        "packed decode parity vs fake-quant reference: {}",
        if parity { "token-identical" } else { "DIVERGED" }
    );

    // --- decode throughput: dense vs packed vs dequantized reference ---
    let dense_tok_s = decode_tok_s(&model, &prompt, gen_len, budget);
    let packed_tok_s = decode_tok_s(&q4, &prompt, gen_len, budget);
    let reference_tok_s = decode_tok_s(&reference, &prompt, gen_len, budget);
    println!(
        "decode tok/s ({}): dense {dense_tok_s:.0} | rtn4 packed {packed_tok_s:.0} | \
         dequantized reference {reference_tok_s:.0}",
        cfg.name
    );

    // --- Table 7 composition: factorize then 4-bit GPTQ the factors ---
    let plan_t7 = CompressionPlan::parse("compot@0.25+gptq4", &defaults).expect("t7 plan");
    let (t7, report) = plan_t7.run(&model, &calib).expect("t7 run");
    let t7_bytes = t7.resident_weight_bytes();
    let t7_tok_s = decode_tok_s(&t7, &prompt, gen_len, budget);
    let t7_reference = t7.dequantize_projections();
    let t7_parity =
        t7.greedy_decode(&prompt, gen_len) == t7_reference.greedy_decode(&prompt, gen_len);
    println!(
        "compot@0.25+gptq4: composed CR {:.3} | {t7_bytes} resident bytes ({:.3}x) | \
         {t7_tok_s:.0} tok/s | parity {}",
        report.composed_cr,
        t7_bytes as f64 / dense_bytes as f64,
        if t7_parity { "ok" } else { "DIVERGED" }
    );

    // --- record the trajectory point ---
    let mut j = Json::obj();
    j.set("bench", "quant_decode".into())
        .set("model", cfg.name.as_str().into())
        .set("prompt_len", prompt_len.into())
        .set("gen_len", gen_len.into())
        .set("dense_resident_bytes", dense_bytes.into())
        .set("rtn4_resident_bytes", q4_bytes.into())
        .set("rtn4_bytes_ratio", ratio.into())
        .set("decode_tok_s_dense", dense_tok_s.into())
        .set("decode_tok_s_rtn4_packed", packed_tok_s.into())
        .set("decode_tok_s_dequant_reference", reference_tok_s.into())
        .set("rtn4_parity_vs_reference", Json::Bool(parity))
        .set("t7_composed_cr", report.composed_cr.into())
        .set("t7_resident_bytes", t7_bytes.into())
        .set("decode_tok_s_t7_packed", t7_tok_s.into())
        .set("t7_parity_vs_reference", Json::Bool(t7_parity));
    let out = std::env::var("BENCH_QUANT_OUT").unwrap_or_else(|_| "BENCH_quant.json".into());
    match std::fs::write(&out, j.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    // --- hard gates (after the JSON so CI still records the numbers) ---
    assert!(
        ratio < 0.5,
        "4-bit packed model must be < 0.5x dense resident bytes, got {ratio:.3}"
    );
    assert!(parity, "packed rtn4 decode diverged from the fake-quant f32 reference");
    assert!(t7_parity, "packed compot+gptq4 decode diverged from its reference");
}
