//! Artifact-gated integration tests: exercise the full three-layer stack
//! (JAX/Pallas-built HLO artifacts + pretrained weights → PJRT runtime →
//! Rust pipeline). Every test skips cleanly when `make artifacts` has not
//! been run, so `cargo test` stays green in a fresh checkout.

use compot::compress::compot::{factorize, CompotConfig, DictInit};
use compot::compress::{CalibContext, MethodCall, StageConfig};
use compot::coordinator::pipeline::{calibrate, compress_with};
use compot::data::corpus::corpus_split;
use compot::eval::perplexity::perplexity;
use compot::linalg::Mat;
use compot::model::Model;
use compot::runtime::compot_exec::CompotExec;
use compot::runtime::{artifacts::artifacts_dir, Manifest, PjrtEngine};
use compot::util::json::Json;
use compot::util::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(&artifacts_dir()).ok()
}

fn skip(name: &str) {
    eprintln!("skipping {name}: run `make artifacts` first");
}

#[test]
fn pjrt_loads_and_runs_matmul_demo() {
    let Some(man) = manifest() else { return skip("pjrt_matmul") };
    let Some(entry) = man.by_name("matmul_demo") else { return skip("pjrt_matmul") };
    let engine = PjrtEngine::cpu().unwrap();
    let exe = engine.load(&entry.path).unwrap();
    let mut rng = Rng::new(1);
    let a = Mat::randn(&mut rng, entry.inputs[0].0, entry.inputs[0].1, 1.0);
    let b = Mat::randn(&mut rng, entry.inputs[1].0, entry.inputs[1].1, 1.0);
    let out = engine.run(&exe, &[&a, &b], &entry.outputs).unwrap();
    let expect = compot::linalg::gemm::matmul(&a, &b);
    assert!(
        out[0].rel_err(&expect) < 1e-4,
        "XLA matmul disagrees with Rust GEMM: {}",
        out[0].rel_err(&expect)
    );
}

#[test]
fn pjrt_compot_iter_matches_rust_engine() {
    // The heart of the three-layer story: one alternating iteration through
    // the AOT artifact (Pallas GEMM + hard-threshold + Newton–Schulz) must
    // match the pure-Rust engine (exact Jacobi-SVD Procrustes) closely.
    let Some(man) = manifest() else { return skip("pjrt_compot_iter") };
    let Some(entry) = man.entries.iter().find(|e| e.kind == "compot_iter") else {
        return skip("pjrt_compot_iter");
    };
    let (m, n, k, s) = (entry.m, entry.n, entry.k, entry.s);
    let engine = PjrtEngine::cpu().unwrap();
    let exec = CompotExec { engine: &engine, manifest: &man };

    let mut rng = Rng::new(2);
    let wt = Mat::randn(&mut rng, m, n, 1.0);
    // Same SVD initialization on both sides.
    let decomp = compot::linalg::svd::svd_thin(&wt);
    let d0 = decomp.u.cols_range(0, k);

    let (s_xla, d_xla) = exec.iter_once(&wt, &d0, k, s).unwrap();

    // Rust side: S = H_s(DᵀW̃), M = W̃Sᵀ, D = procrustes(M).
    let z_t = compot::linalg::gemm::matmul(&wt.transpose(), &d0);
    let s_sparse = compot::compress::sparse::ColumnSparse::hard_threshold_zt(&z_t, s);
    let s_rust = s_sparse.to_dense();
    assert!(
        s_xla.rel_err(&s_rust) < 1e-3,
        "sparse codes disagree: {}",
        s_xla.rel_err(&s_rust)
    );
    let mt = s_sparse.mt_product(&wt.transpose());
    let d_rust = compot::linalg::svd::procrustes(&mt.transpose());
    // Newton–Schulz vs Jacobi SVD: same orthogonal factor up to numerics.
    assert!(
        d_xla.rel_err(&d_rust) < 1e-2,
        "Procrustes factors disagree: {}",
        d_xla.rel_err(&d_rust)
    );
    assert!(d_xla.ortho_defect() < 1e-2);
}

#[test]
fn pjrt_full_factorize_reaches_rust_quality() {
    let Some(man) = manifest() else { return skip("pjrt_factorize") };
    let Some(entry) = man.entries.iter().find(|e| e.kind == "compot_iter") else {
        return skip("pjrt_factorize");
    };
    let (m, n, k, s) = (entry.m, entry.n, entry.k, entry.s);
    let engine = PjrtEngine::cpu().unwrap();
    let exec = CompotExec { engine: &engine, manifest: &man };
    let mut rng = Rng::new(3);
    let wt = Mat::randn(&mut rng, m, n, 1.0);

    let (d_x, s_x) = exec.factorize(&wt, k, s, 5).unwrap();
    let err_xla = wt.sub(&s_x.apply_after(&d_x)).fro_norm();

    let cfg = CompotConfig { iters: 5, init: DictInit::Svd, ..Default::default() };
    let res = factorize(&wt, k, s, &cfg, &mut rng);
    let err_rust = wt.sub(&res.s.apply_after(&res.d)).fro_norm();
    assert!(
        (err_xla - err_rust).abs() / err_rust < 0.05,
        "engines reach different quality: xla {err_xla} vs rust {err_rust}"
    );
}

#[test]
fn jax_rust_forward_parity_on_pretrained_model() {
    let dir = artifacts_dir();
    let parity_path = dir.join("parity.json");
    if !parity_path.exists() {
        return skip("parity");
    }
    let j = Json::parse(&std::fs::read_to_string(&parity_path).unwrap()).unwrap();
    let name = j.get("model").and_then(Json::as_str).unwrap();
    let model = Model::load(&dir.join(format!("{name}.bin"))).unwrap();
    let tokens: Vec<u16> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap() as u16)
        .collect();
    let expect: Vec<f64> = j
        .get("logits_last")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    let logits = model.forward(&tokens);
    let last = logits.row(logits.rows() - 1);
    let mut max_err = 0f64;
    let scale = expect.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1.0);
    for (a, b) in last.iter().zip(expect.iter()) {
        max_err = max_err.max((*a as f64 - b).abs());
    }
    assert!(
        max_err / scale < 1e-3,
        "JAX↔Rust forward parity broke: max_err {max_err} (scale {scale})"
    );
}

#[test]
fn pretrained_model_beats_chance_and_compresses() {
    let dir = artifacts_dir();
    let path = dir.join("llama-micro.bin");
    if !path.exists() {
        return skip("pretrained");
    }
    let model = Model::load(&path).unwrap();
    let wiki = corpus_split(&dir, "wiki", model.cfg.vocab, 8, 128, 5);
    let ppl = perplexity(&model, &wiki);
    assert!(
        ppl < 60.0,
        "pretrained model should be far below uniform (256): ppl {ppl}"
    );

    // Compress at CR 0.2 — perplexity should degrade but stay far from
    // chance, and COMPOT should not lose to SVD-LLM (the paper's headline).
    let calib = corpus_split(&dir, "train", model.cfg.vocab, 8, 128, 6);
    let ctx = CalibContext::build(&model, &calib);
    let run = |method: &str| {
        let (m, r) = compress_with(
            &model,
            &ctx,
            &MethodCall::new(method),
            &StageConfig::new(0.2, false),
        )
        .unwrap();
        (perplexity(&m, &wiki), r.model_cr)
    };
    let (ppl_compot, cr1) = run("compot");
    let (ppl_svdllm, cr2) = run("svd-llm");
    assert!(cr1 >= 0.2 - 1e-9 && cr2 >= 0.2 - 1e-9);
    assert!(ppl_compot < 256.0 && ppl_compot > ppl * 0.9);
    assert!(
        ppl_compot < ppl_svdllm * 1.1,
        "COMPOT ({ppl_compot:.1}) should be ≤ SVD-LLM ({ppl_svdllm:.1}) at matched CR"
    );
}

#[test]
fn whitening_stats_are_sane_on_trained_model() {
    let dir = artifacts_dir();
    let path = dir.join("qwen-nano.bin");
    if !path.exists() {
        return skip("whitening_stats");
    }
    let model = Model::load(&path).unwrap();
    let calib = corpus_split(&dir, "train", model.cfg.vocab, 4, 64, 7);
    let cap = calibrate(&model, &calib);
    assert_eq!(cap.stats.len(), model.cfg.n_layers * 7);
    for ((layer, kind), st) in &cap.stats {
        assert!(st.count > 0, "layer {layer} {kind:?}");
        let rms = st.feature_rms();
        assert!(rms.iter().all(|&r| r >= 0.0 && r.is_finite()));
        let wh = compot::compress::whitening::Whitener::from_stats(st);
        let w = Mat::randn(&mut Rng::new(8), st.dim(), 4, 1.0);
        let back = wh.dewhiten(&wh.whiten(&w));
        assert!(back.rel_err(&w) < 0.15, "layer {layer} {kind:?}: {}", back.rel_err(&w));
    }
}

#[test]
fn cpt2_roundtrip_preserves_every_variant_and_decode() {
    // NOT artifact-gated. The acceptance matrix for the checkpoint
    // subsystem: for Dense, LowRank, Factorized, and all three packed
    // quantized variants, save_compressed → load_compressed reproduces
    // bit-identical buffers (LinearWeight equality covers packed code
    // words, f16 scales, and sparse indices) and token-identical KV-cached
    // greedy decode vs the in-memory model — with no compression stage run
    // at load time.
    use compot::coordinator::plan::CompressionPlan;
    use compot::data::SynthLang;
    use compot::model::config::{ModelConfig, ProjKind};
    use compot::model::transformer::Stage;

    let model = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(50));
    let lang = SynthLang::wiki(model.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(51));
    let prompt: Vec<u16> = vec![2, 7, 1, 8, 2, 8];
    let dir = std::env::temp_dir().join("compot_cpt2_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let defaults = StageConfig::new(0.25, false);

    let specs: [Option<&str>; 6] = [
        None, // dense
        Some("svd-llm@0.2"),
        Some("compot@0.25"),
        Some("rtn4"),
        Some("svd-llm@0.2+rtn4"),
        Some("compot@0.25+gptq4"),
    ];
    for (i, spec) in specs.iter().enumerate() {
        let compressed = match spec {
            Some(s) => {
                let plan = CompressionPlan::parse(s, &defaults).unwrap();
                plan.run(&model, &calib).unwrap().0
            }
            None => model.clone(),
        };
        let path = dir.join(format!("case{i}.cpt2"));
        compressed.save_compressed(&path, spec.as_deref()).unwrap();
        let (reloaded, info) = Model::load_checkpoint(&path).unwrap();
        let label = spec.unwrap_or("dense");
        assert_eq!(info.format, "cpt2", "{label}");
        assert_eq!(info.source, "owned", "{label}");
        assert_eq!(info.plan.as_deref(), spec.as_deref(), "{label}");
        // ... and through the zero-copy loader: WeightBuf equality is
        // content equality, so the same assertions hold with the weights
        // living in the file mapping instead of the heap.
        let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
        assert!(minfo.source.starts_with("mmap"), "{label}: {}", minfo.source);
        // bit-identical buffers, variant tags included
        assert_eq!(reloaded.stages.len(), compressed.stages.len(), "{label}");
        for ((sa, sb), sm) in
            compressed.stages.iter().zip(reloaded.stages.iter()).zip(mapped.stages.iter())
        {
            let (Stage::Block(ba), Stage::Block(bb), Stage::Block(bm)) = (sa, sb, sm) else {
                panic!("{label}: stage kind changed");
            };
            for p in ProjKind::DECODER_SET {
                assert_eq!(ba.proj(p), bb.proj(p), "{label}: {p:?} buffers differ");
                assert_eq!(ba.proj(p), bm.proj(p), "{label}: {p:?} mmap buffers differ");
            }
        }
        // equal measured footprint, token-identical KV-cached greedy decode
        assert_eq!(
            reloaded.resident_weight_bytes(),
            compressed.resident_weight_bytes(),
            "{label}"
        );
        assert_eq!(
            reloaded.greedy_decode(&prompt, 10),
            compressed.greedy_decode(&prompt, 10),
            "{label}: reloaded checkpoint decode diverged"
        );
        // the mapped model decodes identically while keeping its weight
        // bytes in the (page-cache-shared) mapping, not the heap
        assert_eq!(
            mapped.greedy_decode(&prompt, 10),
            compressed.greedy_decode(&prompt, 10),
            "{label}: mmap-loaded checkpoint decode diverged"
        );
        // true mmap keeps the weights in shared file-backed pages; the
        // heap-read fallback ("mmap-fallback") honestly reports them as
        // resident private memory instead
        if minfo.source == "mmap" {
            assert!(mapped.weights_mapped(), "{label}");
        }
        assert_eq!(
            mapped.resident_weight_bytes() + mapped.mapped_weight_bytes(),
            reloaded.resident_weight_bytes(),
            "{label}: mapped + resident must add up to the owned footprint"
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn kv_cached_decode_is_bit_identical_for_compressed_plans() {
    // NOT artifact-gated: a random tiny model stands in for trained weights —
    // decode parity is about the execution paths, not model quality. Covers
    // the acceptance matrix: Dense, LowRank (svd-llm), Factorized (compot),
    // and the multi-stage factorize+quantize composition (Table 7 / Eq. 25).
    use compot::coordinator::plan::CompressionPlan;
    use compot::data::SynthLang;
    use compot::model::config::ModelConfig;

    let model = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(42));
    let lang = SynthLang::wiki(model.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(43));
    let prompt: Vec<u16> = vec![2, 7, 1, 8, 2, 8];
    assert_eq!(
        model.greedy_decode(&prompt, 10),
        model.greedy_decode_full(&prompt, 10),
        "dense: KV-cached decode diverged from full forward"
    );
    let defaults = StageConfig::new(0.25, false);
    for spec in ["svd-llm@0.2", "compot@0.25", "compot@0.25+gptq4"] {
        let plan = CompressionPlan::parse(spec, &defaults).unwrap();
        let (compressed, _) = plan.run(&model, &calib).unwrap();
        let cached = compressed.greedy_decode(&prompt, 10);
        let full = compressed.greedy_decode_full(&prompt, 10);
        assert_eq!(cached, full, "{spec}: KV-cached decode diverged from full forward");
        assert_eq!(cached.len(), 10);
        if spec.contains("gptq4") {
            // The quantize stage emits *packed* storage on every projection;
            // the packed decode path must match the fake-quant f32 reference
            // token for token, while actually occupying fewer resident bytes.
            for (_, b) in compressed.blocks() {
                for p in compot::model::config::ProjKind::DECODER_SET {
                    assert!(b.proj(p).is_quantized(), "{spec}: {p:?} left unpacked");
                }
            }
            let reference = compressed.dequantize_projections();
            assert_eq!(
                cached,
                reference.greedy_decode(&prompt, 10),
                "{spec}: packed decode diverged from the fake-quant reference"
            );
            assert!(compressed.resident_weight_bytes() < reference.resident_weight_bytes());
        }
    }
}

#[test]
fn batched_decode_is_bit_identical_across_all_variants() {
    // NOT artifact-gated. The cross-session batched-decode acceptance
    // matrix: for every LinearWeight variant — in-memory, checkpoint
    // owned-reloaded, AND zero-copy mmap-reloaded — one
    // `Model::decode_step_batch` over sessions whose caches sit at
    // heterogeneous positions (mixed prompt lengths) must reproduce each
    // session's solo `decode_step` logits bitwise, and the caches must stay
    // interchangeable with the sequential path afterwards.
    use compot::coordinator::plan::CompressionPlan;
    use compot::data::SynthLang;
    use compot::model::config::ModelConfig;
    use compot::model::KvCache;

    let base = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(70));
    let lang = SynthLang::wiki(base.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(71));
    let defaults = StageConfig::new(0.25, false);
    let dir = std::env::temp_dir().join("compot_batch_integration");
    std::fs::create_dir_all(&dir).unwrap();

    let specs: [Option<&str>; 6] = [
        None, // dense
        Some("svd-llm@0.2"),
        Some("compot@0.25"),
        Some("rtn4"),
        Some("svd-llm@0.2+rtn4"),
        Some("compot@0.25+gptq4"),
    ];
    // mixed prompt lengths → heterogeneous cache positions inside one batch
    let prompts: [&[u16]; 4] = [&[3, 1, 4, 1, 5, 9, 2, 6], &[2, 7], &[1, 8, 2, 8, 1], &[9, 9, 8]];
    let toks: [u16; 4] = [5, 11, 3, 60];
    let check = |m: &Model, label: &str| {
        let prefilled = |p: &&[u16]| {
            let mut c = m.new_cache();
            m.prefill(&mut c, p);
            c
        };
        let mut seq: Vec<KvCache> = prompts.iter().map(prefilled).collect();
        let seq_rows: Vec<Vec<f32>> =
            seq.iter_mut().zip(toks.iter()).map(|(c, &t)| m.decode_step(c, t)).collect();
        let mut bat: Vec<KvCache> = prompts.iter().map(prefilled).collect();
        let mut refs: Vec<&mut KvCache> = bat.iter_mut().collect();
        let logits = m.decode_step_batch(&mut refs, &toks);
        drop(refs);
        for (b, row) in seq_rows.iter().enumerate() {
            for (j, &want) in row.iter().enumerate() {
                assert!(
                    (logits[(b, j)] - want).abs() == 0.0,
                    "{label}: row {b} logit {j}: {} vs {want}",
                    logits[(b, j)]
                );
            }
        }
        for (b, (sc, bc)) in seq.iter_mut().zip(bat.iter_mut()).enumerate() {
            assert_eq!(sc.len(), bc.len(), "{label}: row {b} position");
            let a = m.decode_step(sc, 7);
            let z = m.decode_step(bc, 7);
            assert!(
                a.iter().zip(z.iter()).all(|(x, y)| (x - y).abs() == 0.0),
                "{label}: post-batch step diverged on row {b}"
            );
        }
    };
    for (i, spec) in specs.iter().enumerate() {
        let label = spec.unwrap_or("dense");
        let compressed = match spec {
            Some(s) => {
                CompressionPlan::parse(s, &defaults).unwrap().run(&base, &calib).unwrap().0
            }
            None => base.clone(),
        };
        check(&compressed, label);
        // ...and through both checkpoint load paths: the batched kernel
        // must not care whether the weight buffers live on the heap or in
        // the file mapping.
        let path = dir.join(format!("batch{i}.cpt2"));
        compressed.save_compressed(&path, spec.as_deref()).unwrap();
        let (owned, _) = Model::load_compressed(&path).unwrap();
        let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
        assert!(minfo.source.starts_with("mmap"), "{label}: {}", minfo.source);
        check(&owned, &format!("{label} owned-reload"));
        check(&mapped, &format!("{label} mmap-reload"));
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn speculative_decode_is_token_identical_across_all_variants() {
    // NOT artifact-gated. The speculative-serving acceptance matrix: for
    // draft/target pairs covering all six LinearWeight variants (dense,
    // low-rank, factorized, and their three packed-quantized forms), and
    // for both the owned and the zero-copy (--mmap) load paths, greedy
    // speculative decode must be token-identical to decoding with the
    // target alone. The draft only ever moves the cost, never the output.
    use compot::coordinator::plan::CompressionPlan;
    use compot::data::SynthLang;
    use compot::model::config::ModelConfig;
    use compot::serve::SpeculativeSession;

    let base = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(60));
    let lang = SynthLang::wiki(base.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(61));
    let defaults = StageConfig::new(0.25, false);
    let dir = std::env::temp_dir().join("compot_spec_integration");
    std::fs::create_dir_all(&dir).unwrap();

    // Each spec produces a model exercising specific LinearWeight variants:
    // dense / LowRank / Factorized / QuantDense / QuantLowRank /
    // QuantFactorized. Every one serves as the target with the rtn4
    // artifact drafting, and as the draft under the dense target.
    let specs: [Option<&str>; 6] = [
        None, // dense
        Some("svd-llm@0.2"),
        Some("compot@0.25"),
        Some("rtn4"),
        Some("svd-llm@0.2+rtn4"),
        Some("compot@0.25+gptq4"),
    ];
    let variants: Vec<Model> = specs
        .iter()
        .map(|spec| match spec {
            Some(s) => {
                CompressionPlan::parse(s, &defaults).unwrap().run(&base, &calib).unwrap().0
            }
            None => base.clone(),
        })
        .collect();
    let run = |target: &Model, draft: &Model, prompt: &[u16], k: usize| -> Vec<u16> {
        let mut s = SpeculativeSession::start(target, draft, prompt, 12, k);
        while s.round(target, draft).is_some() {}
        s.generated().to_vec()
    };
    let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9];
    let rtn4 = &variants[3];
    for (i, (spec, target)) in specs.iter().zip(variants.iter()).enumerate() {
        let label = spec.unwrap_or("dense");
        let want = target.greedy_decode(&prompt, 12);

        // 1. the variant as the target, the rtn4 artifact as its draft
        assert_eq!(run(target, rtn4, &prompt, 4), want, "{label} as target");
        // 2. the variant as the draft under the dense target
        let dense_want = variants[0].greedy_decode(&prompt, 12);
        assert_eq!(run(&variants[0], target, &prompt, 3), dense_want, "{label} as draft");

        // 3. both roles again with checkpoint-reloaded copies: the owned
        //    loader as target, the zero-copy mmap loader as draft — parity
        //    must survive both storage paths at once.
        let path = dir.join(format!("spec{i}.cpt2"));
        target.save_compressed(&path, spec.as_deref()).unwrap();
        let (owned, _) = Model::load_compressed(&path).unwrap();
        let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
        assert!(minfo.source.starts_with("mmap"), "{label}: {}", minfo.source);
        assert_eq!(owned.greedy_decode(&prompt, 12), want, "{label}: owned reload");
        assert_eq!(run(&owned, &mapped, &prompt, 4), want, "{label} owned+mmap pair");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
#[cfg(not(miri))]
fn sharded_pipeline_serves_token_identically_across_all_variants() {
    // NOT artifact-gated. The sharded-serving acceptance matrix: for every
    // LinearWeight variant (dense, low-rank, factorized, and their three
    // packed-quantized forms), save a 2-shard CPT2 set, load the head
    // (embed + first stage) and tail (second stage + LM head) as partial
    // models — through the owned loader AND the zero-copy mmap loader —
    // wire a head -> tail pipeline over loopback TCP, and assert the
    // served continuation is token-identical to the in-memory model's
    // greedy decode. Hidden rows cross the relay as f32 bit patterns, so
    // identity here is exact, not approximate.
    use compot::coordinator::plan::CompressionPlan;
    use compot::data::SynthLang;
    use compot::model::config::ModelConfig;
    use compot::serve::{serve_pipeline_head, serve_pipeline_tail, BatchPolicy, Client};
    use std::sync::{mpsc, Arc};

    let base = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(70));
    let lang = SynthLang::wiki(base.cfg.vocab);
    let calib = lang.gen_batch(6, 48, &mut Rng::new(71));
    let defaults = StageConfig::new(0.25, false);
    let dir = std::env::temp_dir().join("compot_pipeline_integration");
    std::fs::create_dir_all(&dir).unwrap();

    let pipeline_tokens = |head: Model, tail: Model, prompt: &[u16], max_new: usize| {
        let (tail_tx, tail_rx) = mpsc::channel();
        let tail_t = std::thread::spawn(move || {
            serve_pipeline_tail(Arc::new(tail), "127.0.0.1:0", |a| tail_tx.send(a).unwrap())
        });
        let next = tail_rx.recv().unwrap().to_string();
        let (head_tx, head_rx) = mpsc::channel();
        let head_t = std::thread::spawn(move || {
            serve_pipeline_head(
                Arc::new(head),
                "127.0.0.1:0",
                &next,
                BatchPolicy::default(),
                Json::obj(),
                |a| head_tx.send(a).unwrap(),
            )
        });
        let mut c = Client::connect(head_rx.recv().unwrap()).unwrap();
        let tokens = c.request(prompt, max_new).unwrap().tokens;
        c.shutdown().unwrap();
        head_t.join().unwrap().unwrap();
        tail_t.join().unwrap().unwrap();
        tokens
    };

    let specs: [Option<&str>; 6] = [
        None, // dense
        Some("svd-llm@0.2"),
        Some("compot@0.25"),
        Some("rtn4"),
        Some("svd-llm@0.2+rtn4"),
        Some("compot@0.25+gptq4"),
    ];
    let prompt: Vec<u16> = vec![5, 3, 8, 1, 6, 2];
    for (i, spec) in specs.iter().enumerate() {
        let label = spec.unwrap_or("dense");
        let compressed = match spec {
            Some(s) => {
                CompressionPlan::parse(s, &defaults).unwrap().run(&base, &calib).unwrap().0
            }
            None => base.clone(),
        };
        let n = compressed.stages.len();
        let split = n / 2;
        let want = compressed.greedy_decode(&prompt, 8);
        let path = dir.join(format!("pipe{i}.cpt2"));
        compressed.save_compressed_sharded(&path, spec.as_deref(), 2).unwrap();
        for mmap in [false, true] {
            let (head, _) = Model::load_stage_range(&path, 0..split, mmap).unwrap();
            let (tail, tinfo) = Model::load_stage_range(&path, split..n, mmap).unwrap();
            if mmap {
                assert!(tinfo.source.starts_with("mmap"), "{label}: {}", tinfo.source);
            }
            assert_eq!(
                pipeline_tokens(head, tail, &prompt, 8),
                want,
                "{label} (mmap={mmap}): pipeline decode diverged from single-host"
            );
        }
        std::fs::remove_file(&path).ok();
        for s in 0..2 {
            std::fs::remove_file(dir.join(format!("pipe{i}.shard{s}.cpt2"))).ok();
        }
    }
}

/// The static-analysis gate, in-process: the repo itself must scan clean
/// under `compot audit` (every unsafe site SAFETY-commented and confined to
/// the linalg buffer modules, no unannotated panic surface on the serve
/// path), and the scanner must keep firing on its violation fixtures —
/// the self-test that guards the gate against silent lexer regressions.
#[test]
#[cfg(not(miri))]
fn repo_is_audit_clean_and_fixtures_fire() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a repo root parent")
        .to_path_buf();
    let report = compot::audit::audit_repo(&root).expect("audit scan");
    assert!(report.files_scanned > 0, "audit scanned nothing");
    let msgs: Vec<String> = report.violations.iter().map(|v| v.to_string()).collect();
    assert!(msgs.is_empty(), "audit violations:\n{}", msgs.join("\n"));
    for site in &report.unsafe_sites {
        assert!(
            site.safety.is_some(),
            "unsafe site without SAFETY comment: {}:{}",
            site.file,
            site.line
        );
        assert!(
            site.file.ends_with("src/linalg/buf.rs"),
            "unsafe outside the allowlist: {}:{}",
            site.file,
            site.line
        );
    }
    let failures = compot::audit::run_fixtures(&root).expect("fixture run");
    assert!(failures.is_empty(), "fixture self-test failed:\n{}", failures.join("\n"));
}
