//! COMPOT — Algorithm 1 of the paper.
//!
//! Factorize the whitened weight `W̃ = Lᵀ·W` as `D_O·S_O` with a
//! column-orthonormal dictionary `D_O ∈ R^{m×k}` (k ≤ m) and column-s-sparse
//! codes `S_O`, by alternating two *closed-form* steps:
//!
//! 1. sparse coding  `S_O ← H_s(D_Oᵀ·W̃)`          (Eq. 9 — exact under
//!    orthonormality; equivalent to OMP but one matmul + top-s),
//! 2. dictionary     `M = W̃·S_Oᵀ = PΛQᵀ ⇒ D_O ← P·Qᵀ` (Eq. 10 — orthogonal
//!    Procrustes via thin SVD).
//!
//! The achieved objective after step 1 has the free closed form
//! `‖W̃ − D_O·S_O‖² = ‖W̃‖² − ‖S_O‖²` (orthonormal D_O and S = H_s(DᵀW̃)),
//! which powers the early-stopping criterion of Appendix A.7 at zero cost.
//!
//! Storage (Eq. 11): `A = L^{-ᵀ}·D_O` dense at 16-bit plus S_O values at
//! 16-bit and a 1-bit position mask.

use super::sparse::ColumnSparse;
use super::whitening::{CalibStats, Whitener};
use super::{factorized_bits, ks_for_cr, CompressedLayer, Compressor, LinearWeight};
use crate::linalg::{gemm, qr, svd, Mat};
use crate::util::Rng;

/// Dictionary initialization strategy (Table 1 / Fig. 3 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DictInit {
    /// Top-k left singular vectors of W̃ (the paper's default — saturates in
    /// ~5× fewer iterations than random, Fig. 3).
    Svd,
    /// Random orthonormalized subset of W̃ columns.
    RandomColumns,
}

#[derive(Clone, Copy, Debug)]
pub struct CompotConfig {
    /// Dictionary-to-sparsity ratio k/s (paper default 2, Table 15).
    pub ks_ratio: f64,
    /// Alternating-minimization iterations T (paper default 20).
    pub iters: usize,
    pub init: DictInit,
    /// Optional relative-MSE early-stop tolerance τ (Appendix A.7 /
    /// Table 14): stop when |err²_{t−1} − err²_t| / err²_{t−1} < τ.
    pub early_stop_tol: Option<f64>,
    /// Use calibration whitening (Eq. 5–8). Disabled = factorize W directly
    /// (ablation; also the behaviour with no calibration data).
    pub whiten: bool,
}

impl Default for CompotConfig {
    fn default() -> Self {
        CompotConfig {
            ks_ratio: 2.0,
            iters: 20,
            init: DictInit::Svd,
            early_stop_tol: None,
            whiten: true,
        }
    }
}

/// The COMPOT compressor (per-matrix; the model-level pipeline lives in
/// `coordinator`).
#[derive(Clone, Debug, Default)]
pub struct Compot {
    pub cfg: CompotConfig,
}

/// Output of the raw factorization loop, including the per-iteration
/// whitened-error trace (drives Fig. 3 and Table 14).
pub struct FactorizeResult {
    pub d: Mat,
    pub s: ColumnSparse,
    /// ‖W̃ − D·S‖_F after each completed iteration.
    pub err_trace: Vec<f64>,
    pub iters_run: usize,
}

/// One alternating-minimization pass over `wt` (the whitened weight).
/// This is the hot path mirrored by the L2/L1 AOT artifact
/// (`compot_iter_*.hlo.txt`) — `runtime::compot_exec` runs the same math
/// through PJRT and the two are cross-checked in integration tests.
pub fn factorize(
    wt: &Mat,
    k: usize,
    s: usize,
    cfg: &CompotConfig,
    rng: &mut Rng,
) -> FactorizeResult {
    let (m, n) = wt.shape();
    assert!(k <= m, "dictionary must be complete/undercomplete (k ≤ m)");
    assert!(s >= 1 && s <= k);

    let mut d = match cfg.init {
        DictInit::Svd => {
            // Top-k left singular basis via the small-side eigendecomposition
            // (see linalg::svd::left_singular_basis — perf pass).
            let kk = k.min(m.min(n));
            let mut u = svd::left_singular_basis(wt, kk);
            if kk < k {
                // Pathological thin case: complete with orthonormal columns.
                let mut full = Mat::zeros(m, k);
                for i in 0..m {
                    full.row_mut(i)[..kk].copy_from_slice(u.row(i));
                }
                let valid: Vec<bool> = (0..k).map(|j| j < kk).collect();
                qr::fill_null_columns(&mut full, &valid);
                u = full;
            }
            u
        }
        DictInit::RandomColumns => {
            // Random permuted subset of W̃ columns, orthonormalized (QR).
            let mut cols: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut cols);
            let mut picked = Mat::zeros(m, k);
            for (jj, &j) in cols.iter().cycle().take(k).enumerate() {
                for i in 0..m {
                    // tiny jitter decorrelates repeated columns when n < k
                    picked[(i, jj)] = wt[(i, j)] + 1e-4 * rng.gauss32();
                }
            }
            qr::complete_basis(&picked)
        }
    };

    let wt_fro_sq = {
        let f = wt.fro_norm();
        f * f
    };
    let wt_t = wt.transpose(); // n×m, reused by both inner products

    let mut err_trace = Vec::with_capacity(cfg.iters);
    let mut s_mat = ColumnSparse::hard_threshold_zt(&gemm::matmul(&wt_t, &d), s);
    let mut prev_err_sq = f64::INFINITY;
    let mut iters_run = 0;

    for t in 0..cfg.iters.max(1) {
        iters_run = t + 1;
        if t > 0 {
            // Sparse coding step: S ← H_s(Dᵀ·W̃) = H_s((W̃ᵀ·D)ᵀ).
            // (W̃ᵀ·D gives z_j contiguous per row; transpose is cheap.)
            let z_t = gemm::matmul(&wt_t, &d); // n×k
            s_mat = ColumnSparse::hard_threshold_zt(&z_t, s);
        }

        // Closed-form objective: ‖W̃ − D·S‖² = ‖W̃‖² − ‖S‖².
        let err_sq = (wt_fro_sq - s_mat.fro_sq()).max(0.0);
        err_trace.push(err_sq.sqrt());

        if let Some(tol) = cfg.early_stop_tol {
            if prev_err_sq.is_finite() && prev_err_sq > 0.0 {
                let rel = (prev_err_sq - err_sq).abs() / prev_err_sq;
                if rel < tol {
                    break;
                }
            }
            prev_err_sq = err_sq;
        }

        if t + 1 == cfg.iters {
            break;
        }
        // Dictionary step: M = W̃·Sᵀ (computed as Mᵀ = S·W̃ᵀ exploiting
        // sparsity), then Procrustes.
        let mt = s_mat.mt_product(&wt_t); // k×m
        d = svd::procrustes(&mt.transpose());
    }

    FactorizeResult { d, s: s_mat, err_trace, iters_run }
}

impl Compressor for Compot {
    fn name(&self) -> &'static str {
        "COMPOT"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let (m, n) = w.shape();
        let (k, s) = ks_for_cr(m, n, target_cr, self.cfg.ks_ratio);
        anyhow::ensure!(
            factorized_bits(m, n, k, s) < (16 * m * n) as u64,
            "factorization not beneficial for {m}x{n} at cr={target_cr}"
        );
        let whitener = if self.cfg.whiten {
            Whitener::from_stats(stats)
        } else {
            Whitener::identity(m)
        };
        let wt = whitener.whiten(w);
        let result = factorize(&wt, k, s, &self.cfg, rng);
        let a = whitener.dewhiten(&result.d);
        let weight = LinearWeight::Factorized { a, s: result.s };
        let mut layer = CompressedLayer::new("COMPOT", w, weight, Some(stats));
        layer.iters_run = result.iters_run;
        Ok(layer)
    }
}

/// Registry entry: `compot` with options `iters`, `ks_ratio`, `init`
/// (svd|rand), `tol` (early stop, Appendix A.7) and `whiten`.
pub fn registry_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "compot",
        aliases: &[],
        about: "COMPOT: whitened orthogonal-dictionary sparse factorization (Alg. 1)",
        defaults: &[],
        build: |o| {
            let mut cfg = CompotConfig::default();
            if let Some(v) = o.get_f64("ks_ratio")? {
                cfg.ks_ratio = v;
            }
            if let Some(v) = o.get_usize("iters")? {
                cfg.iters = v;
            }
            if let Some(v) = o.get_str("init") {
                cfg.init = match v {
                    "svd" => DictInit::Svd,
                    "rand" | "random" => DictInit::RandomColumns,
                    other => anyhow::bail!("unknown init '{other}' (want svd|rand)"),
                };
            }
            if let Some(v) = o.get_f64("tol")? {
                cfg.early_stop_tol = Some(v);
            }
            if let Some(v) = o.get_bool("whiten")? {
                cfg.whiten = v;
            }
            Ok(Box::new(crate::compress::PerMatrix::new("COMPOT", Compot { cfg })))
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::VALUE_BITS;

    fn make_problem(seed: u64, m: usize, n: usize) -> (Mat, CalibStats) {
        let mut rng = Rng::new(seed);
        // Structured weight: low-rank + sparse noise, realistic-ish spectrum.
        let base = gemm::matmul(
            &Mat::randn(&mut rng, m, m / 2, 1.0),
            &Mat::randn(&mut rng, m / 2, n, 1.0),
        )
        .scale(1.0 / (m as f32).sqrt());
        let w = base.add(&Mat::randn(&mut rng, m, n, 0.05));
        let x = Mat::randn(&mut rng, 4 * m, m, 1.0);
        let stats = CalibStats::from_activations(&x);
        (w, stats)
    }

    #[test]
    fn error_trace_is_monotone_nonincreasing() {
        let (w, stats) = make_problem(90, 32, 48);
        let wh = Whitener::from_stats(&stats);
        let wt = wh.whiten(&w);
        let cfg = CompotConfig { iters: 15, init: DictInit::RandomColumns, ..Default::default() };
        let mut rng = Rng::new(1);
        let res = factorize(&wt, 16, 8, &cfg, &mut rng);
        for pair in res.err_trace.windows(2) {
            assert!(
                pair[1] <= pair[0] + 1e-4 * pair[0].abs().max(1e-9),
                "alternating minimization must not increase the objective: {:?}",
                res.err_trace
            );
        }
    }

    #[test]
    fn closed_form_error_matches_direct() {
        let (w, stats) = make_problem(91, 24, 30);
        let wh = Whitener::from_stats(&stats);
        let wt = wh.whiten(&w);
        let mut rng = Rng::new(2);
        let res = factorize(&wt, 12, 6, &CompotConfig::default(), &mut rng);
        let approx = res.s.apply_after(&res.d); // D·S
        let direct = wt.sub(&approx).fro_norm();
        let traced = *res.err_trace.last().unwrap();
        assert!(
            (direct - traced).abs() / direct.max(1e-9) < 1e-2,
            "direct={direct} traced={traced}"
        );
    }

    #[test]
    fn dictionary_stays_orthonormal() {
        let (w, stats) = make_problem(92, 20, 40);
        let wh = Whitener::from_stats(&stats);
        let wt = wh.whiten(&w);
        let mut rng = Rng::new(3);
        for init in [DictInit::Svd, DictInit::RandomColumns] {
            let cfg = CompotConfig { iters: 10, init, ..Default::default() };
            let res = factorize(&wt, 10, 5, &cfg, &mut rng);
            assert!(res.d.ortho_defect() < 1e-3, "{init:?}");
        }
    }

    #[test]
    fn svd_init_beats_random_at_few_iters() {
        // Fig. 3's claim: at a small iteration budget SVD init achieves a
        // lower objective than random init.
        let (w, stats) = make_problem(93, 32, 64);
        let wh = Whitener::from_stats(&stats);
        let wt = wh.whiten(&w);
        let run = |init: DictInit, seed: u64| {
            let cfg = CompotConfig { iters: 3, init, ..Default::default() };
            let mut rng = Rng::new(seed);
            *factorize(&wt, 16, 8, &cfg, &mut rng).err_trace.last().unwrap()
        };
        let svd_err = run(DictInit::Svd, 4);
        // average a few random seeds to dodge luck
        let rand_err = (0..3).map(|i| run(DictInit::RandomColumns, 10 + i)).sum::<f64>() / 3.0;
        assert!(svd_err < rand_err, "svd={svd_err} rand={rand_err}");
    }

    #[test]
    fn compress_respects_storage_budget() {
        let (w, stats) = make_problem(94, 48, 96);
        for &cr in &[0.2, 0.3, 0.4] {
            let mut rng = Rng::new(5);
            let layer = Compot::default().compress(&w, &stats, cr, &mut rng).unwrap();
            assert!(
                layer.cr >= cr - 1e-9,
                "achieved {} < target {cr}",
                layer.cr
            );
            assert_eq!(layer.bits, layer.weight.storage_bits());
            assert!(layer.func_err.unwrap() > 0.0);
        }
    }

    #[test]
    fn higher_cr_means_higher_error() {
        let (w, stats) = make_problem(95, 40, 60);
        let mut errs = Vec::new();
        for &cr in &[0.2, 0.4, 0.6] {
            let mut rng = Rng::new(6);
            let layer = Compot::default().compress(&w, &stats, cr, &mut rng).unwrap();
            errs.push(layer.func_err.unwrap());
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn early_stop_reduces_iterations() {
        let (w, stats) = make_problem(96, 32, 48);
        let wh = Whitener::from_stats(&stats);
        let wt = wh.whiten(&w);
        let mut rng = Rng::new(7);
        let loose = CompotConfig {
            iters: 150,
            early_stop_tol: Some(1e-1),
            init: DictInit::RandomColumns,
            ..Default::default()
        };
        let tight = CompotConfig {
            iters: 150,
            early_stop_tol: Some(1e-4),
            init: DictInit::RandomColumns,
            ..Default::default()
        };
        let r_loose = factorize(&wt, 16, 8, &loose, &mut rng.fork(1));
        let r_tight = factorize(&wt, 16, 8, &tight, &mut rng.fork(1));
        assert!(r_loose.iters_run <= r_tight.iters_run);
        assert!(
            *r_tight.err_trace.last().unwrap() <= *r_loose.err_trace.last().unwrap() + 1e-9
        );
    }

    #[test]
    fn whitening_improves_functional_error() {
        // The whole point of Eq. 4: whitened factorization should achieve a
        // lower functional (calibration) error than whiten=false, when the
        // activation Gram is anisotropic.
        let mut rng = Rng::new(97);
        let m = 32;
        let n = 48;
        let w = Mat::randn(&mut rng, m, n, 1.0);
        // strongly anisotropic activations
        let mut x = Mat::randn(&mut rng, 300, m, 1.0);
        for i in 0..300 {
            for j in 0..m {
                x[(i, j)] *= 1.0 + 4.0 * (j as f32 / m as f32);
            }
        }
        let stats = CalibStats::from_activations(&x);
        let run = |whiten: bool| {
            let c = Compot { cfg: CompotConfig { whiten, iters: 20, ..Default::default() } };
            let mut r = Rng::new(8);
            c.compress(&w, &stats, 0.3, &mut r).unwrap().func_err.unwrap()
        };
        let with = run(true);
        let without = run(false);
        assert!(with < without, "whitened {with} vs raw {without}");
    }

    #[test]
    fn eq11_cr_accounting() {
        let (w, stats) = make_problem(98, 64, 128);
        let mut rng = Rng::new(9);
        let layer = Compot::default().compress(&w, &stats, 0.25, &mut rng).unwrap();
        if let LinearWeight::Factorized { a, s } = &layer.weight {
            let expect = factorized_bits(64, 128, a.cols(), s.s());
            assert_eq!(layer.bits, expect);
            let dense_bits = VALUE_BITS * (64 * 128) as u64;
            assert!((layer.cr - (1.0 - expect as f64 / dense_bits as f64)).abs() < 1e-12);
        } else {
            panic!("COMPOT must produce a Factorized weight");
        }
    }
}
