//! CoSpaDi baseline (Shopkhoev et al., 2025b): calibration-guided sparse
//! dictionary learning with K-SVD dictionary updates and OMP sparse coding.
//!
//! Same whitened objective and same storage format as COMPOT (dense
//! dictionary + column-s-sparse codes, Eq. 11), but *without* the
//! orthogonality constraint — so sparse coding needs an iterative pursuit
//! (OMP) and the dictionary update is per-atom K-SVD. Following the paper's
//! Appendix A.5 we use power iterations (default 8) for the rank-1 K-SVD
//! updates instead of a full SVD. This module exists both as the main
//! quality baseline (Tables 3, 10, 11) and as the wall-clock comparison
//! target (Table 13: COMPOT is 13–29× faster end-to-end).

use super::sparse::ColumnSparse;
use super::whitening::{CalibStats, Whitener};
use super::{factorized_bits, ks_for_cr, CompressedLayer, Compressor, LinearWeight};
use crate::linalg::{matrix::dot64, qr, Mat};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct CospadiConfig {
    pub ks_ratio: f64,
    /// K-SVD iterations (the paper's reference setting is 60; Table 13's
    /// timing extrapolates from 20).
    pub iters: usize,
    /// Power iterations per atom update.
    pub power_iters: usize,
    pub whiten: bool,
}

impl Default for CospadiConfig {
    fn default() -> Self {
        CospadiConfig { ks_ratio: 2.0, iters: 20, power_iters: 8, whiten: true }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Cospadi {
    pub cfg: CospadiConfig,
}

/// Orthogonal Matching Pursuit for one column: greedily select up to `s`
/// atoms, re-solving the least squares on the support each step via the
/// (incrementally grown) normal equations.
pub fn omp_column(dict: &Mat, atom_norms_sq: &[f64], y: &[f32], s: usize) -> Vec<(u32, f32)> {
    let (m, k) = dict.shape();
    debug_assert_eq!(y.len(), m);
    let mut residual: Vec<f32> = y.to_vec();
    let mut support: Vec<usize> = Vec::with_capacity(s);
    let mut coeffs: Vec<f64> = Vec::new();

    for _ in 0..s {
        // Correlations |d_iᵀ r| / ‖d_i‖ over atoms not in the support.
        let mut best = usize::MAX;
        let mut best_score = 0.0f64;
        for i in 0..k {
            if support.contains(&i) || atom_norms_sq[i] < 1e-20 {
                continue;
            }
            let mut corr = 0.0f64;
            for (row, &r) in residual.iter().enumerate() {
                corr += dict[(row, i)] as f64 * r as f64;
            }
            let score = corr.abs() / atom_norms_sq[i].sqrt();
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        if best == usize::MAX || best_score < 1e-12 {
            break;
        }
        support.push(best);

        // Solve min ‖y − D_supp c‖ via normal equations (small t×t system,
        // solved by Gaussian elimination — t ≤ s is tiny).
        let t = support.len();
        let mut gram = vec![0.0f64; t * t];
        let mut rhs = vec![0.0f64; t];
        for a in 0..t {
            let ia = support[a];
            for b in a..t {
                let ib = support[b];
                let mut g = 0.0f64;
                for row in 0..m {
                    g += dict[(row, ia)] as f64 * dict[(row, ib)] as f64;
                }
                gram[a * t + b] = g;
                gram[b * t + a] = g;
            }
            let mut r = 0.0f64;
            for row in 0..m {
                r += dict[(row, ia)] as f64 * y[row] as f64;
            }
            rhs[a] = r;
        }
        coeffs = solve_small(&mut gram, &mut rhs, t);

        // Update residual r = y − D_supp c.
        residual.copy_from_slice(y);
        for (a, &ia) in support.iter().enumerate() {
            let c = coeffs[a] as f32;
            for row in 0..m {
                residual[row] -= c * dict[(row, ia)];
            }
        }
    }

    support
        .iter()
        .zip(coeffs.iter())
        .map(|(&i, &c)| (i as u32, c as f32))
        .collect()
}

/// Gaussian elimination with partial pivoting for the tiny OMP systems.
fn solve_small(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            b.swap(col, piv);
        }
        let diag = a[col * n + col];
        if diag.abs() < 1e-300 {
            continue; // singular; leave zeros
        }
        for row in col + 1..n {
            let f = a[row * n + col] / diag;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                a[row * n + j] -= f * a[col * n + j];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for j in col + 1..n {
            s -= a[col * n + j] * x[j];
        }
        let diag = a[col * n + col];
        x[col] = if diag.abs() < 1e-300 { 0.0 } else { s / diag };
    }
    x
}

/// Full K-SVD factorization loop on the whitened weight.
pub fn ksvd_factorize(
    wt: &Mat,
    k: usize,
    s: usize,
    cfg: &CospadiConfig,
    rng: &mut Rng,
) -> (Mat, ColumnSparse, Vec<f64>) {
    let (m, n) = wt.shape();
    // Init: random orthonormal (keeps atoms well-conditioned at start).
    let mut dict = qr::random_orthonormal(rng, m, k.min(m));
    if k > m {
        // Overcomplete: extend with random unit atoms (CoSpaDi allows this;
        // our default config keeps k ≤ m for storage parity with COMPOT).
        let mut d2 = Mat::zeros(m, k);
        for i in 0..m {
            d2.row_mut(i)[..dict.cols()].copy_from_slice(dict.row(i));
        }
        for j in m..k {
            let mut norm = 0.0f64;
            let col: Vec<f32> = (0..m).map(|_| rng.gauss32()).collect();
            for &v in &col {
                norm += (v as f64) * (v as f64);
            }
            let norm = norm.sqrt() as f32;
            for i in 0..m {
                d2[(i, j)] = col[i] / norm;
            }
        }
        dict = d2;
    }
    let k = dict.cols();

    let wt_t = wt.transpose();
    let mut err_trace = Vec::with_capacity(cfg.iters);
    let mut s_cols: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];

    for _iter in 0..cfg.iters {
        // --- OMP sparse coding, column by column ---
        let atom_norms_sq: Vec<f64> =
            (0..k).map(|i| (0..m).map(|r| (dict[(r, i)] as f64).powi(2)).sum()).collect();
        for j in 0..n {
            s_cols[j] = omp_column(&dict, &atom_norms_sq, wt_t.row(j), s);
        }

        // --- K-SVD atom updates with power iteration ---
        for atom in 0..k {
            // Columns using this atom.
            let users: Vec<usize> = (0..n)
                .filter(|&j| s_cols[j].iter().any(|&(i, _)| i as usize == atom))
                .collect();
            if users.is_empty() {
                continue;
            }
            // Residual restricted to users, excluding this atom's
            // contribution: E[:, j] = w̃_j − Σ_{i≠atom} d_i s_ij.
            let mut e = Mat::zeros(m, users.len());
            for (jj, &j) in users.iter().enumerate() {
                let wcol = wt_t.row(j);
                let mut col: Vec<f32> = wcol.to_vec();
                for &(i, v) in &s_cols[j] {
                    if i as usize == atom {
                        continue;
                    }
                    for row in 0..m {
                        col[row] -= v * dict[(row, i as usize)];
                    }
                }
                for row in 0..m {
                    e[(row, jj)] = col[row];
                }
            }
            // Rank-1 approx of E via power iteration: d ← E·g / ‖·‖.
            let mut g: Vec<f32> = users
                .iter()
                .map(|&j| {
                    s_cols[j]
                        .iter()
                        .find(|&&(i, _)| i as usize == atom)
                        .map(|&(_, v)| v)
                        .unwrap_or(1.0)
                })
                .collect();
            let mut d_new: Vec<f32> = vec![0.0; m];
            for _ in 0..cfg.power_iters {
                // d = E g
                for row in 0..m {
                    let mut acc = 0.0f64;
                    for (jj, &gv) in g.iter().enumerate() {
                        acc += e[(row, jj)] as f64 * gv as f64;
                    }
                    d_new[row] = acc as f32;
                }
                let dn = dot64(&d_new, &d_new).sqrt();
                if dn < 1e-20 {
                    break;
                }
                for v in d_new.iter_mut() {
                    *v = (*v as f64 / dn) as f32;
                }
                // g = Eᵀ d
                for (jj, gv) in g.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for row in 0..m {
                        acc += e[(row, jj)] as f64 * d_new[row] as f64;
                    }
                    *gv = acc as f32;
                }
            }
            // Write back atom and its coefficients.
            for row in 0..m {
                dict[(row, atom)] = d_new[row];
            }
            for (jj, &j) in users.iter().enumerate() {
                for entry in s_cols[j].iter_mut() {
                    if entry.0 as usize == atom {
                        entry.1 = g[jj];
                    }
                }
            }
        }

        // Track objective ‖W̃ − D·S‖_F directly (no closed form without
        // orthogonality — this asymmetry vs COMPOT is part of the cost).
        let s_mat = ColumnSparse::from_columns(k, n, s, s_cols.clone())
            .expect("internal: dictionary S update produced a malformed column list");
        let approx = s_mat.apply_after(&dict);
        err_trace.push(wt.sub(&approx).fro_norm());
    }

    let s_mat = ColumnSparse::from_columns(k, n, s, s_cols)
        .expect("internal: dictionary S update produced a malformed column list");
    (dict, s_mat, err_trace)
}

impl Compressor for Cospadi {
    fn name(&self) -> &'static str {
        "CoSpaDi"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let (m, n) = w.shape();
        let (k, s) = ks_for_cr(m, n, target_cr, self.cfg.ks_ratio);
        anyhow::ensure!(
            factorized_bits(m, n, k, s) < (16 * m * n) as u64,
            "factorization not beneficial for {m}x{n} at cr={target_cr}"
        );
        let whitener = if self.cfg.whiten {
            Whitener::from_stats(stats)
        } else {
            Whitener::identity(m)
        };
        let wt = whitener.whiten(w);
        let (dict, s_mat, trace) = ksvd_factorize(&wt, k, s, &self.cfg, rng);
        let a = whitener.dewhiten(&dict);
        let mut layer = CompressedLayer::new(
            "CoSpaDi",
            w,
            LinearWeight::Factorized { a, s: s_mat },
            Some(stats),
        );
        layer.iters_run = trace.len();
        Ok(layer)
    }
}

/// Registry entry: `cospadi` with options `iters`, `power_iters`,
/// `ks_ratio`, `whiten`.
pub fn registry_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "cospadi",
        aliases: &[],
        about: "CoSpaDi: K-SVD dictionary learning + OMP sparse coding",
        defaults: &[],
        build: |o| {
            let mut cfg = CospadiConfig::default();
            if let Some(v) = o.get_f64("ks_ratio")? {
                cfg.ks_ratio = v;
            }
            if let Some(v) = o.get_usize("iters")? {
                cfg.iters = v;
            }
            if let Some(v) = o.get_usize("power_iters")? {
                cfg.power_iters = v;
            }
            if let Some(v) = o.get_bool("whiten")? {
                cfg.whiten = v;
            }
            Ok(Box::new(crate::compress::PerMatrix::new("CoSpaDi", Cospadi { cfg })))
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omp_exact_recovery_under_orthonormal_dict() {
        // With an orthonormal dictionary, OMP must recover an s-sparse signal
        // exactly (and match hard thresholding — the paper's A.5 equivalence).
        let mut rng = Rng::new(120);
        let dict = qr::random_orthonormal(&mut rng, 16, 16);
        let mut truth = vec![0.0f32; 16];
        truth[3] = 2.0;
        truth[11] = -1.5;
        truth[7] = 0.7;
        // y = D·truth
        let y: Vec<f32> = (0..16)
            .map(|r| (0..16).map(|i| dict[(r, i)] * truth[i]).sum())
            .collect();
        let norms: Vec<f64> = (0..16).map(|_| 1.0).collect();
        let picked = omp_column(&dict, &norms, &y, 3);
        let mut rec = vec![0.0f32; 16];
        for (i, v) in picked {
            rec[i as usize] = v;
        }
        for i in 0..16 {
            assert!((rec[i] - truth[i]).abs() < 1e-4, "i={i}: {} vs {}", rec[i], truth[i]);
        }
    }

    #[test]
    fn ksvd_error_decreases() {
        let mut rng = Rng::new(121);
        let wt = Mat::randn(&mut rng, 16, 32, 1.0);
        let cfg = CospadiConfig { iters: 6, ..Default::default() };
        let (_, _, trace) = ksvd_factorize(&wt, 8, 4, &cfg, &mut rng);
        assert!(trace.len() == 6);
        assert!(
            *trace.last().unwrap() <= trace[0] * 1.001,
            "K-SVD should reduce the objective: {trace:?}"
        );
    }

    #[test]
    fn compress_respects_budget_and_format() {
        let mut rng = Rng::new(122);
        let w = Mat::randn(&mut rng, 24, 48, 1.0);
        let x = Mat::randn(&mut rng, 100, 24, 1.0);
        let stats = CalibStats::from_activations(&x);
        let c = Cospadi { cfg: CospadiConfig { iters: 4, ..Default::default() } };
        let layer = c.compress(&w, &stats, 0.3, &mut rng).unwrap();
        assert!(layer.cr >= 0.3 - 1e-9);
        assert!(matches!(layer.weight, LinearWeight::Factorized { .. }));
    }

    #[test]
    fn identity_product_sanity() {
        let a = Mat::eye(3);
        assert!(crate::linalg::gemm::matmul(&a, &a).rel_err(&a) < 1e-7);
    }
}
