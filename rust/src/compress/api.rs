//! The model-level compression API.
//!
//! Every compression method — per-matrix factorizations, model-level
//! allocators, structural pruning, and quantization — implements one trait,
//! [`ModelCompressor`]: given a model, a [`CalibContext`], and a
//! [`StageConfig`], produce a compressed model plus a [`CompressionReport`].
//! The coordinator no longer dispatches over a closed method enum; methods
//! register themselves by name in the [`crate::compress::registry`] and
//! compose into [`crate::coordinator::plan::CompressionPlan`]s (e.g.
//! factorization followed by PTQ, Table 7 / Eq. 25).
//!
//! Per-matrix methods (anything implementing [`Compressor`]) are lifted to
//! the model level by the generic [`PerMatrix`] adapter, which owns the
//! static/dynamic rank allocation (Algorithm 2) and the layer-parallel
//! compression loop.

use super::whitening::CalibStats;
use super::{CompressedLayer, Compressor, LinearWeight};
use crate::allocator::{allocate_global, AllocationConfig, Grouping, LayerAllocation, MatrixSpec};
use crate::linalg::Mat;
use crate::model::config::ProjKind;
use crate::model::transformer::{Capture, Model, Stage};
use crate::util::parallel::parallel_map;
use crate::util::{Rng, Timer};

/// Everything a compression stage may consume: the pristine model the run
/// started from (composition stages account storage against it), the
/// per-projection activation Grams captured on it, and the raw calibration
/// sequences (structural methods like ReplaceMe re-run partial forwards).
pub struct CalibContext<'a> {
    pub original: &'a Model,
    pub capture: Capture,
    pub seqs: &'a [Vec<u16>],
}

impl<'a> CalibContext<'a> {
    /// Run the calibration forward passes and capture activation statistics.
    pub fn build(model: &'a Model, seqs: &'a [Vec<u16>]) -> CalibContext<'a> {
        let mut capture = Capture::default();
        for s in seqs {
            model.forward_capture(s, &mut capture);
        }
        CalibContext { original: model, capture, seqs }
    }

    /// Wrap an already-computed capture (it must come from `model` over
    /// `seqs`).
    pub fn from_capture(model: &'a Model, capture: Capture, seqs: &'a [Vec<u16>]) -> CalibContext<'a> {
        CalibContext { original: model, capture, seqs }
    }

    /// Calibration statistics for one projection.
    pub fn stats(&self, layer: usize, proj: ProjKind) -> anyhow::Result<&CalibStats> {
        self.capture
            .stats
            .get(&(layer, proj))
            .ok_or_else(|| anyhow::anyhow!("no calibration stats for layer {layer} {proj:?}"))
    }
}

/// Calibration stats are keyed by the *original* model's stage indices and
/// feature dims; methods that consume them must refuse models whose stage
/// list a structural stage (ReplaceMe) has already reshaped, instead of
/// silently whitening with another layer's Gram.
pub(crate) fn ensure_calibration_aligned(
    method: &str,
    model: &Model,
    ctx: &CalibContext<'_>,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        model.stages.len() == ctx.original.stages.len(),
        "{method}: model has {} stages but calibration was captured on {} — \
         put structural stages (replaceme) after calibration-based ones in the plan",
        model.stages.len(),
        ctx.original.stages.len()
    );
    Ok(())
}

/// How per-matrix ratios are chosen for per-matrix methods.
#[derive(Clone, Debug)]
pub enum Allocation {
    /// Uniform target CR on every projection (COMPOT† / Table 3 protocol).
    Static,
    /// Algorithm 2 (pooled SVs) with the given config.
    Dynamic(AllocationConfig),
}

/// Per-stage knobs shared by every method: the storage target, how it is
/// distributed over matrices (per-matrix methods only), and the RNG seed.
#[derive(Clone, Debug)]
pub struct StageConfig {
    pub target_cr: f64,
    pub allocation: Allocation,
    pub seed: u64,
}

impl StageConfig {
    pub fn new(target_cr: f64, dynamic: bool) -> StageConfig {
        let allocation = if dynamic {
            Allocation::Dynamic(AllocationConfig {
                target_cr,
                grouping: Grouping::AllGrouped,
                ..Default::default()
            })
        } else {
            Allocation::Static
        };
        StageConfig { target_cr, allocation, seed: 0xC0DE }
    }

    pub fn with_seed(mut self, seed: u64) -> StageConfig {
        self.seed = seed;
        self
    }

    pub fn is_dynamic(&self) -> bool {
        matches!(self.allocation, Allocation::Dynamic(_))
    }
}

/// Per-projection outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: usize,
    pub proj: ProjKind,
    pub target_cr: f64,
    pub achieved_cr: f64,
    pub func_err: f64,
    pub secs: f64,
    pub dense: bool,
}

impl LayerReport {
    /// Report for one compressed projection.
    pub fn measured(
        layer: usize,
        proj: ProjKind,
        target_cr: f64,
        out: &CompressedLayer,
        secs: f64,
    ) -> LayerReport {
        LayerReport {
            layer,
            proj,
            target_cr,
            achieved_cr: out.cr,
            func_err: out.func_err.unwrap_or(f64::NAN),
            secs,
            dense: false,
        }
    }

    /// Report for a projection the allocator left dense.
    pub fn skipped_dense(layer: usize, proj: ProjKind) -> LayerReport {
        LayerReport {
            layer,
            proj,
            target_cr: 0.0,
            achieved_cr: 0.0,
            func_err: 0.0,
            secs: 0.0,
            dense: true,
        }
    }
}

/// Outcome of one compression stage. `model_cr` is always accounted against
/// the *original* (pre-plan) model so stage reports compose (Eq. 25).
#[derive(Clone, Debug)]
pub struct CompressionReport {
    pub method: String,
    pub per_layer: Vec<LayerReport>,
    /// Model-level CR over the compressible projections.
    pub model_cr: f64,
    pub wall_secs: f64,
}

impl CompressionReport {
    /// Storage-budget check: achieved model CR within `eps` of the target.
    pub fn achieved_cr_ok(&self, target_cr: f64, eps: f64) -> bool {
        self.model_cr >= target_cr - eps
    }
}

/// A model-level compression method: the single dispatch surface of the
/// pipeline. Implementations live next to their math in `compress::*` and
/// register a constructor in [`crate::compress::registry::MethodRegistry`].
pub trait ModelCompressor: Sync {
    /// Display name used in reports and tables.
    fn name(&self) -> String;

    /// Compress `model`. `ctx` carries calibration for the *original* model
    /// of the run; `cfg` the storage target and allocation policy. The
    /// returned report accounts storage against `ctx.original`.
    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)>;
}

/// The (layer, projection, weight) job list of a model.
pub(crate) fn job_list(model: &Model) -> Vec<(usize, ProjKind, Mat)> {
    let mut jobs = Vec::new();
    for (i, b) in model.blocks() {
        for p in ProjKind::DECODER_SET {
            jobs.push((i, p, b.proj(p).to_dense()));
        }
    }
    jobs
}

pub(crate) fn set_proj(model: &mut Model, layer: usize, proj: ProjKind, w: LinearWeight) {
    if let Stage::Block(b) = &mut model.stages[layer] {
        *b.proj_mut(proj) = w;
    }
}

/// Model CR from the per-layer reports: achieved per-matrix CRs weighted by
/// the dense storage of each job (value-level methods like quantization are
/// invisible to the assembled model's `storage_bits`, so reconstruct from
/// the reports).
pub(crate) fn model_cr_from_reports(
    reports: &[LayerReport],
    jobs: &[(usize, ProjKind, Mat)],
) -> f64 {
    let mut used = 0.0f64;
    let mut total = 0.0f64;
    for (r, (_, _, w)) in reports.iter().zip(jobs.iter()) {
        let dense_bits = (16 * w.rows() * w.cols()) as f64;
        total += dense_bits;
        used += (1.0 - r.achieved_cr) * dense_bits;
    }
    if total == 0.0 {
        0.0
    } else {
        1.0 - used / total
    }
}

/// Lifts a per-matrix [`Compressor`] to a [`ModelCompressor`]: allocate
/// per-matrix CRs (uniform or Algorithm 2), compress every (block,
/// projection) job layer-parallel with deterministic per-job RNG streams,
/// and assemble the compressed model.
pub struct PerMatrix<C: Compressor> {
    display: &'static str,
    pub inner: C,
}

impl<C: Compressor> PerMatrix<C> {
    pub fn new(display: &'static str, inner: C) -> PerMatrix<C> {
        PerMatrix { display, inner }
    }
}

fn allocate(jobs: &[(usize, ProjKind, Mat)], cfg: &StageConfig) -> Vec<LayerAllocation> {
    match &cfg.allocation {
        Allocation::Static => jobs
            .iter()
            .map(|_| LayerAllocation { cr: cfg.target_cr, rank: 0, dense: false })
            .collect(),
        Allocation::Dynamic(acfg) => {
            let specs: Vec<MatrixSpec> = parallel_map(jobs.len(), |i| {
                MatrixSpec::from_weight(&jobs[i].2, jobs[i].1.group())
            });
            let mut acfg = *acfg;
            acfg.target_cr = cfg.target_cr;
            allocate_global(&specs, &acfg)
        }
    }
}

impl<C: Compressor> ModelCompressor for PerMatrix<C> {
    fn name(&self) -> String {
        self.display.to_string()
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        ensure_calibration_aligned(self.display, model, ctx)?;
        let jobs = job_list(model);
        let allocs = allocate(&jobs, cfg);
        let results = parallel_map(jobs.len(), |i| {
            let (layer, proj, ref w) = jobs[i];
            let alloc = allocs[i];
            if alloc.dense || alloc.cr <= 0.0 {
                return Ok::<_, String>(None);
            }
            let stats = ctx
                .capture
                .stats
                .get(&(layer, proj))
                .ok_or_else(|| format!("no calibration stats for layer {layer} {proj:?}"))?;
            if stats.dim() != w.rows() {
                return Err(format!(
                    "layer {layer} {proj:?}: calibration dim {} does not match weight rows {} \
                     (was the model structurally changed after calibration?)",
                    stats.dim(),
                    w.rows()
                ));
            }
            let mut rng = Rng::new(cfg.seed ^ ((layer as u64) << 32) ^ proj as u64);
            let t = Timer::start();
            let out = self
                .inner
                .compress(w, stats, alloc.cr, &mut rng)
                .map_err(|e| format!("layer {layer} {proj:?}: {e}"))?;
            Ok(Some((t.secs(), out)))
        });

        let mut compressed = model.clone();
        let mut reports: Vec<LayerReport> = Vec::new();
        for (i, res) in results.into_iter().enumerate() {
            let (layer, proj, _) = jobs[i];
            match res.map_err(|e| anyhow::anyhow!(e))? {
                Some((secs, out)) => {
                    reports.push(LayerReport::measured(layer, proj, allocs[i].cr, &out, secs));
                    set_proj(&mut compressed, layer, proj, out.weight);
                }
                None => reports.push(LayerReport::skipped_dense(layer, proj)),
            }
        }
        let model_cr = model_cr_from_reports(&reports, &jobs);
        Ok((
            compressed,
            CompressionReport {
                method: self.name(),
                per_layer: reports,
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}
