//! Post-training compression methods.
//!
//! Every method consumes a dense projection weight `W ∈ R^{m×n}` (convention:
//! `y = x·W`, rows of `x` are tokens, `m` = input features) plus calibration
//! statistics, and produces a [`CompressedLayer`] — a replacement weight
//! representation together with exact storage accounting (bits) so that all
//! methods are compared under *matched memory*, the paper's protocol.
//!
//! Implemented methods (one module each):
//! - [`compot`] — the paper's contribution (Algorithm 1).
//! - [`svd_llm`] — SVD-LLM: whitened truncation with closed-form update.
//! - [`svd_llm_v2`] — V2 per-group theoretical-loss allocation (App. A.10).
//! - [`svd_baselines`] — plain truncated SVD, FWSVD, ASVD.
//! - [`cospadi`] — CoSpaDi: K-SVD dictionary learning + OMP sparse coding.
//! - [`dobi`] — Dobi-SVD*-style loss-guided rank allocation (+ Eq. 25
//!   remapping accounting).
//! - [`pruning`] — LLM-Pruner-like channel pruning, ReplaceMe-like depth
//!   pruning (model-level, see that module).
//! - [`quant`] — RTN and GPTQ weight quantization, composable with
//!   factorization (Table 7).
//!
//! Model-level orchestration lives in [`api`] (the [`ModelCompressor`] trait
//! and the [`PerMatrix`] adapter) and [`registry`] (string-name →
//! constructor table); every method registers itself there, so adding one is
//! a local change to its own module plus a single registration line.

pub mod api;
pub mod compot;
pub mod cospadi;
pub mod dobi;
pub mod pruning;
pub mod quant;
pub mod registry;
pub mod sparse;
pub mod svd_baselines;
pub mod svd_llm;
pub mod svd_llm_v2;
pub mod whitening;

pub use api::{
    Allocation, CalibContext, CompressionReport, LayerReport, ModelCompressor, PerMatrix,
    StageConfig,
};
pub use registry::{MethodCall, MethodEntry, MethodOptions, MethodRegistry};

use crate::linalg::{gemm, Mat, QuantMat};
use crate::util::Rng;
use sparse::{ColumnSparse, QuantColumnSparse};
use whitening::CalibStats;

/// Bits per stored value for dense fp16 storage (the paper's Eq. 11 baseline).
pub const VALUE_BITS: u64 = 16;

/// A weight in one of the representations the runtime can apply.
///
/// The `Quant*` variants hold b-bit *packed* storage
/// ([`crate::linalg::qmat::QuantMat`]) emitted by the `quant` stage: their
/// `apply`/`apply_row` kernels fuse dequantization into the product while
/// staying bit-identical to applying the dequantized f32 weights, and their
/// `storage_bits` are measured from the actual packed buffers.
#[derive(Clone, Debug, PartialEq)]
pub enum LinearWeight {
    /// Dense m×n.
    Dense(Mat),
    /// Low-rank `W ≈ B·C` with B m×r, C r×n (all SVD-family methods).
    LowRank { b: Mat, c: Mat },
    /// COMPOT/CoSpaDi factorization `W ≈ A·S` with dense A m×k and
    /// column-s-sparse S k×n.
    Factorized { a: Mat, s: ColumnSparse },
    /// b-bit packed dense weight (RTN/GPTQ on a dense projection).
    QuantDense(QuantMat),
    /// Low-rank with both factors b-bit packed (Table 7 on SVD methods).
    QuantLowRank { b: QuantMat, c: QuantMat },
    /// COMPOT/CoSpaDi factorization with packed dictionary and packed
    /// column-aligned sparse values (Table 7 / Eq. 25 realized in storage).
    QuantFactorized { a: QuantMat, s: QuantColumnSparse },
}

impl LinearWeight {
    /// Input feature count m.
    pub fn in_dim(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.rows(),
            LinearWeight::LowRank { b, .. } => b.rows(),
            LinearWeight::Factorized { a, .. } => a.rows(),
            LinearWeight::QuantDense(w) => w.rows(),
            LinearWeight::QuantLowRank { b, .. } => b.rows(),
            LinearWeight::QuantFactorized { a, .. } => a.rows(),
        }
    }

    /// Output feature count n.
    pub fn out_dim(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.cols(),
            LinearWeight::LowRank { c, .. } => c.cols(),
            LinearWeight::Factorized { s, .. } => s.n(),
            LinearWeight::QuantDense(w) => w.cols(),
            LinearWeight::QuantLowRank { c, .. } => c.cols(),
            LinearWeight::QuantFactorized { s, .. } => s.n(),
        }
    }

    /// y = x·W for a batch x (rows = tokens). Quantized variants run fused
    /// dequant GEMM over packed group panels — never a densified weight.
    pub fn apply(&self, x: &Mat) -> Mat {
        match self {
            LinearWeight::Dense(w) => gemm::matmul(x, w),
            LinearWeight::LowRank { b, c } => gemm::matmul(&gemm::matmul(x, b), c),
            LinearWeight::Factorized { a, s } => s.apply_after(&gemm::matmul(x, a)),
            LinearWeight::QuantDense(w) => w.apply(x),
            LinearWeight::QuantLowRank { b, c } => c.apply(&b.apply(x)),
            LinearWeight::QuantFactorized { a, s } => s.apply_after(&a.apply(x)),
        }
    }

    /// Single-token decode step: y = x·W for one activation row, executed
    /// natively in the stored representation — Dense is one mat-vec, LowRank
    /// is two rank-r mat-vecs, Factorized is a mat-vec through the dictionary
    /// followed by the sparse gather, and the quantized variants run the
    /// same shapes as fused dequant matvecs straight off the packed buffers.
    /// No densification, no batch-Mat round-trip; mirrors
    /// [`apply`](Self::apply)'s accumulation order so the KV-cached decode
    /// path stays bit-identical to the batched forward.
    pub fn apply_row(&self, x: &[f32]) -> Vec<f32> {
        match self {
            LinearWeight::Dense(w) => gemm::matvec_row(x, w),
            LinearWeight::LowRank { b, c } => gemm::matvec_row(&gemm::matvec_row(x, b), c),
            LinearWeight::Factorized { a, s } => s.apply_after_row(&gemm::matvec_row(x, a)),
            LinearWeight::QuantDense(w) => w.apply_row(x),
            LinearWeight::QuantLowRank { b, c } => c.apply_row(&b.apply_row(x)),
            LinearWeight::QuantFactorized { a, s } => s.apply_after_row(&a.apply_row(x)),
        }
    }

    /// Single-token decode with int8-quantized activations: the packed
    /// variants run [`QuantMat::apply_row_i8`] (integer inner loop, one
    /// combined f32 scale per group — a small, bounded activation rounding
    /// error, see that method), the 16-bit forms stay exact. Opt-in: the
    /// default decode path remains the exact [`apply_row`](Self::apply_row).
    pub fn apply_row_i8(&self, x: &[f32]) -> Vec<f32> {
        match self {
            LinearWeight::QuantDense(w) => w.apply_row_i8(x),
            LinearWeight::QuantLowRank { b, c } => c.apply_row_i8(&b.apply_row_i8(x)),
            LinearWeight::QuantFactorized { a, s } => s.apply_after_row(&a.apply_row_i8(x)),
            other => other.apply_row(x),
        }
    }

    /// Re-encode every packed factor in `layout` (see
    /// [`QuantMat::with_layout`]); 16-bit forms clone unchanged. Stored
    /// values are identical either way — only the physical code layout (and
    /// thus which unpack kernel serves decode) changes.
    pub fn with_quant_layout(&self, layout: crate::linalg::QuantLayout) -> LinearWeight {
        match self {
            LinearWeight::QuantDense(w) => LinearWeight::QuantDense(w.with_layout(layout)),
            LinearWeight::QuantLowRank { b, c } => LinearWeight::QuantLowRank {
                b: b.with_layout(layout),
                c: c.with_layout(layout),
            },
            LinearWeight::QuantFactorized { a, s } => LinearWeight::QuantFactorized {
                a: a.with_layout(layout),
                s: s.with_layout(layout),
            },
            other => other.clone(),
        }
    }

    /// Materialize the represented Ŵ (tests, error measurement).
    pub fn to_dense(&self) -> Mat {
        match self {
            LinearWeight::Dense(w) => w.clone(),
            LinearWeight::LowRank { b, c } => gemm::matmul(b, c),
            LinearWeight::Factorized { a, s } => s.apply_after(a),
            LinearWeight::QuantDense(w) => w.dequantize(),
            LinearWeight::QuantLowRank { b, c } => gemm::matmul(&b.dequantize(), &c.dequantize()),
            LinearWeight::QuantFactorized { a, s } => s.apply_after(&a.dequantize()),
        }
    }

    /// Packed-quantized variants mapped back to their fake-quant f32 forms
    /// (bit-identical values — the decode-parity reference); everything else
    /// clones unchanged.
    pub fn dequantized(&self) -> LinearWeight {
        match self {
            LinearWeight::QuantDense(w) => LinearWeight::Dense(w.dequantize()),
            LinearWeight::QuantLowRank { b, c } => {
                LinearWeight::LowRank { b: b.dequantize(), c: c.dequantize() }
            }
            LinearWeight::QuantFactorized { a, s } => {
                LinearWeight::Factorized { a: a.dequantize(), s: s.dequantize() }
            }
            other => other.clone(),
        }
    }

    /// Whether this weight is stored b-bit packed.
    pub fn is_quantized(&self) -> bool {
        matches!(
            self,
            LinearWeight::QuantDense(_)
                | LinearWeight::QuantLowRank { .. }
                | LinearWeight::QuantFactorized { .. }
        )
    }

    /// Exact storage bits: Eq. 11 accounting for the 16-bit forms, and
    /// bits *measured from the actual packed buffers* for the quantized
    /// forms (plus the Eq.-11 position mask on quantized sparse factors).
    pub fn storage_bits(&self) -> u64 {
        match self {
            LinearWeight::Dense(w) => VALUE_BITS * (w.rows() * w.cols()) as u64,
            LinearWeight::LowRank { b, c } => {
                VALUE_BITS * (b.rows() * b.cols() + c.rows() * c.cols()) as u64
            }
            LinearWeight::Factorized { a, s } => {
                VALUE_BITS * (a.rows() * a.cols()) as u64 + s.storage_bits()
            }
            LinearWeight::QuantDense(w) => w.storage_bits(),
            LinearWeight::QuantLowRank { b, c } => b.storage_bits() + c.storage_bits(),
            LinearWeight::QuantFactorized { a, s } => a.storage_bits() + s.storage_bits(),
        }
    }

    /// Actual resident heap bytes of the stored buffers: f32 values at 4 B,
    /// packed codes/scales and u32 sparse indices at their real sizes — the
    /// quantity the `quant_decode` benchmark reports. Mapping-aware: a
    /// buffer that is a zero-copy view into a checkpoint mapping counts 0
    /// here (its pages are file-backed and shared) and shows up in
    /// [`mapped_bytes`](Self::mapped_bytes) instead.
    pub fn resident_bytes(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.resident_bytes(),
            LinearWeight::LowRank { b, c } => b.resident_bytes() + c.resident_bytes(),
            LinearWeight::Factorized { a, s } => a.resident_bytes() + s.resident_bytes(),
            LinearWeight::QuantDense(w) => w.resident_bytes(),
            LinearWeight::QuantLowRank { b, c } => b.resident_bytes() + c.resident_bytes(),
            LinearWeight::QuantFactorized { a, s } => a.resident_bytes() + s.resident_bytes(),
        }
    }

    /// Bytes this weight borrows from a checkpoint mapping (0 when owned).
    pub fn mapped_bytes(&self) -> usize {
        match self {
            LinearWeight::Dense(w) => w.mapped_bytes(),
            LinearWeight::LowRank { b, c } => b.mapped_bytes() + c.mapped_bytes(),
            LinearWeight::Factorized { a, s } => a.mapped_bytes() + s.mapped_bytes(),
            LinearWeight::QuantDense(w) => w.mapped_bytes(),
            LinearWeight::QuantLowRank { b, c } => b.mapped_bytes() + c.mapped_bytes(),
            LinearWeight::QuantFactorized { a, s } => a.mapped_bytes() + s.mapped_bytes(),
        }
    }
}

/// Result of compressing one projection matrix.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub weight: LinearWeight,
    /// Storage bits of `weight` (possibly adjusted by quantization).
    pub bits: u64,
    /// Achieved compression ratio: 1 − bits / (16·m·n).
    pub cr: f64,
    /// Whitened functional error ‖Lᵀ(W−Ŵ)‖_F (≡ ‖X(W−Ŵ)‖_F, Eq. 5),
    /// when calibration was available.
    pub func_err: Option<f64>,
    /// Plain weight-space error ‖W−Ŵ‖_F.
    pub weight_err: f64,
    pub method: &'static str,
    /// Alternating-minimization iterations actually run (COMPOT/CoSpaDi).
    pub iters_run: usize,
}

impl CompressedLayer {
    pub fn new(
        method: &'static str,
        original: &Mat,
        weight: LinearWeight,
        stats: Option<&CalibStats>,
    ) -> CompressedLayer {
        let bits = weight.storage_bits();
        let dense_bits = VALUE_BITS * (original.rows() * original.cols()) as u64;
        let approx = weight.to_dense();
        let weight_err = approx.sub(original).fro_norm();
        let func_err = stats.map(|st| st.functional_err(original, &approx));
        CompressedLayer {
            weight,
            bits,
            cr: 1.0 - bits as f64 / dense_bits as f64,
            func_err,
            weight_err,
            method,
            iters_run: 0,
        }
    }
}

/// A per-matrix compression method. `target_cr` is the *per-matrix* ratio
/// (the model-level allocator decides these); methods must not exceed the
/// implied storage budget (achieved `cr >= target_cr`, up to integer
/// rounding of ranks/sparsity — asserted in tests).
pub trait Compressor: Sync {
    fn name(&self) -> &'static str;
    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer>;
}

/// Retained rank for low-rank storage at a target CR (SVD storage model used
/// by Algorithm 2 and all SVD baselines): r·(m+n) ≤ (1−cr)·m·n.
pub fn rank_for_cr(m: usize, n: usize, cr: f64) -> usize {
    let budget = (1.0 - cr) * (m * n) as f64;
    ((budget / (m + n) as f64).floor() as usize).clamp(1, m.min(n))
}

/// Inverse of [`rank_for_cr`]: CR achieved when storing rank r.
pub fn cr_for_rank(m: usize, n: usize, r: usize) -> f64 {
    1.0 - (r * (m + n)) as f64 / (m * n) as f64
}

/// Solve Eq. 11 for (k, s) given a target CR and the dictionary-to-sparsity
/// ratio k/s: minimize quality loss subject to
/// `16·m·k + 16·s·n + k·n ≤ (1−cr)·16·m·n`, with k = ratio·s and k ≤ m
/// (complete/undercomplete constraint; the paper adjusts the ratio only when
/// it would force an overcomplete dictionary).
pub fn ks_for_cr(m: usize, n: usize, cr: f64, ks_ratio: f64) -> (usize, usize) {
    let budget = (1.0 - cr) * (16 * m * n) as f64;
    // bits(s) = 16·m·(ratio·s) + 16·s·n + (ratio·s)·n
    let per_s = 16.0 * m as f64 * ks_ratio + 16.0 * n as f64 + ks_ratio * n as f64;
    let mut s = (budget / per_s).floor() as usize;
    s = s.max(1);
    let mut k = ((s as f64 * ks_ratio).round() as usize).max(s.max(1));
    if k > m {
        // Undercomplete constraint binds: clamp k=m and re-solve for s with
        // the k·n mask and 16·m·k dictionary terms fixed.
        k = m;
        let fixed = 16.0 * (m * k) as f64 + (k * n) as f64;
        let rem = (budget - fixed).max(0.0);
        s = ((rem / (16.0 * n as f64)).floor() as usize).clamp(1, k);
    }
    s = s.min(k);
    (k, s)
}

/// Eq. 11 storage bits for a COMPOT/CoSpaDi factorization.
pub fn factorized_bits(m: usize, n: usize, k: usize, s: usize) -> u64 {
    (16 * m * k + 16 * s * n + k * n) as u64
}

/// Eq. 25: effective model CR when factorization (CR_fact, 16-bit) is
/// followed by b-bit quantization of the stored values.
pub fn composed_cr(cr_fact: f64, bits: u32) -> f64 {
    1.0 - (1.0 - cr_fact) * bits as f64 / 16.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_for_cr_respects_budget() {
        for &(m, n) in &[(64, 64), (128, 512), (512, 128), (7, 1000)] {
            for &cr in &[0.1, 0.2, 0.4, 0.6, 0.8] {
                let r = rank_for_cr(m, n, cr);
                assert!(r >= 1);
                if r > 1 {
                    assert!((r * (m + n)) as f64 <= (1.0 - cr) * (m * n) as f64 + 1e-6);
                }
                assert!(((r + 1) * (m + n)) as f64 > (1.0 - cr) * (m * n) as f64 || r == m.min(n));
            }
        }
    }

    #[test]
    fn ks_for_cr_respects_budget_and_ratio() {
        for &(m, n) in &[(64, 256), (256, 64), (128, 128), (512, 2048)] {
            for &cr in &[0.2, 0.3, 0.4, 0.6] {
                for &ratio in &[1.5, 2.0, 3.0] {
                    let (k, s) = ks_for_cr(m, n, cr, ratio);
                    assert!(k <= m, "overcomplete dictionary");
                    assert!(s >= 1 && s <= k);
                    let bits = factorized_bits(m, n, k, s);
                    assert!(
                        bits as f64 <= (1.0 - cr) * (16 * m * n) as f64 * 1.001,
                        "budget exceeded m={m} n={n} cr={cr} ratio={ratio}: k={k} s={s}"
                    );
                }
            }
        }
    }

    #[test]
    fn ks_ratio_is_approximately_honored() {
        let (k, s) = ks_for_cr(512, 2048, 0.2, 2.0);
        let ratio = k as f64 / s as f64;
        assert!((ratio - 2.0).abs() < 0.25, "k={k} s={s}");
    }

    #[test]
    fn composed_cr_matches_paper_example() {
        // 8-bit quant of an uncompressed model: CR = 0.5.
        assert!((composed_cr(0.0, 8) - 0.5).abs() < 1e-12);
        // Paper's Dobi example: CR_fact = −0.6, 8-bit ⇒ CR_target = 0.2.
        assert!((composed_cr(-0.6, 8) - 0.2).abs() < 1e-12);
        // 4-bit on CR_fact 0.25 ⇒ 1 − 0.75·0.25 = 0.8125.
        assert!((composed_cr(0.25, 4) - 0.8125).abs() < 1e-12);
    }

    #[test]
    fn dense_weight_accounting() {
        let w = Mat::zeros(10, 20);
        let lw = LinearWeight::Dense(w);
        assert_eq!(lw.storage_bits(), 16 * 200);
        assert_eq!(lw.in_dim(), 10);
        assert_eq!(lw.out_dim(), 20);
    }

    #[test]
    fn apply_row_matches_apply_for_every_variant() {
        // Incremental decode correctness hinges on this: the per-token path
        // must agree with the batched path on the same activation row.
        let mut rng = Rng::new(40);
        let (m, n, r, k, s) = (24usize, 36usize, 6usize, 12usize, 5usize);
        let variants = [
            LinearWeight::Dense(Mat::randn(&mut rng, m, n, 1.0)),
            LinearWeight::LowRank {
                b: Mat::randn(&mut rng, m, r, 1.0),
                c: Mat::randn(&mut rng, r, n, 1.0),
            },
            LinearWeight::Factorized {
                a: Mat::randn(&mut rng, m, k, 1.0),
                s: ColumnSparse::hard_threshold(&Mat::randn(&mut rng, k, n, 1.0), s),
            },
            LinearWeight::QuantDense(QuantMat::quantize_from(&Mat::randn(&mut rng, m, n, 1.0), 4)),
            LinearWeight::QuantLowRank {
                b: QuantMat::quantize_from(&Mat::randn(&mut rng, m, r, 1.0), 4),
                c: QuantMat::quantize_from(&Mat::randn(&mut rng, r, n, 1.0), 4),
            },
            LinearWeight::QuantFactorized {
                a: QuantMat::quantize_from(&Mat::randn(&mut rng, m, k, 1.0), 4),
                s: QuantColumnSparse::quantize_from(
                    &ColumnSparse::hard_threshold(&Mat::randn(&mut rng, k, n, 1.0), s),
                    4,
                ),
            },
        ];
        for lw in &variants {
            let x = Mat::randn(&mut rng, 1, m, 1.0);
            let batched = lw.apply(&x);
            let row = lw.apply_row(x.row(0));
            assert_eq!(row.len(), lw.out_dim());
            for j in 0..n {
                assert!(
                    (row[j] - batched[(0, j)]).abs() == 0.0,
                    "{lw:?} col {j}: {} vs {}",
                    row[j],
                    batched[(0, j)]
                );
            }
        }
    }

    #[test]
    fn quantized_weights_measure_packed_storage() {
        let mut rng = Rng::new(41);
        let w = Mat::randn(&mut rng, 64, 256, 1.0);
        let dense = LinearWeight::Dense(w.clone());
        let qd = LinearWeight::QuantDense(QuantMat::quantize_from(&w, 4));
        assert_eq!((qd.in_dim(), qd.out_dim()), (64, 256));
        // 4-bit values + f16 scales ≈ 4.5/16 of the fp16 accounting …
        assert!(qd.storage_bits() * 3 < dense.storage_bits());
        // … and well under half the resident f32 bytes (the bench gate).
        assert!((qd.resident_bytes() as f64) < 0.5 * dense.resident_bytes() as f64);
        // dequantized() maps back to a bit-identical fake-quant dense form
        let fake = qd.dequantized();
        assert!(matches!(fake, LinearWeight::Dense(_)));
        assert_eq!(fake.to_dense(), qd.to_dense());
        assert!(qd.is_quantized() && !fake.is_quantized());
    }

    #[test]
    fn quant_layout_reencode_and_i8_decode_thread_through_variants() {
        use crate::linalg::QuantLayout;
        let mut rng = Rng::new(42);
        let (m, n, r, k, s) = (24usize, 36usize, 6usize, 12usize, 5usize);
        let variants = [
            LinearWeight::QuantDense(QuantMat::quantize_from(&Mat::randn(&mut rng, m, n, 1.0), 4)),
            LinearWeight::QuantLowRank {
                b: QuantMat::quantize_from(&Mat::randn(&mut rng, m, r, 1.0), 4),
                c: QuantMat::quantize_from(&Mat::randn(&mut rng, r, n, 1.0), 4),
            },
            LinearWeight::QuantFactorized {
                a: QuantMat::quantize_from(&Mat::randn(&mut rng, m, k, 1.0), 4),
                s: QuantColumnSparse::quantize_from(
                    &ColumnSparse::hard_threshold(&Mat::randn(&mut rng, k, n, 1.0), s),
                    4,
                ),
            },
            LinearWeight::Dense(Mat::randn(&mut rng, m, n, 1.0)),
        ];
        for lw in &variants {
            let x: Vec<f32> = (0..m).map(|_| rng.gauss32()).collect();
            // layout re-encode: identical values through every consumer
            let legacy = lw.with_quant_layout(QuantLayout::RowSeq);
            assert_eq!(legacy.to_dense(), lw.to_dense(), "{lw:?}");
            let (a, b) = (lw.apply_row(&x), legacy.apply_row(&x));
            for j in 0..a.len() {
                assert!((a[j] - b[j]).abs() == 0.0, "{lw:?} col {j}");
            }
            assert_eq!(
                legacy.with_quant_layout(QuantLayout::Planar).storage_bits(),
                lw.storage_bits(),
                "round-trip restores the planar footprint"
            );
            // i8 decode: exact on 16-bit forms, close on packed forms
            let exact = lw.apply_row(&x);
            let viai8 = lw.apply_row_i8(&x);
            let scale = exact.iter().fold(0.0f32, |acc, &v| acc.max(v.abs())).max(1.0);
            for j in 0..exact.len() {
                if lw.is_quantized() {
                    assert!((viai8[j] - exact[j]).abs() <= 0.1 * scale, "{lw:?} col {j}");
                } else {
                    assert!((viai8[j] - exact[j]).abs() == 0.0, "{lw:?} col {j}");
                }
            }
        }
    }
}
