//! Name-indexed registry of compression methods.
//!
//! Each method module exports a [`MethodEntry`] — a name, aliases, a
//! one-line description, default options, and a constructor from
//! [`MethodOptions`] — and the built-in registry is just the list of those
//! entries ([`MethodRegistry::builtin`]). Adding a method is an edit to its
//! own module plus one registration line there; no coordinator-wide dispatch
//! to extend.
//!
//! Options are stringly-typed `key=value` pairs (CLI `--set k=v`, plan-stage
//! `name,k=v`, or JSON run specs) parsed by each constructor through the
//! typed getters; any key a constructor does not consume is an error, so
//! typos surface instead of silently using defaults.

use super::api::ModelCompressor;
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::OnceLock;

/// `key=value` options for one method invocation, with consumption tracking
/// so unknown keys can be rejected after the constructor runs.
#[derive(Debug, Default)]
pub struct MethodOptions {
    vals: BTreeMap<String, String>,
    consumed: RefCell<BTreeSet<String>>,
}

impl MethodOptions {
    pub fn new() -> MethodOptions {
        MethodOptions::default()
    }

    pub fn set(&mut self, key: &str, val: &str) {
        self.vals.insert(key.to_string(), val.to_string());
    }

    fn raw(&self, key: &str) -> Option<&str> {
        let v = self.vals.get(key)?;
        self.consumed.borrow_mut().insert(key.to_string());
        Some(v.as_str())
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.raw(key)
    }

    pub fn get_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        self.parse(key)
    }

    pub fn get_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.parse(key)
    }

    pub fn get_u32(&self, key: &str) -> anyhow::Result<Option<u32>> {
        self.parse(key)
    }

    pub fn get_bool(&self, key: &str) -> anyhow::Result<Option<bool>> {
        match self.raw(key) {
            None => Ok(None),
            Some("true") | Some("1") | Some("yes") => Ok(Some(true)),
            Some("false") | Some("0") | Some("no") => Ok(Some(false)),
            Some(other) => anyhow::bail!("option '{key}': expected a bool, got '{other}'"),
        }
    }

    fn parse<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| {
                anyhow::anyhow!(
                    "option '{key}': cannot parse '{v}' as {}",
                    std::any::type_name::<T>()
                )
            }),
        }
    }

    /// Keys that were set but never read by the method constructor.
    pub fn unconsumed(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.vals.keys().filter(|k| !consumed.contains(*k)).cloned().collect()
    }
}

/// A method invocation by name: what the CLI, plan specs, and tables build.
#[derive(Clone, Debug, PartialEq)]
pub struct MethodCall {
    pub name: String,
    pub options: Vec<(String, String)>,
}

impl MethodCall {
    pub fn new(name: impl Into<String>) -> MethodCall {
        MethodCall { name: name.into(), options: Vec::new() }
    }

    pub fn with(mut self, key: impl Into<String>, val: impl ToString) -> MethodCall {
        self.options.push((key.into(), val.to_string()));
        self
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("method", self.name.as_str().into());
        if !self.options.is_empty() {
            let mut opts = Json::obj();
            for (k, v) in &self.options {
                opts.set(k, v.as_str().into());
            }
            j.set("options", opts);
        }
        j
    }
}

/// One registered method: everything the registry needs to list it in
/// `compot help` and build it from a [`MethodCall`].
pub struct MethodEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// One-line description for `compot help` / the README method table.
    pub about: &'static str,
    /// Default options applied before the call's own options.
    pub defaults: &'static [(&'static str, &'static str)],
    pub build: fn(&MethodOptions) -> anyhow::Result<Box<dyn ModelCompressor>>,
}

impl MethodEntry {
    fn matches(&self, name: &str) -> bool {
        self.name == name || self.aliases.contains(&name)
    }
}

/// The method name → constructor table. [`MethodRegistry::global`] holds the
/// built-in methods; tests and downstream users can extend their own
/// instance with [`MethodRegistry::register`].
pub struct MethodRegistry {
    entries: Vec<MethodEntry>,
}

impl MethodRegistry {
    /// The built-in methods — one registration line per method, each entry
    /// defined next to its implementation.
    pub fn builtin() -> MethodRegistry {
        let mut reg = MethodRegistry { entries: Vec::new() };
        for entry in [
            super::compot::registry_entry(),
            super::svd_llm::registry_entry(),
            super::svd_llm_v2::registry_entry(),
            super::cospadi::registry_entry(),
            super::dobi::registry_entry(),
            super::svd_baselines::truncated_svd_entry(),
            super::svd_baselines::fwsvd_entry(),
            super::svd_baselines::asvd_entry(),
            super::pruning::llm_pruner_entry(),
            super::pruning::replaceme_entry(),
            super::quant::rtn_entry(),
            super::quant::gptq_entry(),
            super::quant::gptq3_entry(),
        ] {
            reg.register(entry).expect("built-in registry must be collision-free");
        }
        reg
    }

    /// The process-wide built-in registry.
    pub fn global() -> &'static MethodRegistry {
        static REG: OnceLock<MethodRegistry> = OnceLock::new();
        REG.get_or_init(MethodRegistry::builtin)
    }

    /// Register a method. Fails on a name/alias collision.
    pub fn register(&mut self, entry: MethodEntry) -> anyhow::Result<()> {
        let mut names = vec![entry.name];
        names.extend_from_slice(entry.aliases);
        for n in &names {
            anyhow::ensure!(
                !self.entries.iter().any(|e| e.matches(n)),
                "method name '{n}' is already registered"
            );
        }
        self.entries.push(entry);
        Ok(())
    }

    /// Primary names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    pub fn entries(&self) -> &[MethodEntry] {
        &self.entries
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&MethodEntry> {
        self.entries.iter().find(|e| e.matches(name)).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown method '{name}' (available: {})",
                self.names().join(", ")
            )
        })
    }

    /// Build a compressor from a call: entry defaults, overridden by the
    /// call's options; any option the constructor does not understand is an
    /// error.
    pub fn build(&self, call: &MethodCall) -> anyhow::Result<Box<dyn ModelCompressor>> {
        let entry = self.entry(&call.name)?;
        let mut opts = MethodOptions::new();
        for (k, v) in entry.defaults {
            opts.set(k, v);
        }
        for (k, v) in &call.options {
            opts.set(k, v);
        }
        let compressor = (entry.build)(&opts)
            .map_err(|e| anyhow::anyhow!("method '{}': {e}", entry.name))?;
        let extra = opts.unconsumed();
        anyhow::ensure!(
            extra.is_empty(),
            "unknown option(s) [{}] for method '{}'",
            extra.join(", "),
            entry.name
        );
        Ok(compressor)
    }

    /// `name  description` lines for `compot help`.
    pub fn help_table(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            let alias = if e.aliases.is_empty() {
                String::new()
            } else {
                format!(" (alias: {})", e.aliases.join(", "))
            };
            out.push_str(&format!("  {:<12} {}{}\n", e.name, e.about, alias));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quant::Quantize;

    #[test]
    fn options_track_consumption_and_types() {
        let mut o = MethodOptions::new();
        o.set("iters", "7");
        o.set("tol", "1e-3");
        o.set("typo", "1");
        assert_eq!(o.get_usize("iters").unwrap(), Some(7));
        assert_eq!(o.get_f64("tol").unwrap(), Some(1e-3));
        assert_eq!(o.get_usize("missing").unwrap(), None);
        assert_eq!(o.unconsumed(), vec!["typo".to_string()]);
        o.set("flag", "maybe");
        assert!(o.get_bool("flag").is_err());
    }

    #[test]
    fn builtin_names_resolve_and_aliases_work() {
        let reg = MethodRegistry::global();
        for name in reg.names() {
            assert!(reg.build(&MethodCall::new(name)).is_ok(), "cannot build '{name}'");
        }
        // aliases map to the same entries
        assert_eq!(reg.entry("svdllm").unwrap().name, "svd-llm");
        assert_eq!(reg.entry("v2").unwrap().name, "svd-llm-v2");
        assert_eq!(reg.entry("gptq").unwrap().name, "gptq4");
        assert!(reg.entry("nonesuch").is_err());
    }

    #[test]
    fn unknown_options_are_rejected() {
        let reg = MethodRegistry::global();
        let err = reg
            .build(&MethodCall::new("compot").with("itres", 5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("itres"), "{err}");
    }

    #[test]
    fn options_override_entry_defaults() {
        let reg = MethodRegistry::global();
        // gptq4 defaults to 4 bits; --set bits=8 must take precedence (the
        // compressor's display name encodes nothing, so check via build err
        // on an invalid width instead).
        assert!(reg.build(&MethodCall::new("gptq4").with("bits", 8)).is_ok());
        assert!(reg.build(&MethodCall::new("gptq4").with("bits", 99)).is_err());
    }

    #[test]
    fn custom_registration_is_a_single_local_edit() {
        // The acceptance demo: wire up a new named method (8-bit RTN) purely
        // through the registry — no coordinator edits.
        let mut reg = MethodRegistry::builtin();
        reg.register(MethodEntry {
            name: "rtn8",
            aliases: &[],
            about: "8-bit round-to-nearest (custom registration demo)",
            defaults: &[("bits", "8")],
            build: |o| {
                let bits = o.get_u32("bits")?.unwrap_or(8);
                Ok(Box::new(Quantize { bits, gptq: false, ..Default::default() }))
            },
        })
        .unwrap();
        assert!(reg.names().contains(&"rtn8"));
        assert!(reg.build(&MethodCall::new("rtn8")).is_ok());
        // collisions are refused
        assert!(reg
            .register(MethodEntry {
                name: "rtn8",
                aliases: &[],
                about: "",
                defaults: &[],
                build: |_| anyhow::bail!("unused"),
            })
            .is_err());
    }
}
