//! Structured pruning baselines (Table 6):
//!
//! - **LLM-Pruner-like** channel pruning: remove MLP intermediate channels
//!   (and attention heads) by an activation-weighted magnitude importance,
//!   deleting the coupled rows/columns across the projection group.
//! - **ReplaceMe-like** depth pruning: delete a span of transformer blocks
//!   and fit a single linear map on calibration activations (least squares)
//!   to replace them.
//!
//! The matrix-group helpers are wired to actual transformer blocks by the
//! [`LlmPruner`] and [`ReplaceMe`] model compressors below, which run
//! through the same unified `compress_model` path as every other method
//! (ReplaceMe consumes the raw calibration sequences from the
//! [`CalibContext`]).

use super::api::{CalibContext, CompressionReport, LayerReport, ModelCompressor, StageConfig};
use crate::linalg::{cholesky, gemm, solve, Mat};
use crate::model::config::ProjKind;
use crate::model::transformer::{Model, Stage};
use crate::compress::LinearWeight;
use crate::util::Timer;

/// Importance of each MLP intermediate channel c:
/// (‖gate[:,c]‖ + ‖up[:,c]‖) · ‖down[c,:]‖ · act_rms[c].
/// `act_rms` is the calibration RMS of the intermediate activation (pass
/// ones if unavailable).
pub fn mlp_channel_importance(gate: &Mat, up: &Mat, down: &Mat, act_rms: &[f32]) -> Vec<f64> {
    let h = up.cols();
    assert_eq!(gate.cols(), h);
    assert_eq!(down.rows(), h);
    assert_eq!(act_rms.len(), h);
    (0..h)
        .map(|c| {
            let g: f64 = (0..gate.rows()).map(|i| (gate[(i, c)] as f64).powi(2)).sum::<f64>().sqrt();
            let u: f64 = (0..up.rows()).map(|i| (up[(i, c)] as f64).powi(2)).sum::<f64>().sqrt();
            let d: f64 = down.row(c).iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            (g + u) * d * act_rms[c].max(1e-9) as f64
        })
        .collect()
}

/// Keep the `keep` most important channels; returns pruned (gate, up, down)
/// and the kept channel indices (ascending).
pub fn prune_mlp(
    gate: &Mat,
    up: &Mat,
    down: &Mat,
    importance: &[f64],
    keep: usize,
) -> (Mat, Mat, Mat, Vec<usize>) {
    let h = up.cols();
    let keep = keep.clamp(1, h);
    let mut order: Vec<usize> = (0..h).collect();
    order.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
    let mut kept: Vec<usize> = order[..keep].to_vec();
    kept.sort_unstable();

    let mut g2 = Mat::zeros(gate.rows(), keep);
    let mut u2 = Mat::zeros(up.rows(), keep);
    let mut d2 = Mat::zeros(keep, down.cols());
    for (jj, &c) in kept.iter().enumerate() {
        for i in 0..gate.rows() {
            g2[(i, jj)] = gate[(i, c)];
        }
        for i in 0..up.rows() {
            u2[(i, jj)] = up[(i, c)];
        }
        d2.row_mut(jj).copy_from_slice(down.row(c));
    }
    (g2, u2, d2, kept)
}

/// Importance of attention KV-group g (GQA: one K/V head shared by
/// `q_per_kv` query heads): Σ over the group's query heads of
/// ‖q_head‖·‖o_head‖, times ‖k_head‖·‖v_head‖.
pub fn head_group_importance(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    o: &Mat,
    head_dim: usize,
    n_kv: usize,
) -> Vec<f64> {
    let n_q = q.cols() / head_dim;
    let q_per_kv = n_q / n_kv;
    let col_norm = |m: &Mat, c0: usize, c1: usize| -> f64 {
        let mut s = 0.0f64;
        for i in 0..m.rows() {
            for j in c0..c1 {
                s += (m[(i, j)] as f64).powi(2);
            }
        }
        s.sqrt()
    };
    let row_norm = |m: &Mat, r0: usize, r1: usize| -> f64 {
        let mut s = 0.0f64;
        for i in r0..r1 {
            for &x in m.row(i) {
                s += (x as f64).powi(2);
            }
        }
        s.sqrt()
    };
    (0..n_kv)
        .map(|g| {
            let kn = col_norm(k, g * head_dim, (g + 1) * head_dim);
            let vn = col_norm(v, g * head_dim, (g + 1) * head_dim);
            let mut qo = 0.0;
            for hq in g * q_per_kv..(g + 1) * q_per_kv {
                let qn = col_norm(q, hq * head_dim, (hq + 1) * head_dim);
                let on = row_norm(o, hq * head_dim, (hq + 1) * head_dim);
                qo += qn * on;
            }
            qo * (kn + vn)
        })
        .collect()
}

/// Prune attention to `keep_kv` KV groups. Returns (q, k, v, o, kept groups).
pub fn prune_heads(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    o: &Mat,
    head_dim: usize,
    n_kv: usize,
    importance: &[f64],
    keep_kv: usize,
) -> (Mat, Mat, Mat, Mat, Vec<usize>) {
    let n_q = q.cols() / head_dim;
    let q_per_kv = n_q / n_kv;
    let keep_kv = keep_kv.clamp(1, n_kv);
    let mut order: Vec<usize> = (0..n_kv).collect();
    order.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
    let mut kept: Vec<usize> = order[..keep_kv].to_vec();
    kept.sort_unstable();

    let take_cols = |m: &Mat, groups: &[usize], per: usize| -> Mat {
        let mut out = Mat::zeros(m.rows(), groups.len() * per * head_dim);
        for (gg, &g) in groups.iter().enumerate() {
            for j in 0..per * head_dim {
                let src = g * per * head_dim + j;
                let dst = gg * per * head_dim + j;
                for i in 0..m.rows() {
                    out[(i, dst)] = m[(i, src)];
                }
            }
        }
        out
    };
    let take_rows = |m: &Mat, groups: &[usize], per: usize| -> Mat {
        let mut out = Mat::zeros(groups.len() * per * head_dim, m.cols());
        for (gg, &g) in groups.iter().enumerate() {
            for j in 0..per * head_dim {
                out.row_mut(gg * per * head_dim + j)
                    .copy_from_slice(m.row(g * per * head_dim + j));
            }
        }
        out
    };

    let q2 = take_cols(q, &kept, q_per_kv);
    let k2 = take_cols(k, &kept, 1);
    let v2 = take_cols(v, &kept, 1);
    let o2 = take_rows(o, &kept, q_per_kv);
    (q2, k2, v2, o2, kept)
}

/// ReplaceMe's core: fit `T = argmin ‖X_in·T − X_out‖_F` by regularized
/// normal equations — the linear replacement for a deleted block span.
pub fn fit_linear_replacement(x_in: &Mat, x_out: &Mat) -> Mat {
    assert_eq!(x_in.rows(), x_out.rows());
    let d = x_in.cols();
    let mut gram = gemm::matmul_tn(x_in, x_in);
    let mean_diag: f64 = (0..d).map(|i| gram[(i, i)] as f64).sum::<f64>() / d as f64;
    let damp = (1e-4 * mean_diag).max(1e-8) as f32;
    for i in 0..d {
        gram[(i, i)] += damp;
    }
    let rhs = gemm::matmul_tn(x_in, x_out);
    let l = cholesky::cholesky(&gram).expect("damped Gram must be PD");
    // Solve L·Lᵀ·T = rhs.
    let y = solve::solve_lower_left(&l, &rhs);
    solve::solve_lower_transpose_left(&l, &y)
}

/// LLM-Pruner-like structured pruning toward a target CR: prune MLP
/// intermediate channels and attention KV groups uniformly across blocks.
pub struct LlmPruner;

impl ModelCompressor for LlmPruner {
    fn name(&self) -> String {
        "LLM-Pruner".to_string()
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        super::api::ensure_calibration_aligned("LLM-Pruner", model, ctx)?;
        let keep_frac = 1.0 - cfg.target_cr;
        let hd = model.cfg.head_dim();
        let mut compressed = model.clone();
        for layer in 0..compressed.stages.len() {
            let Stage::Block(b) = &compressed.stages[layer] else { continue };
            let gate = b.gate.to_dense();
            let up = b.up.to_dense();
            let down = b.down.to_dense();
            let act_rms = ctx.stats(layer, ProjKind::Down)?.feature_rms();
            anyhow::ensure!(
                act_rms.len() == up.cols(),
                "LLM-Pruner: layer {layer} calibration dim {} != mlp width {}",
                act_rms.len(),
                up.cols()
            );
            let imp = mlp_channel_importance(&gate, &up, &down, &act_rms);
            let keep = ((up.cols() as f64 * keep_frac).round() as usize).clamp(1, up.cols());
            let (g2, u2, d2, _) = prune_mlp(&gate, &up, &down, &imp, keep);

            let q = b.q.to_dense();
            let k = b.k.to_dense();
            let v = b.v.to_dense();
            let o = b.o.to_dense();
            let n_kv = b.n_kv_heads;
            let imp_h = head_group_importance(&q, &k, &v, &o, hd, n_kv);
            let keep_kv = ((n_kv as f64 * keep_frac).round() as usize).clamp(1, n_kv);
            let (q2, k2, v2, o2, kept) = prune_heads(&q, &k, &v, &o, hd, n_kv, &imp_h, keep_kv);
            let q_per_kv = b.n_heads / n_kv;

            if let Stage::Block(b) = &mut compressed.stages[layer] {
                b.gate = LinearWeight::Dense(g2);
                b.up = LinearWeight::Dense(u2);
                b.down = LinearWeight::Dense(d2);
                b.q = LinearWeight::Dense(q2);
                b.k = LinearWeight::Dense(k2);
                b.v = LinearWeight::Dense(v2);
                b.o = LinearWeight::Dense(o2);
                b.n_kv_heads = kept.len();
                b.n_heads = kept.len() * q_per_kv;
            }
        }
        let model_cr =
            1.0 - compressed.projection_bits() as f64 / ctx.original.projection_bits() as f64;
        Ok((
            compressed,
            CompressionReport {
                method: self.name(),
                per_layer: Vec::new(),
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

/// ReplaceMe-like depth pruning: delete the contiguous block span whose
/// removal best fits a linear replacement, sized to the target CR.
/// Calibration activations are re-captured at the span boundaries from the
/// context's raw sequences.
pub struct ReplaceMe;

impl ModelCompressor for ReplaceMe {
    fn name(&self) -> String {
        "ReplaceMe".to_string()
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        anyhow::ensure!(
            !ctx.seqs.is_empty(),
            "ReplaceMe needs calibration sequences in the CalibContext"
        );
        let wall = Timer::start();
        let target_cr = cfg.target_cr;
        let n_blocks = model.stages.len();
        let d = model.cfg.d_model;
        // Parameters of one block vs linear replacement.
        let block_params: usize = ProjKind::DECODER_SET
            .iter()
            .map(|&p| {
                let (m, n) = model.cfg.proj_shape(p);
                m * n
            })
            .sum();
        let total = block_params * n_blocks;
        // drop `span` blocks, add d×d: choose smallest span meeting the target.
        let mut span = 1;
        while span < n_blocks
            && ((span * block_params) as f64 - (d * d) as f64) < target_cr * total as f64
        {
            span += 1;
        }
        anyhow::ensure!(span < n_blocks, "target CR too high for depth pruning");

        // Hidden states entering/leaving each candidate span, over calib data.
        let hd = model.cfg.head_dim();
        let mut best: Option<(usize, f64, Mat)> = None;
        for start in 0..=(n_blocks - span) {
            let mut xs_in: Vec<Mat> = Vec::new();
            let mut xs_out: Vec<Mat> = Vec::new();
            for seq in ctx.seqs {
                let mut x = model.embed_tokens(seq);
                for (i, stage) in model.stages.iter().enumerate() {
                    if i == start {
                        xs_in.push(x.clone());
                    }
                    x = match stage {
                        Stage::Block(b) => b.forward(&x, hd, model.cfg.rope_theta, i, None),
                        Stage::Linear(t) => gemm::matmul(&x, t),
                    };
                    if i == start + span - 1 {
                        xs_out.push(x.clone());
                    }
                }
            }
            let stack = |xs: &[Mat]| {
                let rows: usize = xs.iter().map(|m| m.rows()).sum();
                let mut out = Mat::zeros(rows, d);
                let mut r = 0;
                for m in xs {
                    for i in 0..m.rows() {
                        out.row_mut(r).copy_from_slice(m.row(i));
                        r += 1;
                    }
                }
                out
            };
            let xin = stack(&xs_in);
            let xout = stack(&xs_out);
            let t = fit_linear_replacement(&xin, &xout);
            let err = gemm::matmul(&xin, &t).sub(&xout).fro_norm() / xout.fro_norm().max(1e-30);
            if best.as_ref().map(|(_, e, _)| err < *e).unwrap_or(true) {
                best = Some((start, err, t));
            }
        }
        let (start, err, t) = best.unwrap();

        let mut out = model.clone();
        out.stages.splice(start..start + span, [Stage::Linear(t)]);
        let model_cr =
            1.0 - out.projection_bits() as f64 / ctx.original.projection_bits() as f64;
        Ok((
            out,
            CompressionReport {
                method: self.name(),
                per_layer: vec![LayerReport {
                    layer: start,
                    proj: ProjKind::Q,
                    target_cr,
                    achieved_cr: model_cr,
                    func_err: err,
                    secs: wall.secs(),
                    dense: false,
                }],
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

/// Registry entry: `llm-pruner` (no options).
pub fn llm_pruner_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "llm-pruner",
        aliases: &[],
        about: "LLM-Pruner-like structured channel/KV-head pruning",
        defaults: &[],
        build: |_| Ok(Box::new(LlmPruner)),
    }
}

/// Registry entry: `replaceme` (no options).
pub fn replaceme_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "replaceme",
        aliases: &[],
        about: "ReplaceMe-like depth pruning with a fitted linear replacement",
        defaults: &[],
        build: |_| Ok(Box::new(ReplaceMe)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mlp_prune_removes_least_important() {
        let mut rng = Rng::new(160);
        let d = 8;
        let h = 12;
        let gate = Mat::randn(&mut rng, d, h, 1.0);
        let up = Mat::randn(&mut rng, d, h, 1.0);
        let mut down = Mat::randn(&mut rng, h, d, 1.0);
        // Make channel 5 clearly dead.
        for x in down.row_mut(5) {
            *x = 1e-6;
        }
        let imp = mlp_channel_importance(&gate, &up, &down, &vec![1.0; h]);
        let (g2, u2, d2, kept) = prune_mlp(&gate, &up, &down, &imp, h - 1);
        assert!(!kept.contains(&5));
        assert_eq!(g2.cols(), h - 1);
        assert_eq!(u2.cols(), h - 1);
        assert_eq!(d2.rows(), h - 1);
    }

    #[test]
    fn pruned_mlp_matches_masked_forward() {
        // Pruning then forward == forward with pruned channels zeroed.
        let mut rng = Rng::new(161);
        let d = 6;
        let h = 10;
        let up = Mat::randn(&mut rng, d, h, 1.0);
        let gate = Mat::randn(&mut rng, d, h, 1.0);
        let down = Mat::randn(&mut rng, h, d, 1.0);
        let imp = mlp_channel_importance(&gate, &up, &down, &vec![1.0; h]);
        let keep = 7;
        let (_, u2, d2, kept) = prune_mlp(&gate, &up, &down, &imp, keep);
        let x = Mat::randn(&mut rng, 4, d, 1.0);
        // linear-only check (ignore gating nonlinearity): x·up·down
        let pruned_out = gemm::matmul(&gemm::matmul(&x, &u2), &d2);
        let mut up_masked = up.clone();
        for c in 0..h {
            if !kept.contains(&c) {
                for i in 0..d {
                    up_masked[(i, c)] = 0.0;
                }
            }
        }
        let masked_out = gemm::matmul(&gemm::matmul(&x, &up_masked), &down);
        assert!(pruned_out.rel_err(&masked_out) < 1e-4);
    }

    #[test]
    fn head_prune_shapes_and_selection() {
        let mut rng = Rng::new(162);
        let d = 16;
        let head_dim = 4;
        let n_q = 8;
        let n_kv = 4;
        let q = Mat::randn(&mut rng, d, n_q * head_dim, 1.0);
        let mut k = Mat::randn(&mut rng, d, n_kv * head_dim, 1.0);
        let v = Mat::randn(&mut rng, d, n_kv * head_dim, 1.0);
        let o = Mat::randn(&mut rng, n_q * head_dim, d, 1.0);
        // Deaden KV group 2.
        for i in 0..d {
            for j in 2 * head_dim..3 * head_dim {
                k[(i, j)] = 1e-6;
            }
        }
        let imp = head_group_importance(&q, &k, &v, &o, head_dim, n_kv);
        let (q2, k2, v2, o2, kept) = prune_heads(&q, &k, &v, &o, head_dim, n_kv, &imp, 3);
        assert!(!kept.contains(&2));
        assert_eq!(q2.cols(), 6 * head_dim);
        assert_eq!(k2.cols(), 3 * head_dim);
        assert_eq!(v2.cols(), 3 * head_dim);
        assert_eq!(o2.rows(), 6 * head_dim);
    }

    #[test]
    fn linear_replacement_fits_linear_map() {
        let mut rng = Rng::new(163);
        let d = 10;
        let t_true = Mat::randn(&mut rng, d, d, 1.0);
        let x = Mat::randn(&mut rng, 200, d, 1.0);
        let y = gemm::matmul(&x, &t_true);
        let t_fit = fit_linear_replacement(&x, &y);
        assert!(t_fit.rel_err(&t_true) < 1e-2);
    }

    #[test]
    fn linear_replacement_is_least_squares_optimal() {
        let mut rng = Rng::new(164);
        let d = 8;
        let x = Mat::randn(&mut rng, 100, d, 1.0);
        // Nonlinear target — fit can't be exact, but must beat perturbations.
        let mut y = gemm::matmul(&x, &Mat::randn(&mut rng, d, d, 1.0));
        for i in 0..y.rows() {
            for j in 0..d {
                let v = y[(i, j)];
                y[(i, j)] = v.tanh();
            }
        }
        let t = fit_linear_replacement(&x, &y);
        let base = gemm::matmul(&x, &t).sub(&y).fro_norm();
        for s in 0..5 {
            let tp = t.add(&Mat::randn(&mut Rng::new(200 + s), d, d, 0.01));
            let perturbed = gemm::matmul(&x, &tp).sub(&y).fro_norm();
            assert!(base <= perturbed + 1e-6);
        }
    }
}
