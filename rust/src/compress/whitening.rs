//! Calibration statistics and data-aware whitening (Eq. 4–6).
//!
//! [`CalibStats`] accumulates the Gram `G = XᵀX` (plus the per-feature
//! second moments used by the FWSVD/ASVD baselines) over calibration
//! batches. [`Whitener`] turns the Gram into the whitening map:
//! `G = L·Lᵀ` (Cholesky, with jitter retries) so `W̃ = Lᵀ·W`, and the
//! dewhitening map `A = L^{-ᵀ}·D`. When even jittered Cholesky fails —
//! the ill-conditioned case the paper's §5 discusses — we fall back to
//! an eigendecomposition square root `L = U·diag(√max(λ, ε·λ₁))`.

use crate::linalg::{cholesky, eigh, gemm, solve, Mat};

/// Accumulated activation statistics for one projection's input.
#[derive(Clone, Debug)]
pub struct CalibStats {
    gram: Mat,
    /// Number of calibration rows (tokens) accumulated.
    pub count: usize,
}

impl CalibStats {
    pub fn new(dim: usize) -> CalibStats {
        CalibStats { gram: Mat::zeros(dim, dim), count: 0 }
    }

    /// Build directly from a calibration activation matrix X (rows=tokens).
    pub fn from_activations(x: &Mat) -> CalibStats {
        let mut st = CalibStats::new(x.cols());
        st.accumulate(x);
        st
    }

    /// G += XᵀX for a batch of activations.
    pub fn accumulate(&mut self, x: &Mat) {
        assert_eq!(x.cols(), self.gram.rows(), "accumulate: feature dim");
        let gx = gemm::matmul_tn(x, x);
        self.gram = self.gram.add(&gx);
        self.count += x.rows();
    }

    pub fn dim(&self) -> usize {
        self.gram.rows()
    }

    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// Per-input-feature RMS activation — ASVD's scaling signal and our
    /// Fisher-diagonal proxy for FWSVD (diag of G / count, sqrt).
    pub fn feature_rms(&self) -> Vec<f32> {
        let n = self.count.max(1) as f64;
        (0..self.dim())
            .map(|i| ((self.gram[(i, i)] as f64 / n).max(0.0)).sqrt() as f32)
            .collect()
    }

    /// ‖X(W−Ŵ)‖_F via the Gram identity (Eq. 5) — no need to keep X.
    pub fn functional_err(&self, w: &Mat, w_hat: &Mat) -> f64 {
        let d = w.sub(w_hat);
        // Tr(Dᵀ G D) computed as ‖?‖: use G·D then row-dot.
        let gd = gemm::matmul(&self.gram, &d);
        let mut acc = 0.0f64;
        for i in 0..d.rows() {
            acc += crate::linalg::matrix::dot64(d.row(i), gd.row(i));
        }
        acc.max(0.0).sqrt()
    }
}

/// Which factorization produced the whitening map (diagnostics/tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WhitenKind {
    Cholesky,
    EighFallback,
    /// No calibration (identity whitening) — degenerates COMPOT to plain
    /// weight-space factorization.
    Identity,
}

/// The whitening transform built from a Gram matrix.
#[derive(Clone, Debug)]
pub struct Whitener {
    /// Lower-triangular-ish factor with L·Lᵀ ≈ G. Only triangular for the
    /// Cholesky path; the eigh fallback produces a general square factor,
    /// handled through explicit inverse application.
    l: Mat,
    /// Cached L^{-1} for the eigh path (cheap: computed once per layer).
    l_inv_t: Option<Mat>,
    pub kind: WhitenKind,
}

impl Whitener {
    pub fn identity(dim: usize) -> Whitener {
        Whitener { l: Mat::eye(dim), l_inv_t: None, kind: WhitenKind::Identity }
    }

    pub fn from_stats(stats: &CalibStats) -> Whitener {
        match cholesky(stats.gram()) {
            Ok(l) => Whitener { l, l_inv_t: None, kind: WhitenKind::Cholesky },
            Err(_) => {
                // Eigendecomposition square root with eigenvalue floor.
                let (vals, vecs) = eigh(stats.gram());
                let lmax = vals.first().copied().unwrap_or(1.0).max(1e-30);
                let floor = lmax * 1e-10;
                let n = stats.dim();
                let mut l = vecs.clone();
                let mut inv = vecs.clone();
                for j in 0..n {
                    let sq = vals[j].max(floor).sqrt();
                    for i in 0..n {
                        l[(i, j)] *= sq as f32;
                        inv[(i, j)] /= sq as f32;
                    }
                }
                // L = U√Λ ⇒ L^{-ᵀ} = U·Λ^{-1/2} = inv (since U orthogonal).
                Whitener { l, l_inv_t: Some(inv), kind: WhitenKind::EighFallback }
            }
        }
    }

    /// W̃ = Lᵀ·W.
    pub fn whiten(&self, w: &Mat) -> Mat {
        gemm::matmul_tn(&self.l, w)
    }

    /// A = L^{-ᵀ}·D (Eq. 8 dewhitening).
    pub fn dewhiten(&self, d: &Mat) -> Mat {
        match (&self.l_inv_t, self.kind) {
            (Some(inv), _) => gemm::matmul(inv, d),
            (None, WhitenKind::Identity) => d.clone(),
            _ => solve::solve_lower_transpose_left(&self.l, d),
        }
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gram_identity_for_functional_error() {
        // ‖X(W−Ŵ)‖_F computed directly vs through the Gram.
        let mut rng = Rng::new(80);
        let x = Mat::randn(&mut rng, 200, 12, 1.0);
        let w = Mat::randn(&mut rng, 12, 8, 1.0);
        let w_hat = w.add(&Mat::randn(&mut rng, 12, 8, 0.1));
        let stats = CalibStats::from_activations(&x);
        let via_gram = stats.functional_err(&w, &w_hat);
        let direct = gemm::matmul(&x, &w.sub(&w_hat)).fro_norm();
        assert!((via_gram - direct).abs() / direct < 1e-3);
    }

    #[test]
    fn whitened_error_equals_functional_error() {
        // Eq. 5: ‖Lᵀ(W−Ŵ)‖_F = ‖X(W−Ŵ)‖_F.
        let mut rng = Rng::new(81);
        let x = Mat::randn(&mut rng, 300, 10, 1.0);
        let stats = CalibStats::from_activations(&x);
        let wh = Whitener::from_stats(&stats);
        assert_eq!(wh.kind, WhitenKind::Cholesky);
        let w = Mat::randn(&mut rng, 10, 6, 1.0);
        let w_hat = w.add(&Mat::randn(&mut rng, 10, 6, 0.05));
        let whitened = wh.whiten(&w).sub(&wh.whiten(&w_hat)).fro_norm();
        let functional = gemm::matmul(&x, &w.sub(&w_hat)).fro_norm();
        assert!((whitened - functional).abs() / functional < 1e-3);
    }

    #[test]
    fn dewhiten_inverts_whiten() {
        let mut rng = Rng::new(82);
        let x = Mat::randn(&mut rng, 150, 14, 1.0);
        let wh = Whitener::from_stats(&CalibStats::from_activations(&x));
        let w = Mat::randn(&mut rng, 14, 9, 1.0);
        let back = wh.dewhiten(&wh.whiten(&w));
        assert!(back.rel_err(&w) < 1e-3);
    }

    #[test]
    fn eigh_fallback_on_degenerate_gram() {
        // Exactly singular Gram with huge dynamic range defeats jittered
        // Cholesky only in extreme cases; force the fallback by constructing
        // a Gram with a negative eigenvalue from numerical asymmetry — use a
        // tiny rank-1 Gram scaled to underflow the jitter.
        let mut g = Mat::zeros(6, 6);
        g[(0, 0)] = 1e30;
        // leave the rest zero: not PD, jitter relative to mean diag (1.7e29)
        // makes the remaining pivots positive, so Cholesky may still pass.
        // Directly exercise the eigh path instead:
        let stats = CalibStats { gram: g, count: 1 };
        let (vals, _) = eigh(stats.gram());
        assert!(vals[0] > 0.0);
        let wh = Whitener::from_stats(&stats);
        // whichever path: L·Lᵀ must approximate G on its range
        let llt = gemm::matmul_nt(wh.l(), wh.l());
        assert!((llt[(0, 0)] as f64 - 1e30).abs() / 1e30 < 1e-3);
    }

    #[test]
    fn accumulate_matches_batched() {
        let mut rng = Rng::new(83);
        let x1 = Mat::randn(&mut rng, 50, 8, 1.0);
        let x2 = Mat::randn(&mut rng, 70, 8, 1.0);
        let mut st = CalibStats::new(8);
        st.accumulate(&x1);
        st.accumulate(&x2);
        // Stack manually
        let mut all = Mat::zeros(120, 8);
        for i in 0..50 {
            all.row_mut(i).copy_from_slice(x1.row(i));
        }
        for i in 0..70 {
            all.row_mut(50 + i).copy_from_slice(x2.row(i));
        }
        let st2 = CalibStats::from_activations(&all);
        assert!(st.gram().rel_err(st2.gram()) < 1e-4);
        assert_eq!(st.count, 120);
    }

    #[test]
    fn feature_rms_is_positive_and_scaled() {
        let mut rng = Rng::new(84);
        let mut x = Mat::randn(&mut rng, 400, 4, 1.0);
        for i in 0..400 {
            x[(i, 2)] *= 5.0; // inflate feature 2
        }
        let st = CalibStats::from_activations(&x);
        let rms = st.feature_rms();
        assert!(rms[2] > 3.0 * rms[0]);
        assert!(rms.iter().all(|&r| r > 0.0));
    }
}
