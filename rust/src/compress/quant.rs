//! Post-training weight quantization: RTN (round-to-nearest) and GPTQ
//! (Frantar et al., 2023), composable with factorization (Table 7).
//!
//! GPTQ quantizes the weight one input-row at a time, compensating the
//! rounding error on the not-yet-quantized rows using the inverse Hessian
//! `H = 2·XᵀX + λI` (here: the calibration Gram). We implement the classic
//! Cholesky formulation.
//!
//! **Storage.** For 2..=8 bits the stage emits *packed* storage
//! ([`QuantMat`] / [`QuantColumnSparse`] inside the `Quant*`
//! [`LinearWeight`] variants): b-bit codes in `u32` words plus f16 group
//! scales, with `bits` **measured from the actual packed buffers** — the
//! Eq.-25 formula (`b·count + 16·⌈count/128⌉`) is kept as a cross-check
//! floor. Packing shares one arithmetic core with the fake-quant path
//! (`linalg::qmat`), so dequantized packed values are bit-identical to the
//! fake-quantized f32 values and every error/CR measurement keeps its
//! meaning. Widths above 8 bits keep the legacy fake-quantized (dense f32)
//! representation with formula accounting.
//!
//! Quantization groups are **column-aligned** on sparse factors (one
//! column's outlier cannot poison its neighbors' scales) and row-aligned on
//! dense/low-rank factors; clamping is symmetric (`[-qmax, qmax]`), so a
//! dequantized value never overshoots its group's amax by a step.

use super::api::{
    self, CalibContext, CompressionReport, LayerReport, ModelCompressor, StageConfig,
};
use super::sparse::{ColumnSparse, QuantColumnSparse};
use super::whitening::CalibStats;
use super::{CompressedLayer, LinearWeight};
use crate::linalg::qmat::{self, QuantMat};
use crate::linalg::{cholesky, gemm, solve, Mat};
use crate::model::config::ProjKind;
use crate::model::transformer::{Model, Stage};

pub use crate::linalg::qmat::{supported_group, GROUP};

/// Per-group symmetric quantization of a value slice (fake-quant form).
/// Shares the packed path's arithmetic core — see `linalg::qmat`.
fn quantize_group(vals: &mut [f32], bits: u32) {
    qmat::fake_quantize_group(vals, bits);
}

/// Eq.-25-style formula bits for `count` values at b bits + one 16-bit
/// scale per flat group of the default [`GROUP`]. For packed storage this
/// is a *floor*: the measured size adds word padding and per-row/column
/// group alignment.
pub fn quant_bits(count: usize, bits: u32) -> u64 {
    quant_bits_grouped(count, bits, GROUP)
}

/// [`quant_bits`] at an explicit group size.
pub fn quant_bits_grouped(count: usize, bits: u32, group: usize) -> u64 {
    (count as u64) * bits as u64 + (count.div_ceil(group) as u64) * 16
}

/// RTN: per-row groups of [`GROUP`] along the output dim (fake-quant f32).
pub fn rtn_quantize(w: &Mat, bits: u32) -> Mat {
    rtn_quantize_grouped(w, bits, GROUP)
}

/// RTN with an explicit group size (the 64/128/256 sweep).
pub fn rtn_quantize_grouped(w: &Mat, bits: u32, group: usize) -> Mat {
    let mut q = w.clone();
    for i in 0..q.rows() {
        let row = q.row_mut(i);
        let cols = row.len();
        for g in (0..cols).step_by(group) {
            let end = (g + group).min(cols);
            quantize_group(&mut row[g..end], bits);
        }
    }
    q
}

/// RTN straight into packed storage; `dequantize()` of the result is
/// bit-identical to [`rtn_quantize`].
pub fn rtn_quantize_packed(w: &Mat, bits: u32) -> QuantMat {
    QuantMat::quantize_from(w, bits)
}

/// GPTQ over the input dimension (rows of W, convention y = x·W, H = Gram of
/// x). Processes rows in natural order with full error compensation:
/// after quantizing row i, the remaining rows absorb `−e·H⁻¹[i, j]/H⁻¹[i,i]`.
/// Returns the fake-quantized matrix plus, for packable widths, the same
/// values in packed storage (bit-identical on dequantization).
fn gptq_core(w: &Mat, stats: &CalibStats, bits: u32, group: usize) -> (Mat, Option<QuantMat>) {
    let m = w.rows();
    assert_eq!(stats.dim(), m, "gptq: Hessian dim must match input dim");
    // H = 2G + λI (damping 1% of mean diagonal, GPTQ's default style).
    let mut h = stats.gram().scale(2.0);
    let mean_diag: f64 = (0..m).map(|i| h[(i, i)] as f64).sum::<f64>() / m as f64;
    let damp = (0.01 * mean_diag).max(1e-8) as f32;
    for i in 0..m {
        h[(i, i)] += damp;
    }
    // Hinv via Cholesky: H = LLᵀ ⇒ H⁻¹ = L⁻ᵀ·L⁻¹.
    let l = cholesky::cholesky(&h).expect("damped Hessian must be PD");
    let linv = solve::solve_lower_left(&l, &Mat::eye(m)); // L⁻¹
    let hinv = gemm::matmul_tn(&linv, &linv); // L⁻ᵀL⁻¹

    let mut work = w.clone();
    let mut out = Mat::zeros(w.rows(), w.cols());
    let n = w.cols();
    let pack = QuantMat::supported_bits(bits);
    let mut codes: Vec<u16> = if pack { vec![0; m * n] } else { Vec::new() };
    let mut scales: Vec<u16> = Vec::with_capacity(if pack { m * n.div_ceil(group) } else { 0 });
    let mut gcodes = vec![0u16; group];

    // Per-(row-slice) group scales, computed on the *current* (compensated)
    // values as in the reference implementation.
    for i in 0..m {
        // Quantize row i in groups through the shared packed/fake core.
        let mut qrow = work.row(i).to_vec();
        for g in (0..n).step_by(group) {
            let end = (g + group).min(n);
            let sbits =
                qmat::quantize_group_inplace(&mut qrow[g..end], bits, &mut gcodes[..end - g]);
            if pack {
                codes[i * n + g..i * n + end].copy_from_slice(&gcodes[..end - g]);
                scales.push(sbits);
            }
        }
        let dii = hinv[(i, i)].max(1e-12);
        // Error on row i.
        let err: Vec<f32> = work
            .row(i)
            .iter()
            .zip(qrow.iter())
            .map(|(&orig, &q)| (orig - q) / dii)
            .collect();
        out.row_mut(i).copy_from_slice(&qrow);
        // Compensate remaining rows: W[j,:] −= Hinv[j,i]·err.
        for j in i + 1..m {
            let f = hinv[(j, i)];
            if f == 0.0 {
                continue;
            }
            let row = work.row_mut(j);
            for (x, e) in row.iter_mut().zip(err.iter()) {
                *x -= f * e;
            }
        }
    }
    let packed = pack.then(|| {
        QuantMat::from_codes_grouped(m, n, bits, group, &codes, scales)
            .expect("gptq_core builds matching codes/scales")
    });
    (out, packed)
}

/// GPTQ returning the fake-quantized (dense f32) matrix.
pub fn gptq_quantize(w: &Mat, stats: &CalibStats, bits: u32) -> Mat {
    gptq_core(w, stats, bits, GROUP).0
}

/// GPTQ straight into packed storage (2..=8 bits); `dequantize()` of the
/// result is bit-identical to [`gptq_quantize`].
pub fn gptq_quantize_packed(w: &Mat, stats: &CalibStats, bits: u32) -> QuantMat {
    gptq_core(w, stats, bits, GROUP).1.expect("gptq_quantize_packed: bits must be in 2..=8")
}

/// Quantize a dense layer: returns the packed layer (fake-quantized above
/// 8 bits) with measured bit accounting.
pub fn quantize_layer(
    w: &Mat,
    stats: &CalibStats,
    bits: u32,
    use_gptq: bool,
) -> CompressedLayer {
    quantize_weight(&LinearWeight::Dense(w.clone()), w, Some(stats), bits, use_gptq)
}

/// Quantize *whatever representation a layer currently stores* to `bits`:
/// dense weights directly, low-rank / factorized layers factor-by-factor
/// (Table 7 composition). GPTQ needs the Gram of the factor's *input*
/// activations, which exists only for the input-side factor (A / B / W
/// itself) — those get GPTQ when `use_gptq` and the stats dimension
/// matches; everything else falls back to RTN. `original` is the dense
/// reference the CR is accounted against (Eq. 25 realized on actual stored
/// bits for 2..=8-bit packed storage).
pub fn quantize_weight(
    current: &LinearWeight,
    original: &Mat,
    stats: Option<&CalibStats>,
    bits: u32,
    use_gptq: bool,
) -> CompressedLayer {
    quantize_weight_grouped(current, original, stats, bits, use_gptq, GROUP)
}

/// [`quantize_weight`] with an explicit quantization group size (the
/// `--set group_size=64|128|256` sweep; 128 is the default).
pub fn quantize_weight_grouped(
    current: &LinearWeight,
    original: &Mat,
    stats: Option<&CalibStats>,
    bits: u32,
    use_gptq: bool,
    group: usize,
) -> CompressedLayer {
    assert!(supported_group(group), "unsupported quantization group size {group}");
    let gptq_fits = |rows: usize| use_gptq && stats.map(|s| s.dim() == rows).unwrap_or(false);
    // Re-quantizing an already-packed weight re-runs on its (bit-identical)
    // fake-quant values.
    let current = current.dequantized();
    let pack = QuantMat::supported_bits(bits);

    // A quantized dense factor in whichever representation the bit width
    // supports.
    enum QFactor {
        Packed(QuantMat),
        Fake(Mat),
    }
    // One quantizer for every dense factor: GPTQ on input-side factors when
    // the calibration Gram matches, RTN otherwise; packed at 2..=8 bits,
    // legacy fake-quant f32 above.
    let quantize_mat = |w: &Mat, input_side: bool| -> QFactor {
        let gptq = input_side && gptq_fits(w.rows());
        match (pack, gptq) {
            (true, true) => {
                QFactor::Packed(gptq_core(w, stats.unwrap(), bits, group).1.expect("packable"))
            }
            (true, false) => QFactor::Packed(QuantMat::quantize_from_grouped(w, bits, group)),
            (false, true) => QFactor::Fake(gptq_core(w, stats.unwrap(), bits, group).0),
            (false, false) => QFactor::Fake(rtn_quantize_grouped(w, bits, group)),
        }
    };

    // stored value count, non-value (mask) bits, the packed-alignment slack
    // for the formula cross-check (≤ one extra 16-bit scale per stored
    // row/column for ragged group tails, plus ≤ 31·bits bits of planar
    // tail-strip padding per stored row — the code-planar layout word-aligns
    // each bit-plane strip of a ragged tail group — plus one u32 of padding
    // per packed matrix for the legacy row-sequential stream), and — for the
    // legacy fake-quant representation only — an exact bit accounting when
    // the flat formula would miscount.
    let row_slack = 16 + 31 * bits as u64;
    let (weight, stored_values, mask_bits, slack_bits, fake_bits) = match &current {
        LinearWeight::Dense(w) => {
            let count = w.rows() * w.cols();
            let slack = row_slack * w.rows() as u64 + 31;
            let weight = match quantize_mat(w, true) {
                QFactor::Packed(qm) => LinearWeight::QuantDense(qm),
                QFactor::Fake(q) => LinearWeight::Dense(q),
            };
            (weight, count, 0u64, slack, None)
        }
        LinearWeight::LowRank { b, c } => {
            let count = b.rows() * b.cols() + c.rows() * c.cols();
            let slack = row_slack * (b.rows() + c.rows()) as u64 + 2 * 31;
            let weight = match (quantize_mat(b, true), quantize_mat(c, false)) {
                (QFactor::Packed(qb), QFactor::Packed(qc)) => {
                    LinearWeight::QuantLowRank { b: qb, c: qc }
                }
                (QFactor::Fake(qb), QFactor::Fake(qc)) => {
                    LinearWeight::LowRank { b: qb, c: qc }
                }
                _ => unreachable!("representation is decided by `pack` alone"),
            };
            (weight, count, 0u64, slack, None)
        }
        LinearWeight::Factorized { a, s } => {
            let count = a.rows() * a.cols() + s.s() * s.n();
            let mask = (s.k() * s.n()) as u64;
            let slack = row_slack * (a.rows() + s.n()) as u64 + 2 * 31;
            // Groups over the sparse values align to columns either way:
            // one column's outlier cannot poison its neighbors' scales.
            match quantize_mat(a, true) {
                QFactor::Packed(qa) => {
                    let weight = LinearWeight::QuantFactorized {
                        a: qa,
                        s: QuantColumnSparse::quantize_from_grouped(s, bits, group),
                    };
                    (weight, count, mask, slack, None)
                }
                QFactor::Fake(qa) => {
                    let mut qs: ColumnSparse = s.clone();
                    let mut vals: Vec<f32> = qs.values().to_vec();
                    if qs.s() > 0 {
                        for col in vals.chunks_mut(qs.s()) {
                            let len = col.len();
                            for g in (0..len).step_by(group) {
                                quantize_group(&mut col[g..(g + group).min(len)], bits);
                            }
                        }
                    }
                    qs.set_values(&vals);
                    // Column-aligned groups cost one scale per column group
                    // (n·⌈s/group⌉) — account them exactly; the flat formula
                    // would under-count them.
                    let sparse_vals = (s.s() * s.n()) as u64;
                    let exact = quant_bits_grouped(a.rows() * a.cols(), bits, group)
                        + sparse_vals * bits as u64
                        + (s.n() * s.s().div_ceil(group)) as u64 * 16
                        + mask;
                    (LinearWeight::Factorized { a: qa, s: qs }, count, mask, slack, Some(exact))
                }
            }
        }
        _ => unreachable!("dequantized() returns only 16-bit forms"),
    };
    let mut out = CompressedLayer::new(
        if use_gptq { "GPTQ" } else { "RTN" },
        original,
        weight,
        stats,
    );
    let formula = quant_bits_grouped(stored_values, bits, group) + mask_bits;
    if pack {
        // `CompressedLayer::new` measured the bits from the packed buffers;
        // the Eq.-25 formula is kept as a cross-check envelope.
        assert!(
            out.bits >= formula && out.bits <= formula + slack_bits,
            "packed storage accounting out of envelope: measured {} vs formula {formula} \
             (+ slack {slack_bits})",
            out.bits
        );
    } else {
        out.bits = fake_bits.unwrap_or(formula);
    }
    out.cr = 1.0 - out.bits as f64 / (16 * original.rows() * original.cols()) as f64;
    out
}

/// Table 7 composition: quantize the *stored factors* of an
/// already-factorized layer to `bits` (GPTQ on the input-side factor, RTN on
/// the rest — matching how SVD-LLM V2 + GPTQ composes).
pub fn quantize_factors(
    layer: &CompressedLayer,
    original: &Mat,
    stats: &CalibStats,
    bits: u32,
) -> CompressedLayer {
    let mut out = quantize_weight(&layer.weight, original, Some(stats), bits, true);
    out.method = layer.method;
    out.iters_run = layer.iters_run;
    out
}

/// Model-level quantization stage: b-bit RTN/GPTQ over every projection of
/// the current model. On a dense model this is plain PTQ; on a factorized
/// model it quantizes the stored factors, so `[factorize, quantize]` plans
/// reproduce the paper's Eq. 25 composed-CR accounting from actual bits —
/// and, at 2..=8 bits, from actually-packed buffers the decode runtime
/// executes on natively. `group` is the quantization group size
/// (`--set group_size=64|128|256`, default [`GROUP`] = 128), recorded in
/// CPT2 headers so checkpoints round-trip non-default groups.
pub struct Quantize {
    pub bits: u32,
    pub gptq: bool,
    pub group: usize,
}

impl Default for Quantize {
    fn default() -> Self {
        Quantize { bits: 4, gptq: false, group: GROUP }
    }
}

impl ModelCompressor for Quantize {
    fn name(&self) -> String {
        if self.gptq { "GPTQ".to_string() } else { "RTN".to_string() }
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        _cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        // Structural stages (ReplaceMe) change the stage list; calibration
        // stats and original weights are only index-aligned when they don't.
        let aligned = model.stages.len() == ctx.original.stages.len();
        let mut out = model.clone();
        let mut reports: Vec<LayerReport> = Vec::new();
        let mut used_bits = 0u64;
        let mut total_bits = 0u64;
        for (layer, b) in model.blocks() {
            for p in ProjKind::DECODER_SET {
                let current = b.proj(p);
                let stats = if aligned { ctx.capture.stats.get(&(layer, p)) } else { None };
                // stats are usable only while the projection keeps its
                // original input width (structured pruning shrinks it)
                let stats = stats.filter(|s| s.dim() == current.in_dim());
                let orig_w = match (aligned, ctx.original.stages.get(layer)) {
                    (true, Some(Stage::Block(ob))) => ob.proj(p).to_dense(),
                    _ => current.to_dense(),
                };
                // Structured pruning keeps the stage count but shrinks
                // projections; account against the current shape then.
                let orig_w = if orig_w.rows() == current.in_dim()
                    && orig_w.cols() == current.out_dim()
                {
                    orig_w
                } else {
                    current.to_dense()
                };
                let q = quantize_weight_grouped(
                    current,
                    &orig_w,
                    stats,
                    self.bits,
                    self.gptq,
                    self.group,
                );
                used_bits += q.bits;
                total_bits += 16 * (orig_w.rows() * orig_w.cols()) as u64;
                reports.push(LayerReport::measured(
                    layer,
                    p,
                    1.0 - self.bits as f64 / 16.0,
                    &q,
                    0.0,
                ));
                api::set_proj(&mut out, layer, p, q.weight);
            }
        }
        // Linear replacement stages keep their 16-bit storage.
        for stage in &model.stages {
            if let Stage::Linear(t) = stage {
                let bits = 16 * (t.rows() * t.cols()) as u64;
                used_bits += bits;
                total_bits += bits;
            }
        }
        anyhow::ensure!(total_bits > 0, "model has no compressible projections");
        let model_cr = 1.0 - used_bits as f64 / total_bits as f64;
        Ok((
            out,
            CompressionReport {
                method: self.name(),
                per_layer: reports,
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

fn build_quantize(o: &super::registry::MethodOptions, gptq: bool) -> anyhow::Result<Box<dyn ModelCompressor>> {
    let bits = o.get_u32("bits")?.unwrap_or(4);
    anyhow::ensure!((2..=16).contains(&bits), "bits must be in 2..=16, got {bits}");
    let group = o.get_usize("group_size")?.unwrap_or(GROUP);
    anyhow::ensure!(
        [64, 128, 256].contains(&group),
        "group_size must be 64, 128, or 256 (the sweep points), got {group}"
    );
    Ok(Box::new(Quantize { bits, gptq, group }))
}

/// Registry entry: `rtn4` (alias `rtn`) with options `bits` (default 4) and
/// `group_size` (default 128).
pub fn rtn_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "rtn4",
        aliases: &["rtn"],
        about: "round-to-nearest b-bit quantization, packed storage (bits=4, group_size=128)",
        defaults: &[("bits", "4"), ("group_size", "128")],
        build: |o| build_quantize(o, false),
    }
}

/// Registry entry: `gptq4` (alias `gptq`) with options `bits` (default 4)
/// and `group_size` (default 128).
pub fn gptq_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "gptq4",
        aliases: &["gptq"],
        about: "GPTQ b-bit quantization, Hessian-compensated, packed storage (bits=4, group_size=128)",
        defaults: &[("bits", "4"), ("group_size", "128")],
        build: |o| build_quantize(o, true),
    }
}

/// Registry entry: `gptq3` — GPTQ at 3 bits (Table 7's memory-matched row).
pub fn gptq3_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "gptq3",
        aliases: &[],
        about: "GPTQ 3-bit quantization (Table 7 matched-memory baseline)",
        defaults: &[("bits", "3"), ("group_size", "128")],
        build: |o| build_quantize(o, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn problem(seed: u64, m: usize, n: usize) -> (Mat, CalibStats) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, m, n, 0.1);
        let mut x = Mat::randn(&mut rng, 8 * m, m, 1.0);
        for i in 0..x.rows() {
            for j in 0..m {
                x[(i, j)] *= 1.0 + 3.0 * ((j * 7 % m) as f32 / m as f32);
            }
        }
        (w, CalibStats::from_activations(&x))
    }

    fn assert_bitwise(a: &Mat, b: &Mat, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape");
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() == 0.0,
                    "{what} ({i},{j}): {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    #[test]
    fn rtn_error_bounded_by_step() {
        let (w, _) = problem(150, 16, 64);
        let q = rtn_quantize(&w, 4);
        // max error ≤ scale/2 with the f16-rounded group scale; the
        // symmetric clamp additionally bounds |q̂| by the group amax on the
        // *negative* edge (the old −qmax−1 level could overshoot it by a
        // full step).
        for i in 0..16 {
            let row = w.row(i);
            for g in (0..64).step_by(GROUP) {
                let end = (g + GROUP).min(64);
                let amax = row[g..end].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let step = qmat::f16_decode(qmat::f16_encode(amax / 7.0));
                for j in g..end {
                    assert!((w[(i, j)] - q[(i, j)]).abs() <= step / 2.0 + 1e-7);
                    assert!(
                        q[(i, j)].abs() <= 7.0 * step + 1e-7,
                        "({i},{j}): |{}| overshoots amax {amax}",
                        q[(i, j)]
                    );
                    assert!(q[(i, j)] >= -7.0 * step - 1e-7, "negative edge at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn packed_rtn_roundtrip_is_bit_exact() {
        // Ragged tails on purpose: 150 cols crosses the 128-group edge.
        for &bits in &[2u32, 3, 4, 8] {
            let (w, _) = problem(160 + bits as u64, 9, 150);
            let fake = rtn_quantize(&w, bits);
            let packed = rtn_quantize_packed(&w, bits);
            assert_bitwise(&packed.dequantize(), &fake, &format!("rtn bits {bits}"));
        }
    }

    #[test]
    fn packed_gptq_roundtrip_is_bit_exact() {
        for &bits in &[3u32, 4, 8] {
            let (w, stats) = problem(170 + bits as u64, 24, 40);
            let fake = gptq_quantize(&w, &stats, bits);
            let packed = gptq_quantize_packed(&w, &stats, bits);
            assert_bitwise(&packed.dequantize(), &fake, &format!("gptq bits {bits}"));
        }
    }

    #[test]
    fn gptq_beats_rtn_on_functional_error() {
        // The whole point of GPTQ: lower ‖X(W−Q)‖ than naive rounding.
        let (w, stats) = problem(151, 32, 64);
        let rtn = rtn_quantize(&w, 3);
        let gptq = gptq_quantize(&w, &stats, 3);
        let err_rtn = stats.functional_err(&w, &rtn);
        let err_gptq = stats.functional_err(&w, &gptq);
        assert!(
            err_gptq < err_rtn,
            "gptq {err_gptq} should beat rtn {err_rtn}"
        );
    }

    #[test]
    fn higher_bits_lower_error() {
        let (w, stats) = problem(152, 24, 48);
        let e4 = stats.functional_err(&w, &gptq_quantize(&w, &stats, 4));
        let e8 = stats.functional_err(&w, &gptq_quantize(&w, &stats, 8));
        assert!(e8 < e4);
    }

    #[test]
    fn bit_accounting() {
        // The Eq.-25 formula itself is unchanged …
        assert_eq!(quant_bits(256, 4), 256 * 4 + 2 * 16);
        assert_eq!(quant_bits(100, 3), 300 + 16);
        // … but layer bits are now *measured* from the packed buffers:
        // 16×32 at 4 bits = 2048 value bits (64 words) + 16 per-row scales.
        let (w, stats) = problem(153, 16, 32);
        let layer = quantize_layer(&w, &stats, 4, false);
        assert!(matches!(layer.weight, LinearWeight::QuantDense(_)));
        assert_eq!(layer.bits, 64 * 32 + 16 * 16);
        assert_eq!(layer.bits, layer.weight.storage_bits());
        assert!(layer.bits >= quant_bits(16 * 32, 4), "formula must stay a floor");
        assert!(layer.cr > 0.7 && layer.cr < 0.76); // ≈ 1 − 4/16 minus scales
    }

    #[test]
    fn quantize_weight_emits_packed_variants() {
        let mut rng = Rng::new(155);
        let (w, stats) = problem(156, 32, 64);
        let variants = [
            LinearWeight::Dense(w.clone()),
            LinearWeight::LowRank {
                b: Mat::randn(&mut rng, 32, 8, 0.2),
                c: Mat::randn(&mut rng, 8, 64, 0.2),
            },
            LinearWeight::Factorized {
                a: Mat::randn(&mut rng, 32, 12, 0.2),
                s: ColumnSparse::hard_threshold(&Mat::randn(&mut rng, 12, 64, 0.2), 5),
            },
        ];
        for current in &variants {
            let out = quantize_weight(current, &w, Some(&stats), 4, true);
            assert!(out.weight.is_quantized(), "{current:?} not packed");
            assert_eq!(out.weight.in_dim(), current.in_dim());
            assert_eq!(out.weight.out_dim(), current.out_dim());
            assert_eq!(out.bits, out.weight.storage_bits());
            // packed apply must be bit-identical to the dequantized form
            let x = Mat::randn(&mut rng, 3, 32, 1.0);
            assert_bitwise(
                &out.weight.apply(&x),
                &out.weight.dequantized().apply(&x),
                "fused apply",
            );
            // quantizing the quantized layer again is a no-op on the values
            let again = quantize_weight(&out.weight, &w, Some(&stats), 4, false);
            assert_bitwise(&again.weight.to_dense(), &out.weight.to_dense(), "requant");
        }
    }

    #[test]
    fn wide_bit_widths_fall_back_to_fake_quant() {
        let (w, stats) = problem(157, 8, 16);
        let layer = quantize_weight(&LinearWeight::Dense(w.clone()), &w, Some(&stats), 12, false);
        assert!(matches!(layer.weight, LinearWeight::Dense(_)));
        assert_eq!(layer.bits, quant_bits(8 * 16, 12));

        // Factorized fake-quant accounts its column-aligned sparse scales
        // exactly: one 16-bit scale per column group (n·⌈s/128⌉), not the
        // flat formula's under-count.
        let mut rng = Rng::new(158);
        let (w2, stats2) = problem(159, 32, 64);
        let current = LinearWeight::Factorized {
            a: Mat::randn(&mut rng, 32, 12, 0.2),
            s: ColumnSparse::hard_threshold(&Mat::randn(&mut rng, 12, 64, 0.2), 5),
        };
        let layer = quantize_weight(&current, &w2, Some(&stats2), 12, false);
        assert!(matches!(layer.weight, LinearWeight::Factorized { .. }));
        let expected = quant_bits(32 * 12, 12)   // dense dictionary, flat legacy
            + (5 * 64) as u64 * 12               // sparse values
            + 64 * 16                            // one scale per column (s=5 ≤ 128)
            + (12 * 64) as u64;                  // Eq.-11 position mask
        assert_eq!(layer.bits, expected);
    }

    #[test]
    fn compose_with_compot_factors() {
        use crate::compress::compot::Compot;
        use crate::compress::Compressor;
        let (w, stats) = problem(154, 32, 64);
        let mut rng = Rng::new(1);
        let fact = Compot::default().compress(&w, &stats, 0.25, &mut rng).unwrap();
        let q = quantize_factors(&fact, &w, &stats, 4);
        // Composition must emit packed factors…
        assert!(matches!(q.weight, LinearWeight::QuantFactorized { .. }));
        // …and exceed factorization-only CR.
        assert!(q.cr > fact.cr, "{} vs {}", q.cr, fact.cr);
        // And error should grow only modestly.
        assert!(q.func_err.unwrap() >= fact.func_err.unwrap() * 0.99);
        assert!(q.func_err.unwrap() < fact.func_err.unwrap() * 3.0 + 1e-6);
    }

    #[test]
    fn grouped_quantization_is_consistent_and_validated() {
        // group_size threads through RTN/GPTQ and every stored variant;
        // smaller groups spend more scale bits and cannot hurt the error.
        let (w, stats) = problem(161, 24, 300);
        let mut layers = Vec::new();
        for group in [64usize, 128, 256] {
            let layer = quantize_weight_grouped(
                &LinearWeight::Dense(w.clone()),
                &w,
                Some(&stats),
                4,
                true,
                group,
            );
            let LinearWeight::QuantDense(ref qm) = layer.weight else {
                panic!("expected packed storage")
            };
            assert_eq!(qm.group(), group);
            assert_eq!(layer.bits, layer.weight.storage_bits());
            layers.push(layer);
        }
        // more scales at 64 than at 256
        assert!(layers[0].bits > layers[2].bits);
        // finer groups track the weights at least as well (loose bound)
        assert!(layers[0].weight_err <= layers[2].weight_err * 1.25);
        // the registry rejects off-sweep group sizes and accepts the sweep
        let reg = crate::compress::MethodRegistry::global();
        for g in ["64", "128", "256"] {
            assert!(
                reg.build(&crate::compress::MethodCall::new("rtn4").with("group_size", g))
                    .is_ok(),
                "group_size={g}"
            );
        }
        let err = reg
            .build(&crate::compress::MethodCall::new("gptq4").with("group_size", 100))
            .unwrap_err()
            .to_string();
        assert!(err.contains("group_size"), "{err}");
    }

    #[test]
    fn quantize_preserves_shape_semantics() {
        let (w, stats) = problem(155, 8, 16);
        let layer = quantize_layer(&w, &stats, 8, true);
        assert_eq!(layer.weight.in_dim(), 8);
        assert_eq!(layer.weight.out_dim(), 16);
        // 8-bit quantization is nearly lossless relative to 3-bit.
        let l3 = quantize_layer(&w, &stats, 3, true);
        assert!(layer.func_err.unwrap() < l3.func_err.unwrap());
    }
}
