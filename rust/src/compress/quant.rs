//! Post-training weight quantization: RTN (round-to-nearest) and GPTQ
//! (Frantar et al., 2023), composable with factorization (Table 7).
//!
//! GPTQ quantizes the weight one input-row at a time, compensating the
//! rounding error on the not-yet-quantized rows using the inverse Hessian
//! `H = 2·XᵀX + λI` (here: the calibration Gram). We implement the classic
//! Cholesky formulation. Quantized weights are stored *fake-quantized*
//! (dequantized f32 values) for evaluation, with exact bit accounting:
//! b bits per value + 16-bit scale per group of 128.

use super::api::{
    self, CalibContext, CompressionReport, LayerReport, ModelCompressor, StageConfig,
};
use super::sparse::ColumnSparse;
use super::whitening::CalibStats;
use super::{CompressedLayer, LinearWeight};
use crate::linalg::{cholesky, gemm, solve, Mat};
use crate::model::config::ProjKind;
use crate::model::transformer::{Model, Stage};

pub const GROUP: usize = 128;

/// Per-group symmetric quantization parameters for a value slice.
fn quantize_group(vals: &mut [f32], bits: u32) {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let amax = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return;
    }
    let scale = amax / qmax;
    for v in vals.iter_mut() {
        let q = (*v / scale).round().clamp(-qmax - 1.0, qmax);
        *v = q * scale;
    }
}

/// Storage bits for `count` values at b bits + one 16-bit scale per group.
pub fn quant_bits(count: usize, bits: u32) -> u64 {
    (count as u64) * bits as u64 + (count.div_ceil(GROUP) as u64) * 16
}

/// RTN: per-row groups of 128 along the output dimension.
pub fn rtn_quantize(w: &Mat, bits: u32) -> Mat {
    let mut q = w.clone();
    for i in 0..q.rows() {
        let row = q.row_mut(i);
        for g in (0..row.len()).step_by(GROUP) {
            let end = (g + GROUP).min(row.len());
            quantize_group(&mut row[g..end], bits);
        }
    }
    q
}

/// GPTQ over the input dimension (rows of W, convention y = x·W, H = Gram of
/// x). Processes rows in natural order with full error compensation:
/// after quantizing row i, the remaining rows absorb `−e·H⁻¹[i, j]/H⁻¹[i,i]`.
pub fn gptq_quantize(w: &Mat, stats: &CalibStats, bits: u32) -> Mat {
    let m = w.rows();
    assert_eq!(stats.dim(), m, "gptq: Hessian dim must match input dim");
    // H = 2G + λI (damping 1% of mean diagonal, GPTQ's default style).
    let mut h = stats.gram().scale(2.0);
    let mean_diag: f64 = (0..m).map(|i| h[(i, i)] as f64).sum::<f64>() / m as f64;
    let damp = (0.01 * mean_diag).max(1e-8) as f32;
    for i in 0..m {
        h[(i, i)] += damp;
    }
    // Hinv via Cholesky: H = LLᵀ ⇒ H⁻¹ = L⁻ᵀ·L⁻¹.
    let l = cholesky::cholesky(&h).expect("damped Hessian must be PD");
    let linv = solve::solve_lower_left(&l, &Mat::eye(m)); // L⁻¹
    let hinv = gemm::matmul_tn(&linv, &linv); // L⁻ᵀL⁻¹

    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let mut work = w.clone();
    let mut out = Mat::zeros(w.rows(), w.cols());
    let n = w.cols();

    // Per-(row-slice) group scales, computed on the *current* (compensated)
    // values as in the reference implementation.
    for i in 0..m {
        // Quantize row i in groups.
        let mut qrow = work.row(i).to_vec();
        for g in (0..n).step_by(GROUP) {
            let end = (g + GROUP).min(n);
            let seg = &mut qrow[g..end];
            let amax = seg.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
            if amax > 0.0 {
                let scale = amax / qmax;
                for v in seg.iter_mut() {
                    *v = (*v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
                }
            }
        }
        let dii = hinv[(i, i)].max(1e-12);
        // Error on row i.
        let err: Vec<f32> = work
            .row(i)
            .iter()
            .zip(qrow.iter())
            .map(|(&orig, &q)| (orig - q) / dii)
            .collect();
        out.row_mut(i).copy_from_slice(&qrow);
        // Compensate remaining rows: W[j,:] −= Hinv[j,i]·err.
        for j in i + 1..m {
            let f = hinv[(j, i)];
            if f == 0.0 {
                continue;
            }
            let row = work.row_mut(j);
            for (x, e) in row.iter_mut().zip(err.iter()) {
                *x -= f * e;
            }
        }
    }
    out
}

/// Quantize a dense layer: returns the fake-quantized layer with adjusted
/// bit accounting.
pub fn quantize_layer(
    w: &Mat,
    stats: &CalibStats,
    bits: u32,
    use_gptq: bool,
) -> CompressedLayer {
    let q = if use_gptq { gptq_quantize(w, stats, bits) } else { rtn_quantize(w, bits) };
    let mut layer = CompressedLayer::new(
        if use_gptq { "GPTQ" } else { "RTN" },
        w,
        LinearWeight::Dense(q),
        Some(stats),
    );
    layer.bits = quant_bits(w.rows() * w.cols(), bits);
    layer.cr = 1.0 - layer.bits as f64 / (16 * w.rows() * w.cols()) as f64;
    layer
}

/// Quantize *whatever representation a layer currently stores* to `bits`:
/// dense weights directly, low-rank / factorized layers factor-by-factor
/// (Table 7 composition). GPTQ needs the Gram of the factor's *input*
/// activations, which exists only for the input-side factor (A / B / W
/// itself) — those get GPTQ when `use_gptq` and the stats dimension
/// matches; everything else falls back to RTN. `original` is the dense
/// reference the CR is accounted against (Eq. 25 on actual stored bits).
pub fn quantize_weight(
    current: &LinearWeight,
    original: &Mat,
    stats: Option<&CalibStats>,
    bits: u32,
    use_gptq: bool,
) -> CompressedLayer {
    let gptq_fits = |rows: usize| use_gptq && stats.map(|s| s.dim() == rows).unwrap_or(false);
    let (weight, stored_values, mask_bits) = match current {
        LinearWeight::Dense(w) => {
            let q = if gptq_fits(w.rows()) {
                gptq_quantize(w, stats.unwrap(), bits)
            } else {
                rtn_quantize(w, bits)
            };
            let count = w.rows() * w.cols();
            (LinearWeight::Dense(q), count, 0u64)
        }
        LinearWeight::LowRank { b, c } => {
            let qb = if gptq_fits(b.rows()) {
                gptq_quantize(b, stats.unwrap(), bits)
            } else {
                rtn_quantize(b, bits)
            };
            let qc = rtn_quantize(c, bits);
            let count = b.rows() * b.cols() + c.rows() * c.cols();
            (LinearWeight::LowRank { b: qb, c: qc }, count, 0u64)
        }
        LinearWeight::Factorized { a, s } => {
            let qa = if gptq_fits(a.rows()) {
                gptq_quantize(a, stats.unwrap(), bits)
            } else {
                rtn_quantize(a, bits)
            };
            let mut qs: ColumnSparse = s.clone();
            // RTN over the sparse values in groups of 128.
            let mut vals: Vec<f32> = qs.values().to_vec();
            for g in (0..vals.len()).step_by(GROUP) {
                let end = (g + GROUP).min(vals.len());
                quantize_group(&mut vals[g..end], bits);
            }
            qs.set_values(&vals);
            let count = a.rows() * a.cols() + s.s() * s.n();
            let mask = (s.k() * s.n()) as u64;
            (LinearWeight::Factorized { a: qa, s: qs }, count, mask)
        }
    };
    let mut out = CompressedLayer::new(
        if use_gptq { "GPTQ" } else { "RTN" },
        original,
        weight,
        stats,
    );
    out.bits = quant_bits(stored_values, bits) + mask_bits;
    out.cr = 1.0 - out.bits as f64 / (16 * original.rows() * original.cols()) as f64;
    out
}

/// Table 7 composition: quantize the *stored factors* of an
/// already-factorized layer to `bits` (GPTQ on the input-side factor, RTN on
/// the rest — matching how SVD-LLM V2 + GPTQ composes).
pub fn quantize_factors(
    layer: &CompressedLayer,
    original: &Mat,
    stats: &CalibStats,
    bits: u32,
) -> CompressedLayer {
    let mut out = quantize_weight(&layer.weight, original, Some(stats), bits, true);
    out.method = layer.method;
    out.iters_run = layer.iters_run;
    out
}

/// Model-level quantization stage: b-bit RTN/GPTQ over every projection of
/// the current model. On a dense model this is plain PTQ; on a factorized
/// model it quantizes the stored factors, so `[factorize, quantize]` plans
/// reproduce the paper's Eq. 25 composed-CR accounting from actual bits.
pub struct Quantize {
    pub bits: u32,
    pub gptq: bool,
}

impl ModelCompressor for Quantize {
    fn name(&self) -> String {
        if self.gptq { "GPTQ".to_string() } else { "RTN".to_string() }
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        _cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        // Structural stages (ReplaceMe) change the stage list; calibration
        // stats and original weights are only index-aligned when they don't.
        let aligned = model.stages.len() == ctx.original.stages.len();
        let mut out = model.clone();
        let mut reports: Vec<LayerReport> = Vec::new();
        let mut used_bits = 0u64;
        let mut total_bits = 0u64;
        for (layer, b) in model.blocks() {
            for p in ProjKind::DECODER_SET {
                let current = b.proj(p);
                let stats = if aligned { ctx.capture.stats.get(&(layer, p)) } else { None };
                // stats are usable only while the projection keeps its
                // original input width (structured pruning shrinks it)
                let stats = stats.filter(|s| s.dim() == current.in_dim());
                let orig_w = match (aligned, ctx.original.stages.get(layer)) {
                    (true, Some(Stage::Block(ob))) => ob.proj(p).to_dense(),
                    _ => current.to_dense(),
                };
                // Structured pruning keeps the stage count but shrinks
                // projections; account against the current shape then.
                let orig_w = if orig_w.rows() == current.in_dim()
                    && orig_w.cols() == current.out_dim()
                {
                    orig_w
                } else {
                    current.to_dense()
                };
                let q = quantize_weight(current, &orig_w, stats, self.bits, self.gptq);
                used_bits += q.bits;
                total_bits += 16 * (orig_w.rows() * orig_w.cols()) as u64;
                reports.push(LayerReport::measured(
                    layer,
                    p,
                    1.0 - self.bits as f64 / 16.0,
                    &q,
                    0.0,
                ));
                api::set_proj(&mut out, layer, p, q.weight);
            }
        }
        // Linear replacement stages keep their 16-bit storage.
        for stage in &model.stages {
            if let Stage::Linear(t) = stage {
                let bits = 16 * (t.rows() * t.cols()) as u64;
                used_bits += bits;
                total_bits += bits;
            }
        }
        anyhow::ensure!(total_bits > 0, "model has no compressible projections");
        let model_cr = 1.0 - used_bits as f64 / total_bits as f64;
        Ok((
            out,
            CompressionReport {
                method: self.name(),
                per_layer: reports,
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

fn build_quantize(o: &super::registry::MethodOptions, gptq: bool) -> anyhow::Result<Box<dyn ModelCompressor>> {
    let bits = o.get_u32("bits")?.unwrap_or(4);
    anyhow::ensure!((2..=16).contains(&bits), "bits must be in 2..=16, got {bits}");
    Ok(Box::new(Quantize { bits, gptq }))
}

/// Registry entry: `rtn4` (alias `rtn`) with option `bits` (default 4).
pub fn rtn_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "rtn4",
        aliases: &["rtn"],
        about: "round-to-nearest b-bit quantization (bits=4 default)",
        defaults: &[("bits", "4")],
        build: |o| build_quantize(o, false),
    }
}

/// Registry entry: `gptq4` (alias `gptq`) with option `bits` (default 4).
pub fn gptq_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "gptq4",
        aliases: &["gptq"],
        about: "GPTQ b-bit quantization with Hessian error compensation (bits=4 default)",
        defaults: &[("bits", "4")],
        build: |o| build_quantize(o, true),
    }
}

/// Registry entry: `gptq3` — GPTQ at 3 bits (Table 7's memory-matched row).
pub fn gptq3_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "gptq3",
        aliases: &[],
        about: "GPTQ 3-bit quantization (Table 7 matched-memory baseline)",
        defaults: &[("bits", "3")],
        build: |o| build_quantize(o, true),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn problem(seed: u64, m: usize, n: usize) -> (Mat, CalibStats) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, m, n, 0.1);
        let mut x = Mat::randn(&mut rng, 8 * m, m, 1.0);
        for i in 0..x.rows() {
            for j in 0..m {
                x[(i, j)] *= 1.0 + 3.0 * ((j * 7 % m) as f32 / m as f32);
            }
        }
        (w, CalibStats::from_activations(&x))
    }

    #[test]
    fn rtn_error_bounded_by_step() {
        let (w, _) = problem(150, 16, 64);
        let q = rtn_quantize(&w, 4);
        // max error ≤ scale/2, scale = amax/7 per group
        for i in 0..16 {
            let row = w.row(i);
            for g in (0..64).step_by(GROUP) {
                let end = (g + GROUP).min(64);
                let amax = row[g..end].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let step = amax / 7.0;
                for j in g..end {
                    assert!((w[(i, j)] - q[(i, j)]).abs() <= step / 2.0 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn gptq_beats_rtn_on_functional_error() {
        // The whole point of GPTQ: lower ‖X(W−Q)‖ than naive rounding.
        let (w, stats) = problem(151, 32, 64);
        let rtn = rtn_quantize(&w, 3);
        let gptq = gptq_quantize(&w, &stats, 3);
        let err_rtn = stats.functional_err(&w, &rtn);
        let err_gptq = stats.functional_err(&w, &gptq);
        assert!(
            err_gptq < err_rtn,
            "gptq {err_gptq} should beat rtn {err_rtn}"
        );
    }

    #[test]
    fn higher_bits_lower_error() {
        let (w, stats) = problem(152, 24, 48);
        let e4 = stats.functional_err(&w, &gptq_quantize(&w, &stats, 4));
        let e8 = stats.functional_err(&w, &gptq_quantize(&w, &stats, 8));
        assert!(e8 < e4);
    }

    #[test]
    fn bit_accounting() {
        assert_eq!(quant_bits(256, 4), 256 * 4 + 2 * 16);
        assert_eq!(quant_bits(100, 3), 300 + 16);
        let (w, stats) = problem(153, 16, 32);
        let layer = quantize_layer(&w, &stats, 4, false);
        assert_eq!(layer.bits, quant_bits(16 * 32, 4));
        assert!(layer.cr > 0.7 && layer.cr < 0.76); // ≈ 1 − 4/16 minus scales
    }

    #[test]
    fn compose_with_compot_factors() {
        use crate::compress::compot::Compot;
        use crate::compress::Compressor;
        let (w, stats) = problem(154, 32, 64);
        let mut rng = Rng::new(1);
        let fact = Compot::default().compress(&w, &stats, 0.25, &mut rng).unwrap();
        let q = quantize_factors(&fact, &w, &stats, 4);
        // Composed CR must exceed factorization-only CR.
        assert!(q.cr > fact.cr, "{} vs {}", q.cr, fact.cr);
        // And error should grow only modestly.
        assert!(q.func_err.unwrap() >= fact.func_err.unwrap() * 0.99);
        assert!(q.func_err.unwrap() < fact.func_err.unwrap() * 3.0 + 1e-6);
    }

    #[test]
    fn quantize_preserves_shape_semantics() {
        let (w, stats) = problem(155, 8, 16);
        let layer = quantize_layer(&w, &stats, 8, true);
        assert_eq!(layer.weight.in_dim(), 8);
        assert_eq!(layer.weight.out_dim(), 16);
        // 8-bit quantization is nearly lossless relative to 3-bit.
        let l3 = quantize_layer(&w, &stats, 3, true);
        assert!(layer.func_err.unwrap() < l3.func_err.unwrap());
    }
}
