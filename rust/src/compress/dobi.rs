//! Dobi-SVD* baseline (Qinsi et al., 2025) — differentiable truncation,
//! reproduced training-free.
//!
//! The original optimizes per-layer ranks by backpropagating through a soft
//! truncation. The quantity that optimization targets is the calibration
//! (whitened) truncation loss as a function of rank, which here is available
//! in closed form: the tail energy of the whitened spectrum. We therefore
//! solve the same allocation problem *exactly* by Lagrangian waterfilling —
//! a whitened singular value σ is kept iff σ² ≥ λ·(mᵢ+nᵢ), with λ bisected
//! to meet the global parameter budget. This is the strongest training-free
//! stand-in for the learned allocation (documented substitution, DESIGN §3).
//!
//! The module also implements the *remapping accounting* of Eq. 25 used by
//! Table 19: remapping re-densifies factors (possibly CR_fact < 0) and
//! recovers the budget through b-bit quantization.

use super::api::{self, CalibContext, CompressionReport, LayerReport, ModelCompressor, StageConfig};
use super::svd_llm::whitened_truncate;
use super::whitening::{CalibStats, Whitener};
use super::{CompressedLayer, LinearWeight};
use crate::linalg::{svd, Mat};
use crate::model::transformer::Model;

/// Per-matrix view of the allocation problem.
pub struct DobiLayer<'a> {
    pub w: &'a Mat,
    pub stats: &'a CalibStats,
}

/// Allocation result: retained rank per matrix.
#[derive(Clone, Debug)]
pub struct DobiAllocation {
    pub ranks: Vec<usize>,
    pub lambda: f64,
}

/// Waterfill ranks across layers to meet a global CR (param budget
/// Σ rᵢ(mᵢ+nᵢ) ≤ (1−cr)·Σ mᵢnᵢ) minimizing total whitened tail energy.
pub fn allocate(layers: &[DobiLayer<'_>], target_cr: f64) -> DobiAllocation {
    // Whitened spectra.
    let spectra: Vec<Vec<f64>> = layers
        .iter()
        .map(|l| {
            let wh = Whitener::from_stats(l.stats);
            let wt = wh.whiten(l.w);
            svd::svd_thin(&wt).s.iter().map(|&x| x as f64).collect()
        })
        .collect();
    let costs: Vec<f64> = layers.iter().map(|l| (l.w.rows() + l.w.cols()) as f64).collect();
    let total_params: f64 = layers.iter().map(|l| (l.w.rows() * l.w.cols()) as f64).sum();
    let budget = (1.0 - target_cr) * total_params;

    let rank_at = |lambda: f64| -> Vec<usize> {
        spectra
            .iter()
            .zip(costs.iter())
            .map(|(sv, &c)| {
                let r = sv.iter().take_while(|&&s| s * s >= lambda * c).count();
                r.max(1)
            })
            .collect()
    };
    let params_of = |ranks: &[usize]| -> f64 {
        ranks.iter().zip(costs.iter()).map(|(&r, &c)| r as f64 * c).sum()
    };

    // Bisection over λ (λ=0 keeps everything).
    let mut lo = 0.0f64;
    let mut hi = spectra
        .iter()
        .zip(costs.iter())
        .map(|(sv, &c)| sv.first().map(|&s| s * s / c).unwrap_or(0.0))
        .fold(0.0, f64::max)
        * 2.0
        + 1e-12;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if params_of(&rank_at(mid)) > budget {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    DobiAllocation { ranks: rank_at(hi), lambda: hi }
}

/// Compress every layer at its allocated rank (whitened truncation, same
/// machinery as SVD-LLM but with the learned-equivalent ranks).
pub fn compress_all(layers: &[DobiLayer<'_>], alloc: &DobiAllocation) -> Vec<CompressedLayer> {
    layers
        .iter()
        .zip(alloc.ranks.iter())
        .map(|(l, &r)| {
            let wh = Whitener::from_stats(l.stats);
            let (b, c) = whitened_truncate(l.w, &wh, r);
            CompressedLayer::new("Dobi-SVD*", l.w, LinearWeight::LowRank { b, c }, Some(l.stats))
        })
        .collect()
}

/// Eq. 25 decomposition for the remapping variant: given a *target* CR and a
/// quantization bit-width, the factorization CR that remapping implies.
/// `cr_target = 1 − (1−cr_fact)·b/16  ⇒  cr_fact = 1 − (1−cr_target)·16/b`.
pub fn remapping_fact_cr(cr_target: f64, bits: u32) -> f64 {
    1.0 - (1.0 - cr_target) * 16.0 / bits as f64
}

/// Model-level Dobi-SVD*: loss-waterfilled rank allocation over all
/// projections, then whitened truncation (own allocator; the `StageConfig`
/// allocation policy does not apply).
pub struct DobiSvd;

impl ModelCompressor for DobiSvd {
    fn name(&self) -> String {
        "Dobi-SVD*".to_string()
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        api::ensure_calibration_aligned("Dobi-SVD*", model, ctx)?;
        let jobs = api::job_list(model);
        let mut layers = Vec::with_capacity(jobs.len());
        for (l, p, w) in &jobs {
            let stats = ctx.stats(*l, *p)?;
            anyhow::ensure!(
                stats.dim() == w.rows(),
                "Dobi-SVD*: layer {l} {p:?} calibration dim {} != weight rows {}",
                stats.dim(),
                w.rows()
            );
            layers.push(DobiLayer { w, stats });
        }
        let alloc = allocate(&layers, cfg.target_cr);
        let outs = compress_all(&layers, &alloc);

        let mut compressed = model.clone();
        let mut reports = Vec::with_capacity(jobs.len());
        for (&(layer, proj, _), out) in jobs.iter().zip(outs.into_iter()) {
            reports.push(LayerReport::measured(layer, proj, cfg.target_cr, &out, 0.0));
            api::set_proj(&mut compressed, layer, proj, out.weight);
        }
        let model_cr = api::model_cr_from_reports(&reports, &jobs);
        Ok((
            compressed,
            CompressionReport {
                method: self.name(),
                per_layer: reports,
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

/// Registry entry: `dobi` (no options).
pub fn registry_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "dobi",
        aliases: &["dobi-svd"],
        about: "Dobi-SVD*: loss-waterfilled rank allocation + whitened truncation",
        defaults: &[],
        build: |_| Ok(Box::new(DobiSvd)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn layers(seed: u64) -> (Vec<Mat>, Vec<CalibStats>) {
        let mut rng = Rng::new(seed);
        let shapes = [(16usize, 32usize), (32, 16), (24, 24)];
        let mut ws = Vec::new();
        let mut sts = Vec::new();
        for &(m, n) in &shapes {
            // Give layers different effective ranks so allocation is
            // non-uniform.
            let r_eff = m.min(n) / 2;
            let w = crate::linalg::gemm::matmul(
                &Mat::randn(&mut rng, m, r_eff, 1.0),
                &Mat::randn(&mut rng, r_eff, n, 1.0),
            )
            .add(&Mat::randn(&mut rng, m, n, 0.02));
            let x = Mat::randn(&mut rng, 4 * m, m, 1.0);
            ws.push(w);
            sts.push(CalibStats::from_activations(&x));
        }
        (ws, sts)
    }

    #[test]
    fn allocation_meets_budget() {
        let (ws, sts) = layers(130);
        let ls: Vec<DobiLayer> =
            ws.iter().zip(sts.iter()).map(|(w, s)| DobiLayer { w, stats: s }).collect();
        for &cr in &[0.2, 0.4, 0.6] {
            let alloc = allocate(&ls, cr);
            let params: usize = alloc
                .ranks
                .iter()
                .zip(ws.iter())
                .map(|(&r, w)| r * (w.rows() + w.cols()))
                .sum();
            let total: usize = ws.iter().map(|w| w.rows() * w.cols()).sum();
            assert!(
                params as f64 <= (1.0 - cr) * total as f64 * 1.02 + 200.0,
                "cr={cr}: params {params} vs budget {}",
                (1.0 - cr) * total as f64
            );
        }
    }

    #[test]
    fn allocation_is_nonuniform_for_heterogeneous_layers() {
        let (ws, sts) = layers(131);
        let ls: Vec<DobiLayer> =
            ws.iter().zip(sts.iter()).map(|(w, s)| DobiLayer { w, stats: s }).collect();
        let alloc = allocate(&ls, 0.4);
        // different shapes/spectra ⇒ not all keep-fractions equal
        let fracs: Vec<f64> = alloc
            .ranks
            .iter()
            .zip(ws.iter())
            .map(|(&r, w)| r as f64 / w.rows().min(w.cols()) as f64)
            .collect();
        let spread = fracs.iter().cloned().fold(0.0f64, f64::max)
            - fracs.iter().cloned().fold(1.0f64, f64::min);
        assert!(spread > 0.01, "fracs {fracs:?}");
    }

    #[test]
    fn remapping_cr_roundtrip() {
        // Paper: target 0.2 at 8-bit ⇒ fact CR −0.6.
        assert!((remapping_fact_cr(0.2, 8) + 0.6).abs() < 1e-12);
        assert!((remapping_fact_cr(0.6, 8) - 0.2).abs() < 1e-12);
        let back = super::super::composed_cr(remapping_fact_cr(0.35, 8), 8);
        assert!((back - 0.35).abs() < 1e-12);
    }

    #[test]
    fn compress_all_produces_lowrank() {
        let (ws, sts) = layers(132);
        let ls: Vec<DobiLayer> =
            ws.iter().zip(sts.iter()).map(|(w, s)| DobiLayer { w, stats: s }).collect();
        let alloc = allocate(&ls, 0.3);
        let out = compress_all(&ls, &alloc);
        assert_eq!(out.len(), 3);
        for l in &out {
            assert!(matches!(l.weight, LinearWeight::LowRank { .. }));
            assert!(l.func_err.unwrap().is_finite());
        }
    }
}
