//! SVD-LLM V2 baseline (Wang et al., 2025a), re-implemented from the code
//! listings in the COMPOT paper's Appendix A.10 (the official repo provides
//! no ready-to-run V2 — the COMPOT authors re-implemented it from the same
//! listings, and we follow their reproduction exactly):
//!
//! 1. `theoretical_loss`: whitened truncation loss of each matrix at the
//!    uniform target keep-ratio (listing 1);
//! 2. `cr_allocation`: per projection-type *group*, weight each layer by
//!    `1/ln(loss)` and distribute the group's total keep budget
//!    proportionally (listing 2);
//! 3. compress each matrix by whitened truncation at its allocated ratio.

use super::api::{self, CalibContext, CompressionReport, LayerReport, ModelCompressor, StageConfig};
use super::svd_llm::{truncation_loss, whitened_truncate};
use super::whitening::{CalibStats, Whitener};
use super::{CompressedLayer, LinearWeight};
use crate::linalg::Mat;
use crate::model::transformer::Model;

/// One projection matrix plus its group key (projection type, e.g. "q_proj").
pub struct V2Layer<'a> {
    pub w: &'a Mat,
    pub stats: &'a CalibStats,
    pub group: &'a str,
}

/// The listing's rank rule: `rank = m·n·keep/(m+n)` at keep-fraction `keep`.
fn rank_for_keep(m: usize, n: usize, keep: f64) -> usize {
    (((m * n) as f64 * keep / (m + n) as f64).floor() as usize).clamp(1, m.min(n))
}

/// Allocate per-matrix keep fractions (1 − crᵢ) under a global target CR,
/// following Appendix A.10 listing 2: within each projection-type group,
/// keepᵢ ∝ 1/ln(lossᵢ), scaled so the group average equals the global keep.
pub fn allocate_v2(layers: &[V2Layer<'_>], target_cr: f64) -> Vec<f64> {
    let keep_target = 1.0 - target_cr;
    let mut keeps = vec![keep_target; layers.len()];

    // Group indices by projection type.
    let mut groups: std::collections::BTreeMap<&str, Vec<usize>> = Default::default();
    for (i, l) in layers.iter().enumerate() {
        groups.entry(l.group).or_default().push(i);
    }

    for (_, idxs) in groups {
        // Theoretical losses at the uniform keep fraction.
        let losses: Vec<f64> = idxs
            .iter()
            .map(|&i| {
                let l = &layers[i];
                let wh = Whitener::from_stats(l.stats);
                let r = rank_for_keep(l.w.rows(), l.w.cols(), keep_target);
                truncation_loss(l.w, &wh, r).max(1e-12)
            })
            .collect();
        // Listing 2: L_G ← 1/log(L_G); R_d = len·target_cr·L_G/Σ L_G — these
        // are *compression* (removal) ratios: a lossier (more sensitive)
        // matrix gets a smaller weight and is therefore compressed less.
        // Guard log ≤ 0 (loss ≤ 1) by offsetting into the monotone region —
        // the paper notes "multiple ambiguities" in the original listing and
        // we document this choice (losses are scale-dependent).
        let weights: Vec<f64> =
            losses.iter().map(|&l| 1.0 / (l + std::f64::consts::E).ln()).collect();
        let wsum: f64 = weights.iter().sum();
        for (j, &i) in idxs.iter().enumerate() {
            let cr_i = (idxs.len() as f64 * target_cr * weights[j] / wsum).clamp(0.02, 0.98);
            keeps[i] = 1.0 - cr_i;
        }
    }
    keeps
}

/// Compress every layer by whitened truncation at its allocated keep
/// fraction.
pub fn compress_all_v2(layers: &[V2Layer<'_>], keeps: &[f64]) -> Vec<CompressedLayer> {
    layers
        .iter()
        .zip(keeps.iter())
        .map(|(l, &keep)| {
            let wh = Whitener::from_stats(l.stats);
            let r = rank_for_keep(l.w.rows(), l.w.cols(), keep);
            let (b, c) = whitened_truncate(l.w, &wh, r);
            CompressedLayer::new("SVD-LLM V2", l.w, LinearWeight::LowRank { b, c }, Some(l.stats))
        })
        .collect()
}

/// Model-level V2: allocates its own keep fractions per projection-type
/// group (the `StageConfig` allocation policy does not apply).
pub struct SvdLlmV2;

impl ModelCompressor for SvdLlmV2 {
    fn name(&self) -> String {
        "SVD-LLM V2".to_string()
    }

    fn compress(
        &self,
        model: &Model,
        ctx: &CalibContext<'_>,
        cfg: &StageConfig,
    ) -> anyhow::Result<(Model, CompressionReport)> {
        api::ensure_calibration_aligned("SVD-LLM V2", model, ctx)?;
        let jobs = api::job_list(model);
        let mut layers = Vec::with_capacity(jobs.len());
        for (l, p, w) in &jobs {
            let stats = ctx.stats(*l, *p)?;
            anyhow::ensure!(
                stats.dim() == w.rows(),
                "SVD-LLM V2: layer {l} {p:?} calibration dim {} != weight rows {}",
                stats.dim(),
                w.rows()
            );
            layers.push(V2Layer { w, stats, group: p.group() });
        }
        let keeps = allocate_v2(&layers, cfg.target_cr);
        let outs = compress_all_v2(&layers, &keeps);

        let mut compressed = model.clone();
        let mut reports = Vec::with_capacity(jobs.len());
        for ((&(layer, proj, _), &keep), out) in
            jobs.iter().zip(keeps.iter()).zip(outs.into_iter())
        {
            reports.push(LayerReport::measured(layer, proj, 1.0 - keep, &out, 0.0));
            api::set_proj(&mut compressed, layer, proj, out.weight);
        }
        let model_cr = api::model_cr_from_reports(&reports, &jobs);
        Ok((
            compressed,
            CompressionReport {
                method: self.name(),
                per_layer: reports,
                model_cr,
                wall_secs: 0.0,
            },
        ))
    }
}

/// Registry entry: `svd-llm-v2` (no options).
pub fn registry_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "svd-llm-v2",
        aliases: &["v2"],
        about: "SVD-LLM V2: per-group theoretical-loss rank allocation (A.10)",
        defaults: &[],
        build: |_| Ok(Box::new(SvdLlmV2)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn layers(seed: u64) -> (Vec<Mat>, Vec<CalibStats>, Vec<&'static str>) {
        let mut rng = Rng::new(seed);
        let specs = [
            (16usize, 32usize, "q_proj"),
            (16, 32, "q_proj"),
            (16, 48, "up_proj"),
            (16, 48, "up_proj"),
        ];
        let mut ws = Vec::new();
        let mut sts = Vec::new();
        let mut gs = Vec::new();
        for &(m, n, g) in &specs {
            // vary effective rank across layers within a group
            let r = 2 + (ws.len() * 3) % (m / 2);
            let w = crate::linalg::gemm::matmul(
                &Mat::randn(&mut rng, m, r, 1.0),
                &Mat::randn(&mut rng, r, n, 1.0),
            )
            .add(&Mat::randn(&mut rng, m, n, 0.05));
            let x = Mat::randn(&mut rng, 4 * m, m, 1.0);
            ws.push(w);
            sts.push(CalibStats::from_activations(&x));
            gs.push(g);
        }
        (ws, sts, gs)
    }

    #[test]
    fn group_average_keep_matches_target() {
        let (ws, sts, gs) = layers(140);
        let ls: Vec<V2Layer> = ws
            .iter()
            .zip(sts.iter())
            .zip(gs.iter())
            .map(|((w, s), g)| V2Layer { w, stats: s, group: g })
            .collect();
        let keeps = allocate_v2(&ls, 0.3);
        // Each group's mean keep ≈ 0.7 (modulo the clamp).
        let q_mean = (keeps[0] + keeps[1]) / 2.0;
        let up_mean = (keeps[2] + keeps[3]) / 2.0;
        assert!((q_mean - 0.7).abs() < 0.05, "{keeps:?}");
        assert!((up_mean - 0.7).abs() < 0.05, "{keeps:?}");
    }

    #[test]
    fn lossier_layers_keep_more() {
        let (ws, sts, gs) = layers(141);
        let ls: Vec<V2Layer> = ws
            .iter()
            .zip(sts.iter())
            .zip(gs.iter())
            .map(|((w, s), g)| V2Layer { w, stats: s, group: g })
            .collect();
        let keeps = allocate_v2(&ls, 0.3);
        // within q_proj group: the layer with higher theoretical loss (higher
        // effective rank) gets more keep
        let loss = |i: usize| {
            let wh = Whitener::from_stats(&sts[i]);
            truncation_loss(&ws[i], &wh, rank_for_keep(16, 32, 0.7))
        };
        if loss(0) > loss(1) {
            assert!(keeps[0] >= keeps[1]);
        } else {
            assert!(keeps[1] >= keeps[0]);
        }
    }

    #[test]
    fn compress_all_is_lowrank_and_finite() {
        let (ws, sts, gs) = layers(142);
        let ls: Vec<V2Layer> = ws
            .iter()
            .zip(sts.iter())
            .zip(gs.iter())
            .map(|((w, s), g)| V2Layer { w, stats: s, group: g })
            .collect();
        let keeps = allocate_v2(&ls, 0.25);
        let out = compress_all_v2(&ls, &keeps);
        for l in &out {
            assert!(matches!(l.weight, LinearWeight::LowRank { .. }));
            assert!(l.func_err.unwrap().is_finite());
        }
    }
}
