//! Non-whitened SVD-family baselines: plain truncated SVD, FWSVD
//! (Fisher-weighted, Hsu et al. 2022) and ASVD (activation-aware scaling,
//! Yuan et al. 2023). Used by Table 18 and as sanity lower bounds.

use super::whitening::CalibStats;
use super::{rank_for_cr, CompressedLayer, Compressor, LinearWeight};
use crate::linalg::{svd, Mat};
use crate::util::Rng;

/// Plain truncated SVD of W — Frobenius-optimal, calibration-blind.
#[derive(Clone, Copy, Debug, Default)]
pub struct TruncatedSvd;

impl Compressor for TruncatedSvd {
    fn name(&self) -> &'static str {
        "SVD"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        _rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let r = rank_for_cr(w.rows(), w.cols(), target_cr);
        let decomp = svd::svd_thin(w);
        let (b, c) = decomp.truncate(r);
        Ok(CompressedLayer::new("SVD", w, LinearWeight::LowRank { b, c }, Some(stats)))
    }
}

/// Row-scaled truncation shared by FWSVD and ASVD: truncate `diag(t)·W`,
/// return `B = diag(t)⁻¹·U_rΣ_r`, `C = V_rᵀ`.
fn scaled_truncate(w: &Mat, scale: &[f32], r: usize) -> (Mat, Mat) {
    let m = w.rows();
    assert_eq!(scale.len(), m);
    let mut sw = w.clone();
    for i in 0..m {
        let t = scale[i].max(1e-6);
        for x in sw.row_mut(i) {
            *x *= t;
        }
    }
    let decomp = svd::svd_thin(&sw);
    let (mut b, c) = decomp.truncate(r);
    for i in 0..m {
        let t = scale[i].max(1e-6);
        for x in b.row_mut(i) {
            *x /= t;
        }
    }
    (b, c)
}

/// FWSVD — weights the reconstruction by (a diagonal proxy of) the Fisher
/// information. Without gradients, the standard proxy is the activation
/// second moment per input feature (same signal SVD-LLM whitens by, but
/// diagonal-only), which is what we use.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fwsvd;

impl Compressor for Fwsvd {
    fn name(&self) -> &'static str {
        "FWSVD"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        _rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let r = rank_for_cr(w.rows(), w.cols(), target_cr);
        let fisher = stats.feature_rms(); // ∝ sqrt(E[x_i²])
        let (b, c) = scaled_truncate(w, &fisher, r);
        Ok(CompressedLayer::new("FWSVD", w, LinearWeight::LowRank { b, c }, Some(stats)))
    }
}

/// ASVD — scales rows by activation magnitude raised to α (paper uses
/// α = 0.5) before truncation.
#[derive(Clone, Copy, Debug)]
pub struct Asvd {
    pub alpha: f32,
}

impl Default for Asvd {
    fn default() -> Self {
        Asvd { alpha: 0.5 }
    }
}

impl Compressor for Asvd {
    fn name(&self) -> &'static str {
        "ASVD"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        _rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let r = rank_for_cr(w.rows(), w.cols(), target_cr);
        let scale: Vec<f32> = stats
            .feature_rms()
            .iter()
            .map(|&x| x.max(1e-6).powf(self.alpha))
            .collect();
        let (b, c) = scaled_truncate(w, &scale, r);
        Ok(CompressedLayer::new("ASVD", w, LinearWeight::LowRank { b, c }, Some(stats)))
    }
}

/// Registry entry: `svd` — plain truncated SVD (no options).
pub fn truncated_svd_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "svd",
        aliases: &[],
        about: "plain truncated SVD (no calibration)",
        defaults: &[],
        build: |_| Ok(Box::new(crate::compress::PerMatrix::new("SVD", TruncatedSvd))),
    }
}

/// Registry entry: `fwsvd` — Fisher-weighted SVD (no options).
pub fn fwsvd_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "fwsvd",
        aliases: &[],
        about: "FWSVD: Fisher/row-importance weighted truncated SVD",
        defaults: &[],
        build: |_| Ok(Box::new(crate::compress::PerMatrix::new("FWSVD", Fwsvd))),
    }
}

/// Registry entry: `asvd` with option `alpha` (activation-scaling exponent).
pub fn asvd_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "asvd",
        aliases: &[],
        about: "ASVD: activation-scaled truncated SVD",
        defaults: &[],
        build: |o| {
            let mut asvd = Asvd::default();
            if let Some(v) = o.get_f64("alpha")? {
                asvd.alpha = v as f32;
            }
            Ok(Box::new(crate::compress::PerMatrix::new("ASVD", asvd)))
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::svd_llm::SvdLlm;

    fn problem(seed: u64) -> (Mat, CalibStats) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, 24, 40, 1.0);
        let mut x = Mat::randn(&mut rng, 200, 24, 1.0);
        for i in 0..200 {
            for j in 0..24 {
                x[(i, j)] *= 1.0 + 5.0 * (j as f32 / 24.0);
            }
        }
        (w, CalibStats::from_activations(&x))
    }

    #[test]
    fn all_achieve_target_cr() {
        let (w, stats) = problem(110);
        let mut rng = Rng::new(1);
        let methods: Vec<Box<dyn Compressor>> =
            vec![Box::new(TruncatedSvd), Box::new(Fwsvd), Box::new(Asvd::default())];
        for m in &methods {
            let layer = m.compress(&w, &stats, 0.3, &mut rng).unwrap();
            assert!(layer.cr >= 0.3 - 1e-9, "{}", m.name());
        }
    }

    #[test]
    fn plain_svd_is_weight_optimal() {
        // Plain SVD minimizes weight error; data-aware variants trade it away.
        let (w, stats) = problem(111);
        let mut rng = Rng::new(2);
        let plain = TruncatedSvd.compress(&w, &stats, 0.4, &mut rng).unwrap();
        let fw = Fwsvd.compress(&w, &stats, 0.4, &mut rng).unwrap();
        let asvd = Asvd::default().compress(&w, &stats, 0.4, &mut rng).unwrap();
        assert!(plain.weight_err <= fw.weight_err * 1.001);
        assert!(plain.weight_err <= asvd.weight_err * 1.001);
    }

    #[test]
    fn data_aware_beats_plain_on_functional_error() {
        let (w, stats) = problem(112);
        let mut rng = Rng::new(3);
        let plain = TruncatedSvd.compress(&w, &stats, 0.4, &mut rng).unwrap();
        let asvd = Asvd::default().compress(&w, &stats, 0.4, &mut rng).unwrap();
        let svdllm = SvdLlm.compress(&w, &stats, 0.4, &mut rng).unwrap();
        assert!(asvd.func_err.unwrap() <= plain.func_err.unwrap() * 1.01);
        // Full whitening dominates diagonal scaling.
        assert!(svdllm.func_err.unwrap() <= asvd.func_err.unwrap() * 1.001);
    }
}
