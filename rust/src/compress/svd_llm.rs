//! SVD-LLM baseline (Wang et al., 2025b): truncation-aware data whitening.
//!
//! The key insight of SVD-LLM is that truncating the SVD of the *whitened*
//! weight `W̃ = Lᵀ·W` (L the Cholesky factor of the calibration Gram) makes
//! the discarded singular values exactly equal to the incurred functional
//! loss, and the optimal compressed weight has the closed form
//! `Ŵ = L^{-ᵀ}·U_r·Σ_r·V_rᵀ`. Stored as `B = L^{-ᵀ}·U_r·Σ_r` (m×r) and
//! `C = V_rᵀ` (r×n).

use super::whitening::{CalibStats, Whitener};
use super::{rank_for_cr, CompressedLayer, Compressor, LinearWeight};
use crate::linalg::{svd, Mat};
use crate::util::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct SvdLlm;

/// Whitened truncation at an explicit rank (shared with `svd_llm_v2` and
/// `dobi`). Returns (B, C) such that Ŵ = B·C.
pub fn whitened_truncate(w: &Mat, whitener: &Whitener, r: usize) -> (Mat, Mat) {
    let wt = whitener.whiten(w);
    let decomp = svd::svd_thin(&wt);
    let (u_sig, vt) = decomp.truncate(r);
    let b = whitener.dewhiten(&u_sig);
    (b, vt)
}

/// The whitened truncation loss ‖W̃ − (W̃)_r‖_F — the theoretical loss of
/// SVD-LLM V2 (Appendix A.10 `theoretical_loss`), reused by the dynamic
/// allocators.
pub fn truncation_loss(w: &Mat, whitener: &Whitener, r: usize) -> f64 {
    let wt = whitener.whiten(w);
    let decomp = svd::svd_thin(&wt);
    let tail: f64 = decomp.s[r.min(decomp.s.len())..]
        .iter()
        .map(|&s| (s as f64) * (s as f64))
        .sum();
    tail.sqrt()
}

impl Compressor for SvdLlm {
    fn name(&self) -> &'static str {
        "SVD-LLM"
    }

    fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        target_cr: f64,
        _rng: &mut Rng,
    ) -> anyhow::Result<CompressedLayer> {
        let (m, n) = w.shape();
        let r = rank_for_cr(m, n, target_cr);
        let whitener = Whitener::from_stats(stats);
        let (b, c) = whitened_truncate(w, &whitener, r);
        Ok(CompressedLayer::new(
            "SVD-LLM",
            w,
            LinearWeight::LowRank { b, c },
            Some(stats),
        ))
    }
}

/// Registry entry: `svd-llm` (no options).
pub fn registry_entry() -> crate::compress::registry::MethodEntry {
    crate::compress::registry::MethodEntry {
        name: "svd-llm",
        aliases: &["svdllm"],
        about: "SVD-LLM: whitened truncation with closed-form update",
        defaults: &[],
        build: |_| Ok(Box::new(crate::compress::PerMatrix::new("SVD-LLM", SvdLlm))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;

    fn problem(seed: u64, m: usize, n: usize) -> (Mat, CalibStats) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(&mut rng, m, n, 1.0);
        let mut x = Mat::randn(&mut rng, 6 * m, m, 1.0);
        for i in 0..x.rows() {
            for j in 0..m {
                x[(i, j)] *= 1.0 + 3.0 * (j as f32 / m as f32); // anisotropy
            }
        }
        (w, CalibStats::from_activations(&x))
    }

    #[test]
    fn achieves_target_cr() {
        let (w, stats) = problem(100, 32, 64);
        let mut rng = Rng::new(1);
        for &cr in &[0.2, 0.4, 0.6] {
            let layer = SvdLlm.compress(&w, &stats, cr, &mut rng).unwrap();
            assert!(layer.cr >= cr - 1e-9, "cr {} < {cr}", layer.cr);
        }
    }

    #[test]
    fn beats_plain_svd_on_functional_error() {
        // Whitening must reduce ‖X(W−Ŵ)‖ vs truncating W directly when the
        // Gram is anisotropic.
        let (w, stats) = problem(101, 24, 36);
        let mut rng = Rng::new(2);
        let data_aware = SvdLlm.compress(&w, &stats, 0.4, &mut rng).unwrap();
        let r = rank_for_cr(24, 36, 0.4);
        let plain = {
            let decomp = svd::svd_thin(&w);
            let (b, c) = decomp.truncate(r);
            CompressedLayer::new("svd", &w, LinearWeight::LowRank { b, c }, Some(&stats))
        };
        assert!(data_aware.func_err.unwrap() <= plain.func_err.unwrap() * 1.001);
    }

    #[test]
    fn truncation_loss_matches_functional_error() {
        // ‖X(W−Ŵ)‖_F == tail singular energy of W̃ (SVD-LLM's core identity).
        let (w, stats) = problem(102, 20, 28);
        let whitener = Whitener::from_stats(&stats);
        let r = 7;
        let (b, c) = whitened_truncate(&w, &whitener, r);
        let w_hat = gemm::matmul(&b, &c);
        let func = stats.functional_err(&w, &w_hat);
        let theo = truncation_loss(&w, &whitener, r);
        assert!((func - theo).abs() / theo.max(1e-9) < 2e-2, "func={func} theo={theo}");
    }

    #[test]
    fn loss_decreases_with_rank() {
        let (w, stats) = problem(103, 16, 16);
        let whitener = Whitener::from_stats(&stats);
        let losses: Vec<f64> = (1..16).map(|r| truncation_loss(&w, &whitener, r)).collect();
        for pair in losses.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-9);
        }
    }
}
