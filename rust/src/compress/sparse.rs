//! Column-s-sparse coefficient storage — the `S_O` half of the COMPOT
//! factorization. Matches the paper's storage model (Eq. 11): non-zero
//! values at 16 bits each plus a 1-bit position mask over the full k×n grid.
//!
//! Layout: exactly `s` (index, value) pairs per column, column-major
//! concatenation, indices sorted ascending within a column. The regular
//! structure keeps [`apply_after`] branch-free in the hot loop.

use crate::linalg::qmat::{self, QuantMat};
use crate::linalg::{Mat, WeightBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ColumnSparse {
    k: usize,
    n: usize,
    s: usize,
    /// len = n·s; idx[j·s + t] = row index of the t-th nonzero of column j.
    /// Owned, or a zero-copy view into a checkpoint mapping.
    idx: WeightBuf<u32>,
    /// len = n·s; matching values.
    val: WeightBuf<f32>,
}

impl ColumnSparse {
    /// Build from a dense k×n matrix by keeping, per column, the `s` entries
    /// of largest magnitude (the hard-thresholding operator H_s, Eq. 9).
    /// Ties are broken by lower row index (deterministic; the paper notes
    /// ties can be broken arbitrarily without losing optimality).
    pub fn hard_threshold(z: &Mat, s: usize) -> ColumnSparse {
        // Work on Zᵀ so each column of Z is a contiguous row.
        Self::hard_threshold_zt(&z.transpose(), s)
    }

    /// Same as [`hard_threshold`] but takes Zᵀ (n×k) directly — the COMPOT
    /// inner loop computes W̃ᵀ·D = Zᵀ natively, so this avoids two transpose
    /// copies per iteration on the hot path.
    pub fn hard_threshold_zt(zt: &Mat, s: usize) -> ColumnSparse {
        let (n, k) = zt.shape();
        // s is clamped to k (keeping more entries than a column has is the
        // identity); s = 0 or an empty matrix degenerates to the all-zero
        // sparse map — both must work, the allocator can produce them at
        // extreme CRs.
        let s = s.min(k);
        if s == 0 || n == 0 {
            return ColumnSparse { k, n, s, idx: WeightBuf::default(), val: WeightBuf::default() };
        }
        let mut idx = vec![0u32; n * s];
        let mut val = vec![0f32; n * s];
        let mut order: Vec<u32> = Vec::with_capacity(k);
        for j in 0..n {
            let row = zt.row(j);
            order.clear();
            order.extend(0..k as u32);
            // Partial selection of the s largest |z|. total_cmp keeps the
            // comparator a total order even on NaN/±0 inputs, so selection
            // cannot panic on degenerate calibration data.
            let (top, _, _) = order.select_nth_unstable_by(s - 1, |&a, &b| {
                let ma = row[a as usize].abs();
                let mb = row[b as usize].abs();
                mb.total_cmp(&ma).then(a.cmp(&b))
            });
            let mut chosen: Vec<u32> = top.to_vec();
            chosen.push(order[s - 1]);
            chosen.truncate(s);
            chosen.sort_unstable();
            for (t, &i) in chosen.iter().enumerate() {
                idx[j * s + t] = i;
                val[j * s + t] = row[i as usize];
            }
        }
        ColumnSparse { k, n, s, idx: idx.into(), val: val.into() }
    }

    /// Build from explicit per-column (index, value) lists (CoSpaDi/OMP).
    /// The lists come from numeric solvers, so malformed shapes are errors
    /// rather than panics (and `compot audit` rule L5 holds this module's
    /// buffer-consuming constructors to the fallible signature).
    pub fn from_columns(
        k: usize,
        n: usize,
        s: usize,
        cols: Vec<Vec<(u32, f32)>>,
    ) -> anyhow::Result<ColumnSparse> {
        anyhow::ensure!(cols.len() == n, "got {} columns, want n = {n}", cols.len());
        let mut idx = vec![0u32; n * s];
        let mut val = vec![0f32; n * s];
        for (j, col) in cols.into_iter().enumerate() {
            anyhow::ensure!(col.len() <= s, "column {j} has more than s = {s} nonzeros");
            let mut col = col;
            col.sort_unstable_by_key(|&(i, _)| i);
            for (t, (i, v)) in col.into_iter().enumerate() {
                anyhow::ensure!((i as usize) < k, "column {j} index {i} out of range (k={k})");
                idx[j * s + t] = i;
                val[j * s + t] = v;
            }
            // remaining slots stay (0, 0.0) — harmless padding
        }
        Ok(ColumnSparse { k, n, s, idx: idx.into(), val: val.into() })
    }

    pub fn k(&self) -> usize {
        self.k
    }
    pub fn n(&self) -> usize {
        self.n
    }
    pub fn s(&self) -> usize {
        self.s
    }

    /// Storage bits per Eq. 11: 16 bits per value + 1-bit mask over k×n.
    pub fn storage_bits(&self) -> u64 {
        (16 * self.s * self.n + self.k * self.n) as u64
    }

    /// Densify to k×n.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.k, self.n);
        for j in 0..self.n {
            for t in 0..self.s {
                let i = self.idx[j * self.s + t] as usize;
                let v = self.val[j * self.s + t];
                if v != 0.0 {
                    m[(i, j)] = v;
                }
            }
        }
        m
    }

    /// Given T = x·A (rows×k), compute T·S (rows×n) without densifying:
    /// out[r, j] = Σ_t T[r, idx[j,t]] · val[j,t].
    ///
    /// **Perf (EXPERIMENTS.md §Perf):** for multi-row batches the gather
    /// per output element defeats vectorization; instead work in the
    /// transposed layout — `outᵀ[j,:] += val · Tᵀ[idx,:]` is a contiguous
    /// axpy over the batch dimension. The two transpose copies are O(rows·k
    /// + rows·n), negligible next to the O(rows·s·n) product.
    pub fn apply_after(&self, t: &Mat) -> Mat {
        assert_eq!(t.cols(), self.k, "apply_after: inner dim");
        let rows = t.rows();
        let s = self.s;
        let (idx, val) = (self.idx.as_slice(), self.val.as_slice());
        if rows >= 4 {
            let tt = t.transpose(); // k×rows, row i = feature i over batch
            let mut out_t = Mat::zeros(self.n, rows);
            for j in 0..self.n {
                let base = j * s;
                let orow = out_t.row_mut(j);
                for tti in 0..s {
                    let v = val[base + tti];
                    if v == 0.0 {
                        continue;
                    }
                    let trow = tt.row(idx[base + tti] as usize);
                    for (o, x) in orow.iter_mut().zip(trow.iter()) {
                        *o += v * *x;
                    }
                }
            }
            return out_t.transpose();
        }
        let mut out = Mat::zeros(rows, self.n);
        for r in 0..rows {
            self.gather_row_into(t.row(r), out.row_mut(r));
        }
        out
    }

    /// Single-row [`apply_after`]: y = t·S for one activation row (len k).
    /// The compressed-native decode step of the `S_O` half — one token's
    /// output features via an s-wide gather per column, never densifying.
    pub fn apply_after_row(&self, t: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.n];
        self.gather_row_into(t, &mut out);
        out
    }

    /// Shared row kernel: overwrite `out` (len n) with t·S. Writes every
    /// slot, so callers need no zero-init of their own.
    fn gather_row_into(&self, t: &[f32], out: &mut [f32]) {
        assert_eq!(t.len(), self.k, "apply_after_row: inner dim");
        debug_assert_eq!(out.len(), self.n);
        let s = self.s;
        let (idx, val) = (self.idx.as_slice(), self.val.as_slice());
        for (j, o) in out.iter_mut().enumerate() {
            let base = j * s;
            let mut acc = 0f32;
            for tti in 0..s {
                acc += t[idx[base + tti] as usize] * val[base + tti];
            }
            *o = acc;
        }
    }

    /// Squared Frobenius norm (used by the free error identity
    /// ‖W̃−DS‖² = ‖W̃‖² − ‖S‖² under orthonormal D).
    pub fn fro_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Mᵀ = S·W̃ᵀ accumulation helper for the Procrustes step: given W̃ᵀ
    /// (n×m), returns Mᵀ = S·W̃ᵀ (k×m) exploiting column sparsity:
    /// Mᵀ[i, :] += val · W̃ᵀ[j, :] for each nonzero (i, val) of column j.
    pub fn mt_product(&self, wt_t: &Mat) -> Mat {
        assert_eq!(wt_t.rows(), self.n, "mt_product: W̃ᵀ rows");
        let m = wt_t.cols();
        let (idx, val) = (self.idx.as_slice(), self.val.as_slice());
        let mut mt = Mat::zeros(self.k, m);
        for j in 0..self.n {
            let wrow = wt_t.row(j);
            for t in 0..self.s {
                let i = idx[j * self.s + t] as usize;
                let v = val[j * self.s + t];
                if v == 0.0 {
                    continue;
                }
                let mrow = mt.row_mut(i);
                for (mx, wx) in mrow.iter_mut().zip(wrow.iter()) {
                    *mx += v * *wx;
                }
            }
        }
        mt
    }

    /// Iterate (row, col, value) of stored nonzeros (including padded zeros).
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        (0..self.n).flat_map(move |j| {
            (0..self.s).map(move |t| {
                (self.idx[j * self.s + t] as usize, j, self.val[j * self.s + t])
            })
        })
    }

    /// Map stored values in place (used by quantization composition).
    /// Copy-on-write: a mapped buffer materializes first.
    pub fn map_values(&mut self, mut f: impl FnMut(f32) -> f32) {
        for v in self.val.make_mut().iter_mut() {
            *v = f(*v);
        }
    }

    /// Overwrite stored values wholesale (quantization composition).
    pub fn set_values(&mut self, vals: &[f32]) {
        assert_eq!(vals.len(), self.val.len());
        self.val.make_mut().copy_from_slice(vals);
    }

    pub fn values(&self) -> &[f32] {
        self.val.as_slice()
    }

    /// Raw row indices (len n·s, column-major, ascending within a column) —
    /// what a CPT2 checkpoint writes and reads back verbatim.
    pub fn indices(&self) -> &[u32] {
        self.idx.as_slice()
    }

    /// Reassemble from raw checkpoint buffers — owned or zero-copy mapped —
    /// validating the layout invariants (lengths, s ≤ k, every index < k):
    /// the buffers come from disk, so violations are errors, not panics.
    pub fn from_raw_parts(
        k: usize,
        n: usize,
        s: usize,
        idx: impl Into<WeightBuf<u32>>,
        val: impl Into<WeightBuf<f32>>,
    ) -> anyhow::Result<ColumnSparse> {
        let (idx, val) = (idx.into(), val.into());
        anyhow::ensure!(s <= k, "sparse map s={s} exceeds k={k}");
        let want = n
            .checked_mul(s)
            .ok_or_else(|| anyhow::anyhow!("sparse map n·s overflows (n={n}, s={s})"))?;
        anyhow::ensure!(
            idx.len() == want && val.len() == want,
            "sparse map buffers ({} idx, {} val) do not match n·s = {want}",
            idx.len(),
            val.len()
        );
        anyhow::ensure!(
            idx.as_slice().iter().all(|&i| (i as usize) < k),
            "sparse map index out of range (k={k})"
        );
        Ok(ColumnSparse { k, n, s, idx, val })
    }

    /// Heap bytes actually resident (mapped buffers count 0).
    pub fn resident_bytes(&self) -> usize {
        self.val.resident_bytes() + self.idx.resident_bytes()
    }

    /// Bytes borrowed from a checkpoint mapping.
    pub fn mapped_bytes(&self) -> usize {
        self.val.mapped_bytes() + self.idx.mapped_bytes()
    }
}

/// Packed-quantized [`ColumnSparse`]: same `(index, value)` layout, but the
/// values live b-bit packed in a [`QuantMat`] whose row `j` holds column
/// `j`'s `s` values. Quantization groups therefore **never straddle column
/// boundaries** — one column's outlier cannot poison its neighbors' scales,
/// and the scale count is `n·⌈s/128⌉` (accounted by the `QuantMat`'s
/// measured storage).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantColumnSparse {
    k: usize,
    /// len = n·s, same layout as [`ColumnSparse::idx`].
    idx: WeightBuf<u32>,
    /// n×s: row j = quantized values of column j (column-aligned groups).
    val: QuantMat,
}

impl QuantColumnSparse {
    /// Quantize a sparse map's values to `bits` at the default group size,
    /// column-aligned.
    pub fn quantize_from(cs: &ColumnSparse, bits: u32) -> QuantColumnSparse {
        Self::quantize_from_grouped(cs, bits, qmat::GROUP)
    }

    /// Quantize with an explicit group size. Groups still never straddle
    /// column boundaries — the value matrix is n×s with per-row groups, and
    /// each row is one column of the sparse map.
    pub fn quantize_from_grouped(cs: &ColumnSparse, bits: u32, group: usize) -> QuantColumnSparse {
        let vmat = Mat::from_vec(cs.n, cs.s, cs.val.as_slice().to_vec());
        QuantColumnSparse {
            k: cs.k,
            idx: cs.idx.clone(),
            val: QuantMat::quantize_from_grouped(&vmat, bits, group),
        }
    }

    /// Re-encode the packed value matrix in `layout` (see
    /// [`QuantMat::with_layout`]); indices and stored values are unchanged.
    pub fn with_layout(&self, layout: qmat::QuantLayout) -> QuantColumnSparse {
        QuantColumnSparse { k: self.k, idx: self.idx.clone(), val: self.val.with_layout(layout) }
    }

    /// Fake-quant f32 form — bit-identical values to what the packed apply
    /// kernels compute with.
    pub fn dequantize(&self) -> ColumnSparse {
        ColumnSparse {
            k: self.k,
            n: self.n(),
            s: self.s(),
            idx: self.idx.clone(),
            val: self.val.dequantize().into_data().into(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.val.rows()
    }

    pub fn s(&self) -> usize {
        self.val.cols()
    }

    pub fn bits(&self) -> u32 {
        self.val.bits()
    }

    /// Fused-dequant T·S (mirrors [`ColumnSparse::apply_after`]'s
    /// accumulation exactly, dequantizing one column's values at a time —
    /// bit-identical to `self.dequantize().apply_after(t)`).
    pub fn apply_after(&self, t: &Mat) -> Mat {
        assert_eq!(t.cols(), self.k, "apply_after: inner dim");
        let rows = t.rows();
        let (n, s) = (self.n(), self.s());
        let idx = self.idx.as_slice();
        if rows >= 4 {
            let tt = t.transpose();
            let mut out_t = Mat::zeros(n, rows);
            let mut vbuf = vec![0f32; s];
            for j in 0..n {
                self.val.dequant_row_into(j, &mut vbuf);
                let base = j * s;
                let orow = out_t.row_mut(j);
                for (tti, &v) in vbuf.iter().enumerate() {
                    if v == 0.0 {
                        continue;
                    }
                    let trow = tt.row(idx[base + tti] as usize);
                    for (o, x) in orow.iter_mut().zip(trow.iter()) {
                        *o += v * *x;
                    }
                }
            }
            return out_t.transpose();
        }
        let mut out = Mat::zeros(rows, n);
        for r in 0..rows {
            self.gather_row_into(t.row(r), out.row_mut(r));
        }
        out
    }

    /// Single-row fused-dequant gather — the packed-native decode step of
    /// the `S_O` half.
    pub fn apply_after_row(&self, t: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.n()];
        self.gather_row_into(t, &mut out);
        out
    }

    /// Mirrors `ColumnSparse::gather_row_into`: same accumulation order,
    /// values dequantized per column on the fly.
    fn gather_row_into(&self, t: &[f32], out: &mut [f32]) {
        assert_eq!(t.len(), self.k, "apply_after_row: inner dim");
        debug_assert_eq!(out.len(), self.n());
        let s = self.s();
        let idx = self.idx.as_slice();
        let mut vbuf = vec![0f32; s];
        for (j, o) in out.iter_mut().enumerate() {
            self.val.dequant_row_into(j, &mut vbuf);
            let base = j * s;
            let mut acc = 0f32;
            for (tti, &v) in vbuf.iter().enumerate() {
                acc += t[idx[base + tti] as usize] * v;
            }
            *o = acc;
        }
    }

    /// Storage bits: packed values + scales *measured from the buffers*,
    /// plus the paper's 1-bit k×n position mask (Eq. 11 — the storable
    /// format for the sparsity pattern).
    pub fn storage_bits(&self) -> u64 {
        self.val.storage_bits() + (self.k * self.n()) as u64
    }

    /// Raw row indices (len n·s, same layout as [`ColumnSparse::indices`]).
    pub fn indices(&self) -> &[u32] {
        self.idx.as_slice()
    }

    /// The packed n×s value matrix (row j = column j's quantized values).
    pub fn values_qmat(&self) -> &QuantMat {
        &self.val
    }

    /// Reassemble from raw checkpoint buffers (owned or mapped): `val` row
    /// count is n, its column count is s. Validates the same invariants as
    /// [`ColumnSparse::from_raw_parts`].
    pub fn from_raw_parts(
        k: usize,
        idx: impl Into<WeightBuf<u32>>,
        val: QuantMat,
    ) -> anyhow::Result<QuantColumnSparse> {
        let idx = idx.into();
        let (n, s) = val.shape();
        anyhow::ensure!(s <= k, "quantized sparse map s={s} exceeds k={k}");
        let want = n
            .checked_mul(s)
            .ok_or_else(|| anyhow::anyhow!("quantized sparse map n·s overflows"))?;
        anyhow::ensure!(
            idx.len() == want,
            "quantized sparse map has {} indices, want n·s = {want}",
            idx.len()
        );
        anyhow::ensure!(
            idx.as_slice().iter().all(|&i| (i as usize) < k),
            "quantized sparse map index out of range (k={k})"
        );
        Ok(QuantColumnSparse { k, idx, val })
    }

    /// Heap bytes actually resident (mapped buffers count 0).
    pub fn resident_bytes(&self) -> usize {
        self.val.resident_bytes() + self.idx.resident_bytes()
    }

    /// Bytes borrowed from a checkpoint mapping.
    pub fn mapped_bytes(&self) -> usize {
        self.val.mapped_bytes() + self.idx.mapped_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::util::{prop, Rng};

    #[test]
    fn hard_threshold_keeps_top_s() {
        let z = Mat::from_vec(4, 2, vec![
            1.0, -4.0, //
            -3.0, 0.5, //
            2.0, 0.1, //
            -0.5, 2.5,
        ]);
        let cs = ColumnSparse::hard_threshold(&z, 2);
        let d = cs.to_dense();
        // col 0: top-2 by |.| are rows 1 (−3) and 2 (2)
        assert_eq!(d[(0, 0)], 0.0);
        assert_eq!(d[(1, 0)], -3.0);
        assert_eq!(d[(2, 0)], 2.0);
        assert_eq!(d[(3, 0)], 0.0);
        // col 1: rows 0 (−4) and 3 (2.5)
        assert_eq!(d[(0, 1)], -4.0);
        assert_eq!(d[(3, 1)], 2.5);
        assert_eq!(d[(1, 1)], 0.0);
    }

    #[test]
    fn hard_threshold_is_projection_optimum() {
        // H_s(z) must be the best s-sparse L2 approximation of each column.
        prop::check(70, 30, |rng, _| {
            let k = rng.range(2, 12);
            let n = rng.range(1, 6);
            let s = rng.range(1, k + 1);
            let z = Mat::randn(rng, k, n, 1.0);
            let cs = ColumnSparse::hard_threshold(&z, s);
            let dense = cs.to_dense();
            for j in 0..n {
                let kept: f64 = (0..k)
                    .map(|i| {
                        if dense[(i, j)] != 0.0 {
                            (z[(i, j)] as f64).powi(2)
                        } else {
                            0.0
                        }
                    })
                    .sum();
                // any other s-subset keeps at most this much energy: check
                // against the best-s directly
                let mut mags: Vec<f64> = (0..k).map(|i| (z[(i, j)] as f64).powi(2)).collect();
                mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let best: f64 = mags[..s].iter().sum();
                assert!((kept - best).abs() < 1e-9 * best.max(1.0));
            }
        });
    }

    #[test]
    fn apply_after_matches_dense() {
        prop::check(71, 20, |rng, _| {
            let k = rng.range(2, 16);
            let n = rng.range(1, 16);
            let s = rng.range(1, k + 1);
            let rows = rng.range(1, 8);
            let z = Mat::randn(rng, k, n, 1.0);
            let cs = ColumnSparse::hard_threshold(&z, s);
            let t = Mat::randn(rng, rows, k, 1.0);
            let fast = cs.apply_after(&t);
            let dense = matmul(&t, &cs.to_dense());
            assert!(fast.rel_err(&dense) < 1e-4);
        });
    }

    #[test]
    fn mt_product_matches_dense() {
        prop::check(72, 20, |rng, _| {
            let k = rng.range(2, 10);
            let n = rng.range(2, 14);
            let s = rng.range(1, k + 1);
            let m = rng.range(1, 9);
            let z = Mat::randn(rng, k, n, 1.0);
            let cs = ColumnSparse::hard_threshold(&z, s);
            let w = Mat::randn(rng, m, n, 1.0);
            let mt = cs.mt_product(&w.transpose());
            // Mᵀ = S·W̃ᵀ ⇔ M = W̃·Sᵀ
            let dense = matmul(&w, &cs.to_dense().transpose());
            assert!(mt.transpose().rel_err(&dense) < 1e-4);
        });
    }

    #[test]
    fn storage_bits_formula() {
        let z = Mat::zeros(128, 256);
        let cs = ColumnSparse::hard_threshold(&z, 16);
        assert_eq!(cs.storage_bits(), (16 * 16 * 256 + 128 * 256) as u64);
    }

    #[test]
    fn roundtrip_from_columns() {
        let cols = vec![vec![(3u32, 1.5f32), (0, -2.0)], vec![(1, 0.25)]];
        let cs = ColumnSparse::from_columns(5, 2, 2, cols).unwrap();
        let d = cs.to_dense();
        assert_eq!(d[(0, 0)], -2.0);
        assert_eq!(d[(3, 0)], 1.5);
        assert_eq!(d[(1, 1)], 0.25);
        assert_eq!(cs.s(), 2);
        // malformed inputs are errors, not panics: wrong column count,
        // overfull column, out-of-range row index
        assert!(ColumnSparse::from_columns(5, 3, 2, vec![vec![]]).is_err());
        let over = vec![vec![(0u32, 1.0f32), (1, 1.0), (2, 1.0)], vec![]];
        assert!(ColumnSparse::from_columns(5, 2, 2, over).is_err());
        let oob = vec![vec![(5u32, 1.0f32)], vec![]];
        assert!(ColumnSparse::from_columns(5, 2, 2, oob).is_err());
    }

    #[test]
    fn s_zero_yields_empty_map() {
        let mut rng = Rng::new(80);
        let z = Mat::randn(&mut rng, 5, 3, 1.0);
        let cs = ColumnSparse::hard_threshold(&z, 0);
        assert_eq!((cs.k(), cs.n(), cs.s()), (5, 3, 0));
        assert_eq!(cs.to_dense(), Mat::zeros(5, 3));
        assert_eq!(cs.fro_sq(), 0.0);
        // mask bits still accounted (Eq. 11 charges the k×n position mask)
        assert_eq!(cs.storage_bits(), 15);
        // both apply branches produce zeros
        for rows in [1, 6] {
            let t = Mat::randn(&mut rng, rows, 5, 1.0);
            assert_eq!(cs.apply_after(&t), Mat::zeros(rows, 3));
        }
        assert_eq!(cs.iter().count(), 0);
    }

    #[test]
    fn s_larger_than_k_clamps_to_identity() {
        let mut rng = Rng::new(81);
        let z = Mat::randn(&mut rng, 4, 6, 1.0);
        let cs = ColumnSparse::hard_threshold(&z, 10);
        assert_eq!(cs.s(), 4);
        assert_eq!(cs.to_dense(), z);
    }

    #[test]
    fn empty_matrices_do_not_panic() {
        // n = 0: no columns at all.
        let cs = ColumnSparse::hard_threshold(&Mat::zeros(4, 0), 2);
        assert_eq!((cs.k(), cs.n(), cs.s()), (4, 0, 2));
        assert_eq!(cs.to_dense().shape(), (4, 0));
        assert_eq!(cs.apply_after(&Mat::zeros(3, 4)).shape(), (3, 0));
        // k = 0: columns with no rows — s clamps to 0.
        let cs = ColumnSparse::hard_threshold(&Mat::zeros(0, 5), 2);
        assert_eq!((cs.k(), cs.n(), cs.s()), (0, 5, 0));
        assert_eq!(cs.apply_after(&Mat::zeros(2, 0)), Mat::zeros(2, 5));
        // 0 × 0.
        let cs = ColumnSparse::hard_threshold(&Mat::zeros(0, 0), 1);
        assert_eq!(cs.storage_bits(), 0);
    }

    #[test]
    fn non_finite_entries_do_not_panic_selection() {
        // total_cmp keeps the selection deterministic even with NaN columns.
        let mut z = Mat::zeros(4, 2);
        z[(1, 0)] = f32::NAN;
        z[(2, 0)] = 3.0;
        z[(0, 1)] = -2.0;
        let cs = ColumnSparse::hard_threshold(&z, 2);
        assert_eq!(cs.s(), 2);
        // finite column selected normally
        assert_eq!(cs.to_dense()[(0, 1)], -2.0);
        // the finite large entry of column 0 survives alongside the NaN
        assert_eq!(cs.to_dense()[(2, 0)], 3.0);
    }

    #[test]
    fn apply_after_row_matches_batched() {
        prop::check(82, 20, |rng, _| {
            let k = rng.range(1, 12);
            let n = rng.range(1, 12);
            let s = rng.range(0, k + 1);
            let z = Mat::randn(rng, k, n, 1.0);
            let cs = ColumnSparse::hard_threshold(&z, s);
            let t = Mat::randn(rng, 1, k, 1.0);
            let row = cs.apply_after_row(t.row(0));
            let full = cs.apply_after(&t);
            for j in 0..n {
                assert!((row[j] - full[(0, j)]).abs() == 0.0);
            }
        });
    }

    #[test]
    fn fro_sq_matches_dense() {
        let mut rng = Rng::new(73);
        let z = Mat::randn(&mut rng, 9, 7, 1.0);
        let cs = ColumnSparse::hard_threshold(&z, 4);
        let d = cs.to_dense().fro_norm();
        assert!((cs.fro_sq().sqrt() - d).abs() < 1e-5);
    }

    #[test]
    fn quant_sparse_apply_matches_dequantized_bitwise() {
        // The packed sparse kernels must agree bit-for-bit with the
        // fake-quant ColumnSparse they round-trip through.
        prop::check(83, 25, |rng, _| {
            let bits = [2u32, 3, 4, 8][rng.range(0, 4)];
            let k = rng.range(1, 14);
            let n = rng.range(1, 14);
            let s = rng.range(0, k + 1);
            let z = Mat::randn(rng, k, n, 1.0);
            let cs = ColumnSparse::hard_threshold(&z, s);
            let qs = QuantColumnSparse::quantize_from(&cs, bits);
            assert_eq!((qs.k(), qs.n(), qs.s()), (cs.k(), cs.n(), cs.s()));
            let fake = qs.dequantize();
            for rows in [1usize, 6] {
                let t = Mat::randn(rng, rows, k, 1.0);
                let a = qs.apply_after(&t);
                let b = fake.apply_after(&t);
                assert_eq!(a.shape(), b.shape());
                for i in 0..rows {
                    for j in 0..n {
                        assert!(
                            (a[(i, j)] - b[(i, j)]).abs() == 0.0,
                            "rows {rows} ({i},{j}): {} vs {}",
                            a[(i, j)],
                            b[(i, j)]
                        );
                    }
                }
            }
            let t = Mat::randn(rng, 1, k, 1.0);
            let row = qs.apply_after_row(t.row(0));
            let full = qs.apply_after(&t);
            for j in 0..n {
                assert!((row[j] - full[(0, j)]).abs() == 0.0, "col {j}");
            }
        });
    }

    #[test]
    fn quant_sparse_groups_are_column_aligned() {
        // One column with a huge value must not poison its neighbor's
        // scale: the tiny column keeps its own (fine) quantization step.
        let z = Mat::from_vec(2, 2, vec![
            1000.0, 0.001, //
            -900.0, 0.0009,
        ]);
        let cs = ColumnSparse::hard_threshold(&z, 2);
        let qs = QuantColumnSparse::quantize_from(&cs, 4);
        let d = qs.dequantize().to_dense();
        // column 1's step is ~0.001/7 ≈ 1.4e-4; a flattened group sharing
        // column 0's scale (step ~143) would zero it out entirely.
        assert!((d[(0, 1)] - 0.001).abs() < 2e-4, "poisoned: {}", d[(0, 1)]);
        assert!(d[(0, 1)] != 0.0);
        // column 0 still quantized sanely
        assert!((d[(0, 0)] - 1000.0).abs() <= 1000.0 / 7.0);
    }

    #[test]
    fn raw_parts_roundtrip_both_layouts() {
        let mut rng = Rng::new(84);
        let z = Mat::randn(&mut rng, 9, 6, 1.0);
        let cs = ColumnSparse::hard_threshold(&z, 3);
        let back = ColumnSparse::from_raw_parts(
            cs.k(),
            cs.n(),
            cs.s(),
            cs.indices().to_vec(),
            cs.values().to_vec(),
        )
        .unwrap();
        assert_eq!(back, cs);
        let qs = QuantColumnSparse::quantize_from(&cs, 4);
        let qback = QuantColumnSparse::from_raw_parts(
            qs.k(),
            qs.indices().to_vec(),
            qs.values_qmat().clone(),
        )
        .unwrap();
        assert_eq!(qback, qs);
        // validation: mismatched lengths, s > k, out-of-range indices
        assert!(ColumnSparse::from_raw_parts(9, 6, 3, vec![0; 5], vec![0.0; 18]).is_err());
        let (idx, val) = (cs.indices().to_vec(), cs.values().to_vec());
        assert!(ColumnSparse::from_raw_parts(2, 6, 3, idx, val).is_err());
        assert!(ColumnSparse::from_raw_parts(9, 1, 1, vec![9], vec![1.0]).is_err());
        let (qidx, qval) = (qs.indices().to_vec(), qs.values_qmat().clone());
        assert!(QuantColumnSparse::from_raw_parts(1, qidx, qval).is_err());
        // degenerate s = 0 round-trips too
        let empty = ColumnSparse::hard_threshold(&z, 0);
        assert_eq!(
            ColumnSparse::from_raw_parts(9, 6, 0, vec![], vec![]).unwrap(),
            empty
        );
    }

    #[test]
    fn quant_sparse_storage_and_resident_accounting() {
        let z = Mat::zeros(128, 256);
        let cs = ColumnSparse::hard_threshold(&z, 16);
        let qs = QuantColumnSparse::quantize_from(&cs, 4);
        // 256 columns × 16 values at 4 bits, code-planar: each column is one
        // ragged tail group whose 4 bit-plane strips word-align to 4 u32s →
        // 1024 words; one scale per column (16 ≤ 128); mask 128×256.
        assert_eq!(qs.storage_bits(), 1024 * 32 + 256 * 16 + 128 * 256);
        assert_eq!(qs.resident_bytes(), 1024 * 4 + 256 * 2 + 4 * 256 * 16);
        assert!(qs.storage_bits() < cs.storage_bits());
        // the legacy row-sequential re-encode packs the same values into
        // 512 words and stays value-identical
        let legacy = qs.with_layout(crate::linalg::QuantLayout::RowSeq);
        assert_eq!(legacy.storage_bits(), 512 * 32 + 256 * 16 + 128 * 256);
        assert_eq!(legacy.dequantize(), qs.dequantize());
        // s = 0 degenerates cleanly
        let qs0 = QuantColumnSparse::quantize_from(&ColumnSparse::hard_threshold(&z, 0), 4);
        assert_eq!(qs0.s(), 0);
        assert_eq!(qs0.apply_after_row(&[0.0; 128]), vec![0.0; 256]);
    }
}
