//! Evaluation harness: perplexity, zero-shot MCQ accuracy
//! (lm-evaluation-harness protocol), WER, and the method × CR grid runner
//! that regenerates the paper's tables.

pub mod harness;
pub mod perplexity;
pub mod wer;
pub mod zeroshot;

pub use perplexity::perplexity;
pub use wer::wer;
pub use zeroshot::{task_accuracy, vlm_accuracy};
