//! Zero-shot multiple-choice scoring, following the lm-evaluation-harness
//! protocol the paper uses: each choice is scored by the sum of its token
//! log-likelihoods given the context, length-normalized (acc_norm); the
//! highest-scoring choice is the prediction.

use super::perplexity::log_prob;
use crate::data::tasks::{McqItem, Task};
use crate::data::vlm::VlmItem;
use crate::model::encdec::VlmModel;
use crate::model::Model;
use crate::util::parallel::parallel_map;

/// Length-normalized log-likelihood of `choice` following `context`.
pub fn score_choice(model: &Model, context: &[u16], choice: &[u16]) -> f64 {
    let mut seq = context.to_vec();
    seq.extend_from_slice(choice);
    let logits = model.forward(&seq);
    let mut total = 0.0;
    let mut scored = 0usize;
    for (i, &tok) in choice.iter().enumerate() {
        // token at position context.len()+i is predicted from the previous
        // position's logits; position 0 has no predictor.
        if context.len() + i == 0 {
            continue;
        }
        let pos = context.len() + i - 1;
        total += log_prob(logits.row(pos), tok as usize);
        scored += 1;
    }
    total / scored.max(1) as f64
}

/// Predicted choice index for one item.
pub fn predict(model: &Model, item: &McqItem) -> usize {
    let mut best = 0;
    let mut best_score = f64::NEG_INFINITY;
    for (i, ch) in item.choices.iter().enumerate() {
        let s = score_choice(model, &item.context, ch);
        if s > best_score {
            best_score = s;
            best = i;
        }
    }
    best
}

/// Accuracy (%) of the model on one task, parallel over items.
pub fn task_accuracy(model: &Model, task: &Task) -> f64 {
    let hits = parallel_map(task.items.len(), |i| {
        (predict(model, &task.items[i]) == task.items[i].answer) as usize
    });
    100.0 * hits.iter().sum::<usize>() as f64 / task.items.len().max(1) as f64
}

/// VLM variant: choices conditioned on the patch prefix.
pub fn vlm_accuracy(model: &VlmModel, items: &[VlmItem]) -> f64 {
    let hits = parallel_map(items.len(), |i| {
        let it = &items[i];
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (c, choice) in it.mcq.choices.iter().enumerate() {
            let mut seq = it.mcq.context.clone();
            seq.extend_from_slice(choice);
            let logits = model.forward(&it.patches, &seq);
            let mut total = 0.0;
            for (j, &tok) in choice.iter().enumerate() {
                let pos = it.mcq.context.len() + j;
                // prefix-LM: logits row `pos` predicts seq[pos] from patches
                // + seq[..pos]; row index into caption logits is pos
                // (position 0 is predicted from the last patch).
                let row = if pos == 0 {
                    // predicted from the final patch position — the VLM
                    // forward returns caption rows only, so use row 0's
                    // *input* convention: approximate with row 0.
                    // (Consistent across choices, so ranking is fair.)
                    0
                } else {
                    pos - 1
                };
                total += log_prob(logits.row(row), tok as usize);
            }
            let score = total / choice.len() as f64;
            if score > best_score {
                best_score = score;
                best = c;
            }
        }
        (best == it.mcq.answer) as usize
    });
    100.0 * hits.iter().sum::<usize>() as f64 / items.len().max(1) as f64
}

/// Test-only helpers for rigging deterministic models (used by several
/// eval test modules).
#[cfg(test)]
pub mod tests_support {
    use crate::compress::LinearWeight;
    use crate::linalg::Mat;
    use crate::model::transformer::{Model, Stage};

    /// Zero every block projection (residual stream = embedding), set every
    /// embedding row to ones, and point the LM head at `winner`: the model
    /// then assigns `winner` the highest probability at every position.
    pub fn rig_constant_model(m: &mut Model, winner: usize) {
        let d = m.cfg.d_model;
        for stage in &mut m.stages {
            if let Stage::Block(b) = stage {
                for p in crate::model::config::ProjKind::DECODER_SET {
                    let (rows, cols) = {
                        let w = b.proj(p);
                        (w.in_dim(), w.out_dim())
                    };
                    *b.proj_mut(p) = LinearWeight::Dense(Mat::zeros(rows, cols));
                }
            }
        }
        m.embed = Mat::from_fn(m.cfg.vocab, d, |_, _| 1.0);
        m.lm_head = Mat::from_fn(d, m.cfg.vocab, |_, j| if j == winner { 10.0 } else { -10.0 });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::McqItem;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;

    /// A model rigged to always prefer token 7 (constant hidden state).
    fn rigged_model() -> Model {
        let cfg = ModelConfig::test_tiny();
        let mut m = Model::random(&cfg, &mut Rng::new(1));
        tests_support::rig_constant_model(&mut m, 7);
        m
    }

    #[test]
    fn predict_prefers_high_likelihood_choice() {
        let m = rigged_model();
        let item = McqItem {
            context: vec![1, 2, 3],
            choices: vec![vec![9], vec![7], vec![13], vec![2]],
            answer: 1,
        };
        assert_eq!(predict(&m, &item), 1);
    }

    #[test]
    fn accuracy_100_on_rigged_task() {
        let m = rigged_model();
        let items: Vec<McqItem> = (0..10)
            .map(|i| McqItem {
                context: vec![i as u16, (i + 1) as u16],
                choices: vec![vec![7], vec![(i % 6) as u16 + 8]],
                answer: 0,
            })
            .collect();
        let task = Task { name: "rigged", items };
        assert_eq!(task_accuracy(&m, &task), 100.0);
    }

    #[test]
    fn random_model_near_chance_on_hard_distractors() {
        // With choices that are all non-successors of a random model's
        // context, accuracy over many binary items should be near 50%.
        let cfg = ModelConfig::test_tiny();
        let m = Model::random(&cfg, &mut Rng::new(5));
        let mut rng = Rng::new(6);
        let items: Vec<McqItem> = (0..60)
            .map(|_| {
                let a = rng.below(64) as u16;
                let b = rng.below(64) as u16;
                McqItem {
                    context: vec![rng.below(64) as u16; 8],
                    choices: vec![vec![a], vec![b]],
                    answer: rng.below(2),
                }
            })
            .collect();
        let task = Task { name: "chance", items };
        let acc = task_accuracy(&m, &task);
        assert!((20.0..80.0).contains(&acc), "acc {acc} not near chance");
    }

    use crate::data::tasks::Task;
}
