//! Word error rate: Levenshtein distance between hypothesis and reference
//! token sequences, normalized by reference length — the ASR metric of the
//! audio transfer table.

/// Edit distance (insertions + deletions + substitutions).
pub fn levenshtein(a: &[u16], b: &[u16]) -> usize {
    let n = b.len();
    let mut prev: Vec<usize> = (0..=n).collect();
    let mut cur = vec![0usize; n + 1];
    for (i, &ta) in a.iter().enumerate() {
        cur[0] = i + 1;
        for j in 0..n {
            let sub = prev[j] + (ta != b[j]) as usize;
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

/// WER (%) over a corpus of (hypothesis, reference) pairs — total edits over
/// total reference length, the standard pooled formulation.
pub fn wer(pairs: &[(Vec<u16>, Vec<u16>)]) -> f64 {
    let mut edits = 0usize;
    let mut total = 0usize;
    for (hyp, reference) in pairs {
        edits += levenshtein(hyp, reference);
        total += reference.len();
    }
    100.0 * edits as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_zero() {
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(wer(&[(vec![1, 2], vec![1, 2])]), 0.0);
    }

    #[test]
    fn known_distances() {
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(levenshtein(&[1, 2], &[1, 2, 3]), 1); // insertion
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(levenshtein(&[], &[1, 2, 3]), 3);
        assert_eq!(levenshtein(&[1, 2, 3], &[]), 3);
    }

    #[test]
    fn wer_pools_over_pairs() {
        let pairs = vec![
            (vec![1u16, 2, 3], vec![1u16, 2, 3]), // 0 edits / 3
            (vec![9u16, 9, 9], vec![1u16, 2, 3]), // 3 edits / 3
        ];
        assert!((wer(&pairs) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn symmetric() {
        let a = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let b = [2u16, 7, 1, 8, 2, 8];
        assert_eq!(levenshtein(&a, &b), levenshtein(&b, &a));
    }

    #[test]
    fn triangle_inequality() {
        let a = [1u16, 2, 3, 4];
        let b = [1u16, 3, 4, 5];
        let c = [2u16, 3, 5];
        assert!(levenshtein(&a, &c) <= levenshtein(&a, &b) + levenshtein(&b, &c));
    }
}
