//! Token-level perplexity over a set of sequences, parallel across
//! sequences.

use crate::linalg::Mat;
use crate::model::Model;
use crate::util::parallel::parallel_map;

/// Numerically stable log-softmax pick: log p(target | logits row).
pub fn log_prob(logits_row: &[f32], target: usize) -> f64 {
    let maxv = logits_row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let mut denom = 0.0f64;
    for &x in logits_row {
        denom += ((x as f64) - maxv).exp();
    }
    (logits_row[target] as f64 - maxv) - denom.ln()
}

/// Total negative log-likelihood and token count of one sequence
/// (predicting tokens 1..T from 0..T-1). Runs through the incremental
/// runtime's prefill, which is bit-identical to the stateless forward (see
/// `model::decode`) — so perplexity exercises the same execution path the
/// server decodes with.
pub fn sequence_nll(model: &Model, tokens: &[u16]) -> (f64, usize) {
    // A sequence shorter than 2 tokens has no next-token predictions (and
    // prefill rejects empty input) — contribute nothing instead of
    // underflowing the token count.
    if tokens.len() < 2 {
        return (0.0, 0);
    }
    let mut cache = model.new_cache_with(tokens.len());
    let logits = model.prefill(&mut cache, tokens);
    nll_from_logits(&logits, tokens)
}

pub fn nll_from_logits(logits: &Mat, tokens: &[u16]) -> (f64, usize) {
    // Guard the `tokens.len() - 1` loop bound and returned count against
    // empty / length-1 sequences (usize underflow).
    if tokens.len() < 2 {
        return (0.0, 0);
    }
    let mut nll = 0.0;
    for t in 0..tokens.len() - 1 {
        nll -= log_prob(logits.row(t), tokens[t + 1] as usize);
    }
    (nll, tokens.len() - 1)
}

/// Perplexity over a corpus of sequences.
pub fn perplexity(model: &Model, seqs: &[Vec<u16>]) -> f64 {
    let parts = parallel_map(seqs.len(), |i| sequence_nll(model, &seqs[i]));
    let (nll, count) = parts
        .into_iter()
        .fold((0.0f64, 0usize), |(a, b), (n, c)| (a + n, b + c));
    (nll / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthLang;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;

    #[test]
    fn log_prob_is_valid_distribution() {
        let logits = vec![1.0f32, 2.0, 0.5, -1.0];
        let total: f64 = (0..4).map(|t| log_prob(&logits, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // argmax target has highest prob
        assert!(log_prob(&logits, 1) > log_prob(&logits, 0));
    }

    #[test]
    fn prefill_nll_is_identical_to_stateless_forward() {
        let cfg = ModelConfig::test_tiny();
        let model = crate::model::Model::random(&cfg, &mut Rng::new(9));
        let seq: Vec<u16> = (0..24u16).map(|i| (i * 13) % 64).collect();
        let (nll_pre, count_pre) = sequence_nll(&model, &seq);
        let (nll_full, count_full) = nll_from_logits(&model.forward(&seq), &seq);
        assert_eq!(count_pre, count_full);
        assert_eq!(nll_pre, nll_full);
    }

    #[test]
    fn degenerate_sequences_contribute_nothing() {
        let cfg = ModelConfig::test_tiny();
        let model = crate::model::Model::random(&cfg, &mut Rng::new(11));
        // Empty and length-1 sequences used to underflow `len - 1`.
        assert_eq!(sequence_nll(&model, &[]), (0.0, 0));
        assert_eq!(sequence_nll(&model, &[3]), (0.0, 0));
        assert_eq!(nll_from_logits(&Mat::zeros(0, 4), &[]), (0.0, 0));
        assert_eq!(nll_from_logits(&Mat::zeros(1, 4), &[2]), (0.0, 0));
        // A corpus of only degenerate sequences yields a neutral perplexity
        // (exp(0/1) = 1) instead of panicking.
        let ppl = perplexity(&model, &[vec![], vec![7]]);
        assert_eq!(ppl, 1.0);
        // Mixed corpora count only the real predictions.
        let seq: Vec<u16> = (0..8u16).collect();
        let alone = perplexity(&model, std::slice::from_ref(&seq));
        let mixed = perplexity(&model, &[seq.clone(), vec![], vec![5]]);
        assert_eq!(alone, mixed);
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        // An untrained model's perplexity should be near |V| (uniform-ish),
        // certainly within a small constant factor.
        let cfg = ModelConfig::test_tiny();
        let model = crate::model::Model::random(&cfg, &mut Rng::new(1));
        let lang = SynthLang::wiki(cfg.vocab);
        let seqs = lang.gen_batch(4, 32, &mut Rng::new(2));
        let ppl = perplexity(&model, &seqs);
        assert!(ppl > 16.0 && ppl < 256.0, "ppl = {ppl}, vocab = 64");
    }

    #[test]
    fn lower_entropy_data_scores_better_with_matching_bias() {
        // Rig a constant-hidden-state model biased toward token 0 and feed
        // all-zeros sequences: perplexity must approach 1.
        let cfg = ModelConfig::test_tiny();
        let mut model = crate::model::Model::random(&cfg, &mut Rng::new(3));
        crate::eval::zeroshot::tests_support::rig_constant_model(&mut model, 0);
        let seqs = vec![vec![0u16; 16], vec![0u16; 16]];
        let ppl = perplexity(&model, &seqs);
        assert!(ppl < 1.05, "ppl = {ppl}");

        // And a zeroed head gives exactly-uniform perplexity = vocab.
        let mut uniform = crate::model::Model::random(&cfg, &mut Rng::new(4));
        uniform.lm_head = crate::linalg::Mat::zeros(cfg.d_model, cfg.vocab);
        let ppl_u = perplexity(&uniform, &seqs);
        assert!((ppl_u - cfg.vocab as f64).abs() < 1e-6, "ppl_u = {ppl_u}");
    }
}
