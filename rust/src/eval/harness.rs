//! The method × CR grid runner: compress a model with a registry method (or
//! a multi-stage plan) at a target CR, evaluate perplexity + the zero-shot
//! suite, and return one table row. This is what the `compot table <id>`
//! commands are built from.

use crate::compress::{CalibContext, MethodCall, StageConfig};
use crate::coordinator::pipeline::compress_with;
use crate::coordinator::plan::CompressionPlan;
use crate::data::tasks::Task;
use crate::data::SynthLang;
use crate::model::Model;
use crate::util::Rng;

/// Everything needed to evaluate one model configuration.
pub struct EvalSetup {
    pub calib: Vec<Vec<u16>>,
    pub ppl_wiki: Vec<Vec<u16>>,
    pub ppl_c4: Vec<Vec<u16>>,
    pub tasks: Vec<Task>,
}

impl EvalSetup {
    /// Standard setup: `n_calib` calibration sequences, held-out perplexity
    /// splits, and the 8-task suite with `n_items` items each.
    pub fn standard(vocab: usize, n_calib: usize, seq_len: usize, n_items: usize, seed: u64) -> EvalSetup {
        let wiki = SynthLang::wiki(vocab);
        let c4 = SynthLang::c4(vocab);
        let mut rng = Rng::new(seed);
        EvalSetup {
            calib: wiki.gen_batch(n_calib, seq_len, &mut rng.fork(1)),
            ppl_wiki: wiki.gen_batch(16, seq_len, &mut rng.fork(2)),
            ppl_c4: c4.gen_batch(16, seq_len, &mut rng.fork(3)),
            tasks: crate::data::tasks::standard_suite(&wiki, n_items, seed ^ 0x7a57),
        }
    }
}

/// One evaluated row: per-task accuracies, their mean, and perplexities.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub method: String,
    pub target_cr: f64,
    pub model_cr: f64,
    pub accs: Vec<f64>,
    pub avg_acc: f64,
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
    pub compress_secs: f64,
}

/// Evaluate an already-compressed model.
pub fn evaluate(model: &Model, setup: &EvalSetup, method: &str, target_cr: f64, model_cr: f64, secs: f64) -> EvalRow {
    let accs: Vec<f64> =
        setup.tasks.iter().map(|t| super::zeroshot::task_accuracy(model, t)).collect();
    let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    EvalRow {
        method: method.to_string(),
        target_cr,
        model_cr,
        avg_acc: avg,
        accs,
        ppl_wiki: super::perplexity::perplexity(model, &setup.ppl_wiki),
        ppl_c4: super::perplexity::perplexity(model, &setup.ppl_c4),
        compress_secs: secs,
    }
}

/// Compress with a registry method at `target_cr` (static or dynamic
/// allocation) and evaluate. Every method — including structural ones like
/// ReplaceMe — runs through the unified pipeline; the calibration sequences
/// travel in the [`CalibContext`].
pub fn run_method(
    model: &Model,
    setup: &EvalSetup,
    call: &MethodCall,
    target_cr: f64,
    dynamic: bool,
) -> anyhow::Result<EvalRow> {
    let ctx = CalibContext::build(model, &setup.calib);
    let cfg = StageConfig::new(target_cr, dynamic);
    let (compressed, report) = compress_with(model, &ctx, call, &cfg)?;
    Ok(evaluate(
        &compressed,
        setup,
        &report.method,
        target_cr,
        report.model_cr,
        report.wall_secs,
    ))
}

/// Run a multi-stage plan and evaluate the final model. The row's CR is the
/// composed CR (Eq. 25 accounting on actual stored bits).
pub fn run_plan(
    model: &Model,
    setup: &EvalSetup,
    plan: &CompressionPlan,
    label: &str,
) -> anyhow::Result<EvalRow> {
    let (compressed, report) = plan.run(model, &setup.calib)?;
    let target = plan.stages.first().map(|s| s.cfg.target_cr).unwrap_or(0.0);
    Ok(evaluate(&compressed, setup, label, target, report.composed_cr, report.wall_secs))
}

/// The uncompressed reference row.
pub fn baseline_row(model: &Model, setup: &EvalSetup, name: &str) -> EvalRow {
    evaluate(model, setup, name, 0.0, 0.0, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn harness_produces_complete_rows() {
        let cfg = ModelConfig::test_tiny();
        let model = Model::random(&cfg, &mut Rng::new(1));
        let setup = EvalSetup::standard(cfg.vocab, 4, 32, 4, 99);
        let base = baseline_row(&model, &setup, "orig");
        assert_eq!(base.accs.len(), 8);
        assert!(base.ppl_wiki.is_finite());
        let row = run_method(
            &model,
            &setup,
            &MethodCall::new("compot").with("iters", 3),
            0.25,
            false,
        )
        .unwrap();
        assert!(row.model_cr >= 0.25 - 1e-9);
        assert!(row.avg_acc >= 0.0 && row.avg_acc <= 100.0);
        // compression should not *improve* ppl on a random model much; just
        // check finiteness and ordering sanity
        assert!(row.ppl_wiki.is_finite() && row.ppl_c4.is_finite());
    }

    #[test]
    fn replaceme_runs_through_run_method() {
        let cfg = ModelConfig::test_tiny();
        let model = Model::random(&cfg, &mut Rng::new(2));
        let setup = EvalSetup::standard(cfg.vocab, 3, 32, 2, 7);
        let row = run_method(&model, &setup, &MethodCall::new("replaceme"), 0.3, false).unwrap();
        assert!(row.model_cr > 0.2, "cr {}", row.model_cr);
    }
}
