//! PJRT-backed COMPOT engine: runs the alternating-minimization inner loop
//! through the AOT artifact `compot_iter_{m}x{n}_k{k}_s{s}.hlo.txt`
//! (L1 Pallas GEMM/top-s + Newton–Schulz Procrustes, lowered by
//! `python/compile/aot.py`). For the fixed projection shapes of the shipped
//! presets this exercises the full three-layer stack; arbitrary shapes fall
//! back to the pure-Rust engine (`compress::compot::factorize`), and the two
//! are cross-checked in `rust/tests/integration.rs`.

use super::artifacts::Manifest;
use super::pjrt::PjrtEngine;
use crate::compress::sparse::ColumnSparse;
use crate::compress::whitening::{CalibStats, Whitener};
use crate::compress::{CompressedLayer, LinearWeight};
use crate::linalg::{svd, Mat};

pub struct CompotExec<'a> {
    pub engine: &'a PjrtEngine,
    pub manifest: &'a Manifest,
}

impl<'a> CompotExec<'a> {
    /// One alternating iteration via XLA: (W̃, D) → (S_dense, D_next).
    pub fn iter_once(
        &self,
        wt: &Mat,
        d: &Mat,
        k: usize,
        s: usize,
    ) -> anyhow::Result<(Mat, Mat)> {
        let (m, n) = wt.shape();
        let entry = self
            .manifest
            .compot_iter(m, n, k, s)
            .ok_or_else(|| anyhow::anyhow!("no compot_iter artifact for {m}x{n} k={k} s={s}"))?;
        let exe = self.engine.load(&entry.path)?;
        let outs = self.engine.run(&exe, &[wt, d], &[(k, n), (m, k)])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// Full factorization through the artifact loop. `iters` alternating
    /// steps with SVD initialization (computed host-side, as in the paper).
    pub fn factorize(
        &self,
        wt: &Mat,
        k: usize,
        s: usize,
        iters: usize,
    ) -> anyhow::Result<(Mat, ColumnSparse)> {
        let mut d = svd::left_singular_basis(wt, k);
        anyhow::ensure!(d.cols() == k, "SVD init rank-deficient for k={k}");
        let mut s_dense = Mat::zeros(k, wt.cols());
        for t in 0..iters.max(1) {
            let (s_out, d_next) = self.iter_once(wt, &d, k, s)?;
            s_dense = s_out;
            if t + 1 < iters {
                d = d_next;
            }
        }
        Ok((d, ColumnSparse::hard_threshold(&s_dense, s)))
    }

    /// End-to-end compression of one projection through PJRT, matching
    /// `Compot::compress` semantics (whiten → factorize → dewhiten).
    pub fn compress(
        &self,
        w: &Mat,
        stats: &CalibStats,
        k: usize,
        s: usize,
        iters: usize,
    ) -> anyhow::Result<CompressedLayer> {
        let whitener = Whitener::from_stats(stats);
        let wt = whitener.whiten(w);
        let (d, s_mat) = self.factorize(&wt, k, s, iters)?;
        let a = whitener.dewhiten(&d);
        Ok(CompressedLayer::new(
            "COMPOT(pjrt)",
            w,
            LinearWeight::Factorized { a, s: s_mat },
            Some(stats),
        ))
    }
}
