//! Artifact manifest (`artifacts/manifest.json`) — shape-keyed lookup of
//! the AOT-compiled programs.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub s: usize,
    pub inputs: Vec<(usize, usize)>,
    pub outputs: Vec<(usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    pub models: Vec<String>,
}

/// Default artifacts directory: `$COMPOT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("COMPOT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let shapes = |v: Option<&Json>| -> Vec<(usize, usize)> {
            v.and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|s| {
                            let s = s.as_arr()?;
                            Some((s[0].as_usize()?, s[1].as_usize()?))
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let entries = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|e| ArtifactEntry {
                name: e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                path: dir.join(e.get("path").and_then(Json::as_str).unwrap_or("")),
                kind: e.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                m: e.get("m").and_then(Json::as_usize).unwrap_or(0),
                n: e.get("n").and_then(Json::as_usize).unwrap_or(0),
                k: e.get("k").and_then(Json::as_usize).unwrap_or(0),
                s: e.get("s").and_then(Json::as_usize).unwrap_or(0),
                inputs: shapes(e.get("inputs")),
                outputs: shapes(e.get("outputs")),
            })
            .collect();
        let models = j
            .get("models")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| m.as_str().map(String::from))
            .collect();
        Ok(Manifest { dir: dir.to_path_buf(), entries, models })
    }

    /// The compot_iter artifact for a given (m, n, k, s), if exported.
    pub fn compot_iter(&self, m: usize, n: usize, k: usize, s: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "compot_iter" && e.m == m && e.n == n && e.k == k && e.s == s)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn model_path(&self, preset: &str) -> Option<PathBuf> {
        let file = format!("{preset}.bin");
        self.models.contains(&file).then(|| self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_json() {
        let dir = std::env::temp_dir().join("compot_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"compot_iter_96x256_k32_s16","path":"x.hlo.txt",
                "kind":"compot_iter","m":96,"n":256,"k":32,"s":16,
                "inputs":[[96,256],[96,32]],"outputs":[[32,256],[96,32]]}],
                "models":["llama-micro.bin"]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.compot_iter(96, 256, 32, 16).unwrap();
        assert_eq!(e.inputs, vec![(96, 256), (96, 32)]);
        assert!(m.compot_iter(1, 2, 3, 4).is_none());
        assert!(m.model_path("llama-micro").is_some());
        assert!(m.model_path("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
