//! Artifact manifest (`artifacts/manifest.json`) — shape-keyed lookup of
//! the AOT-compiled programs, plus provenance records for saved CPT2
//! compressed checkpoints (which plan produced which file).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One saved compressed checkpoint: where it lives and which compression
/// plan produced it, so a serve host can pick an artifact by plan without
/// re-deriving anything.
#[derive(Clone, Debug)]
pub struct CheckpointEntry {
    pub name: String,
    pub path: PathBuf,
    /// Container format, `"cpt2"` (or `"cpt1"` for dense snapshots).
    pub format: String,
    /// Compression-plan provenance (e.g. `compot@0.25 → gptq4`), if known.
    pub plan: Option<String>,
    /// Shard count when `path` is a sharded CPT2 **index** file (the shard
    /// payloads live next to it); `None` for a monolithic checkpoint.
    pub shards: Option<usize>,
}

impl CheckpointEntry {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str().into())
            .set("path", self.path.to_string_lossy().as_ref().into())
            .set("format", self.format.as_str().into());
        if let Some(p) = &self.plan {
            j.set("plan", p.as_str().into());
        }
        if let Some(n) = self.shards {
            j.set("shards", n.into());
        }
        j
    }

    fn from_json(j: &Json) -> Option<CheckpointEntry> {
        Some(CheckpointEntry {
            name: j.get("name").and_then(Json::as_str)?.to_string(),
            path: PathBuf::from(j.get("path").and_then(Json::as_str)?),
            format: j.get("format").and_then(Json::as_str).unwrap_or("cpt2").to_string(),
            plan: j.get("plan").and_then(Json::as_str).map(String::from),
            shards: j.get("shards").and_then(Json::as_usize),
        })
    }
}

/// Append (or replace, keyed by *path* — re-saving the same file updates
/// its record, while distinct files that happen to share a stem both
/// persist) a checkpoint record in `<dir>/manifest.json`, creating the
/// manifest if the artifacts build has not run — checkpoint provenance
/// must not require `make artifacts`.
pub fn record_checkpoint(dir: &Path, entry: &CheckpointEntry) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let manifest_path = dir.join("manifest.json");
    let mut root = match std::fs::read_to_string(&manifest_path) {
        Ok(text) => Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?,
        // Only a genuinely absent manifest starts from scratch — any other
        // read error must propagate, or a transient failure would rewrite
        // the manifest and destroy the artifact/model records.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Json::obj(),
        Err(e) => return Err(e.into()),
    };
    let mut list: Vec<Json> = root
        .get("checkpoints")
        .and_then(Json::as_arr)
        .map(|a| a.to_vec())
        .unwrap_or_default();
    let path_str = entry.path.to_string_lossy().into_owned();
    list.retain(|c| c.get("path").and_then(Json::as_str) != Some(path_str.as_str()));
    list.push(entry.to_json());
    root.set("checkpoints", Json::Arr(list));
    std::fs::write(&manifest_path, root.to_string() + "\n")?;
    Ok(())
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub s: usize,
    pub inputs: Vec<(usize, usize)>,
    pub outputs: Vec<(usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    pub models: Vec<String>,
    /// Saved compressed checkpoints (see [`record_checkpoint`]).
    pub checkpoints: Vec<CheckpointEntry>,
}

/// Default artifacts directory: `$COMPOT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("COMPOT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let shapes = |v: Option<&Json>| -> Vec<(usize, usize)> {
            v.and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|s| {
                            let s = s.as_arr()?;
                            Some((s[0].as_usize()?, s[1].as_usize()?))
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        let entries = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|e| ArtifactEntry {
                name: e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                path: dir.join(e.get("path").and_then(Json::as_str).unwrap_or("")),
                kind: e.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                m: e.get("m").and_then(Json::as_usize).unwrap_or(0),
                n: e.get("n").and_then(Json::as_usize).unwrap_or(0),
                k: e.get("k").and_then(Json::as_usize).unwrap_or(0),
                s: e.get("s").and_then(Json::as_usize).unwrap_or(0),
                inputs: shapes(e.get("inputs")),
                outputs: shapes(e.get("outputs")),
            })
            .collect();
        let models = j
            .get("models")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| m.as_str().map(String::from))
            .collect();
        let checkpoints = j
            .get("checkpoints")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(CheckpointEntry::from_json)
            .collect();
        Ok(Manifest { dir: dir.to_path_buf(), entries, models, checkpoints })
    }

    /// Look up a recorded checkpoint by name. Records are keyed by path, so
    /// distinct files may share a name — the most recently recorded wins.
    pub fn checkpoint(&self, name: &str) -> Option<&CheckpointEntry> {
        self.checkpoints.iter().rev().find(|c| c.name == name)
    }

    /// The compot_iter artifact for a given (m, n, k, s), if exported.
    pub fn compot_iter(&self, m: usize, n: usize, k: usize, s: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "compot_iter" && e.m == m && e.n == n && e.k == k && e.s == s)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn model_path(&self, preset: &str) -> Option<PathBuf> {
        let file = format!("{preset}.bin");
        self.models.contains(&file).then(|| self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_json() {
        let dir = std::env::temp_dir().join("compot_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"name":"compot_iter_96x256_k32_s16","path":"x.hlo.txt",
                "kind":"compot_iter","m":96,"n":256,"k":32,"s":16,
                "inputs":[[96,256],[96,32]],"outputs":[[32,256],[96,32]]}],
                "models":["llama-micro.bin"]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.compot_iter(96, 256, 32, 16).unwrap();
        assert_eq!(e.inputs, vec![(96, 256), (96, 32)]);
        assert!(m.compot_iter(1, 2, 3, 4).is_none());
        assert!(m.model_path("llama-micro").is_some());
        assert!(m.model_path("nope").is_none());
        assert!(m.checkpoints.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_records_roundtrip_and_replace() {
        // record_checkpoint must work with *no* pre-existing manifest (a
        // checkpoint save must not require `make artifacts`), append to an
        // existing one without touching artifact entries, and replace
        // records that reuse a name.
        let dir = std::env::temp_dir().join("compot_manifest_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        let entry = CheckpointEntry {
            name: "tiny-t7".to_string(),
            path: dir.join("tiny-t7.cpt2"),
            format: "cpt2".to_string(),
            plan: Some("compot@0.25 → gptq4".to_string()),
            shards: None,
        };
        record_checkpoint(&dir, &entry).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.checkpoints.len(), 1);
        let c = m.checkpoint("tiny-t7").unwrap();
        assert_eq!(c.format, "cpt2");
        assert_eq!(c.plan.as_deref(), Some("compot@0.25 → gptq4"));
        assert_eq!(c.shards, None, "monolithic records must stay shard-free");
        assert!(m.checkpoint("nope").is_none());
        // same path replaces its record, a different path appends
        record_checkpoint(&dir, &CheckpointEntry { plan: None, ..entry.clone() }).unwrap();
        record_checkpoint(
            &dir,
            &CheckpointEntry {
                name: "other".to_string(),
                path: dir.join("other.cpt2"),
                ..entry.clone()
            },
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.checkpoints.len(), 2);
        assert!(m.checkpoint("tiny-t7").unwrap().plan.is_none());
        // two distinct files sharing one name: both records persist and the
        // most recently recorded one wins the name lookup
        record_checkpoint(
            &dir,
            &CheckpointEntry {
                path: dir.join("elsewhere/tiny-t7.cpt2"),
                plan: Some("svd-llm@0.20".to_string()),
                ..entry.clone()
            },
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.checkpoints.len(), 3);
        assert_eq!(m.checkpoint("tiny-t7").unwrap().plan.as_deref(), Some("svd-llm@0.20"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_set_records_roundtrip() {
        // A sharded save records one entry for the index file with its
        // shard count; reloading the manifest preserves it.
        let dir = std::env::temp_dir().join("compot_manifest_shard_test");
        std::fs::remove_dir_all(&dir).ok();
        record_checkpoint(
            &dir,
            &CheckpointEntry {
                name: "tiny-sharded".to_string(),
                path: dir.join("tiny-sharded.cpt2"),
                format: "cpt2".to_string(),
                plan: Some("rtn4".to_string()),
                shards: Some(2),
            },
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.checkpoint("tiny-sharded").unwrap().shards, Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }
}
