//! PJRT runtime: load AOT-compiled HLO-text artifacts (built once by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//! Python is never on the request path — the artifacts are self-contained
//! XLA programs.

pub mod artifacts;
pub mod compot_exec;
pub mod pjrt;

pub use artifacts::{record_checkpoint, CheckpointEntry, Manifest};
pub use pjrt::PjrtEngine;
