//! Thin wrapper over the `xla` crate: one CPU PJRT client, a compile cache
//! keyed by artifact path, and Mat ⇄ Literal conversion.

use crate::linalg::Mat;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub struct PjrtEngine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    pub fn cpu() -> anyhow::Result<PjrtEngine> {
        Ok(PjrtEngine { client: xla::PjRtClient::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> anyhow::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute with Mat inputs; outputs come back as Mats with the given
    /// shapes (artifacts are lowered with `return_tuple=True`).
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&Mat],
        out_shapes: &[(usize, usize)],
    ) -> anyhow::Result<Vec<Mat>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                xla::Literal::vec1(m.data())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .map_err(|e| anyhow::anyhow!("reshape: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        anyhow::ensure!(
            tuple.len() == out_shapes.len(),
            "artifact returned {} outputs, expected {}",
            tuple.len(),
            out_shapes.len()
        );
        tuple
            .into_iter()
            .zip(out_shapes.iter())
            .map(|(lit, &(r, c))| {
                let v = lit.to_vec::<f32>()?;
                anyhow::ensure!(v.len() == r * c, "output size {} != {r}x{c}", v.len());
                Ok(Mat::from_vec(r, c, v))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // PJRT tests live in rust/tests/integration.rs (artifact-gated).
}
