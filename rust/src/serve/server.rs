//! JSON-lines TCP inference server.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": [1,2,3], "max_new": 16}
//!   ← {"tokens": [...], "latency_ms": 1.8, "batch": 3}
//!   → {"cmd": "stats"}   ← aggregated metrics
//!   → {"cmd": "info"}    ← static serving metadata (model, compression plan, CR)
//!   → {"cmd": "shutdown"}
//!
//! Thread-per-connection front-end feeds the shared [`Batcher`]; one worker
//! thread drains batches and decodes. Everything std-only (offline env —
//! no tokio), which is fine at this scale: the model forward dominates.

use super::batcher::{BatchPolicy, Batcher};
use crate::model::Model;
use crate::util::json::Json;
use crate::util::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u16>,
    pub latency_ms: f64,
    pub batch: usize,
}

struct Job {
    req: GenRequest,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    pub total_latency_us: AtomicU64,
    pub batches: AtomicU64,
}

impl Metrics {
    pub fn to_json(&self) -> Json {
        let reqs = self.requests.load(Ordering::Relaxed).max(1);
        let mut j = Json::obj();
        j.set("requests", (self.requests.load(Ordering::Relaxed) as f64).into())
            .set("tokens_out", (self.tokens_out.load(Ordering::Relaxed) as f64).into())
            .set("batches", (self.batches.load(Ordering::Relaxed) as f64).into())
            .set(
                "mean_latency_ms",
                (self.total_latency_us.load(Ordering::Relaxed) as f64 / reqs as f64 / 1e3).into(),
            );
        j
    }
}

/// Run the server until a shutdown command. Returns the bound address
/// through `on_ready` (port 0 = ephemeral). `info` is static serving
/// metadata (model preset, compression plan, achieved CR — whatever the
/// launcher knows) exposed verbatim on `{"cmd":"info"}`.
pub fn serve_blocking(
    model: Arc<Model>,
    addr: &str,
    policy: BatchPolicy,
    info: Json,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);

    let info = Arc::new(info);
    let batcher: Arc<Batcher<Job>> = Arc::new(Batcher::new(policy));
    let metrics = Arc::new(Metrics::default());
    let shutdown = Arc::new(AtomicBool::new(false));

    // Worker: drain batches, decode, reply.
    let worker = {
        let batcher = batcher.clone();
        let metrics = metrics.clone();
        let model = model.clone();
        std::thread::spawn(move || loop {
            let batch = batcher.next_batch();
            if batch.is_empty() {
                break; // closed + drained
            }
            let bsize = batch.len();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            for job in batch {
                let out = model.greedy_decode(&job.req.prompt, job.req.max_new);
                let latency = job.enqueued.secs() * 1e3;
                metrics.requests.fetch_add(1, Ordering::Relaxed);
                metrics.tokens_out.fetch_add(out.len() as u64, Ordering::Relaxed);
                metrics
                    .total_latency_us
                    .fetch_add((latency * 1e3) as u64, Ordering::Relaxed);
                let _ = job.reply.send(GenResponse { tokens: out, latency_ms: latency, batch: bsize });
            }
        })
    };

    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let info = info.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, &batcher, &metrics, &info, &shutdown);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    batcher.close();
    for c in conns {
        let _ = c.join();
    }
    let _ = worker.join();
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    info: &Json,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
            match cmd {
                "stats" => {
                    writeln!(writer, "{}", metrics.to_json().to_string())?;
                }
                "info" => {
                    writeln!(writer, "{}", info.to_string())?;
                }
                "shutdown" => {
                    shutdown.store(true, Ordering::Relaxed);
                    writeln!(writer, "{{\"ok\":true}}")?;
                    break;
                }
                _ => writeln!(writer, "{{\"error\":\"unknown cmd\"}}")?,
            }
            continue;
        }
        let prompt: Vec<u16> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|x| x.as_usize().map(|v| v as u16)).collect())
            .unwrap_or_default();
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let (tx, rx) = mpsc::channel();
        batcher.push(Job { req: GenRequest { prompt, max_new }, enqueued: Timer::start(), reply: tx });
        let resp = rx.recv()?;
        let mut out = Json::obj();
        out.set("tokens", Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("latency_ms", resp.latency_ms.into())
            .set("batch", resp.batch.into());
        writeln!(writer, "{}", out.to_string())?;
    }
    Ok(())
}

/// Simple blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn request(&mut self, prompt: &[u16], max_new: usize) -> anyhow::Result<GenResponse> {
        let mut j = Json::obj();
        j.set("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("max_new", max_new.into());
        writeln!(self.stream, "{}", j.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let r = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        Ok(GenResponse {
            tokens: r
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_usize().map(|v| v as u16)).collect())
                .unwrap_or_default(),
            latency_ms: r.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch: r.get("batch").and_then(Json::as_usize).unwrap_or(0),
        })
    }

    pub fn stats(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"stats\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad stats: {e}"))
    }

    pub fn info(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"info\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad info: {e}"))
    }

    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        writeln!(self.stream, "{{\"cmd\":\"shutdown\"}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;

    #[test]
    fn end_to_end_serve_and_shutdown() {
        let model = Arc::new(Model::random(&ModelConfig::test_tiny(), &mut Rng::new(1)));
        let (addr_tx, addr_rx) = mpsc::channel();
        let m2 = model.clone();
        let server = std::thread::spawn(move || {
            let mut info = Json::obj();
            info.set("model", "test-tiny".into());
            serve_blocking(m2, "127.0.0.1:0", BatchPolicy::default(), info, |a| {
                addr_tx.send(a).unwrap();
            })
            .unwrap();
        });
        let addr = addr_rx.recv().unwrap();
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        assert_eq!(info.get("model").and_then(Json::as_str), Some("test-tiny"));
        let r = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert!(r.latency_ms >= 0.0);
        // deterministic: same prompt → same continuation
        let r2 = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens, r2.tokens);
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("requests").and_then(Json::as_usize), Some(2));
        client.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn concurrent_clients_are_all_served() {
        let model = Arc::new(Model::random(&ModelConfig::test_tiny(), &mut Rng::new(2)));
        let (addr_tx, addr_rx) = mpsc::channel();
        let m2 = model.clone();
        let server = std::thread::spawn(move || {
            serve_blocking(
                m2,
                "127.0.0.1:0",
                BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(3) },
                Json::obj(),
                |a| {
                    addr_tx.send(a).unwrap();
                },
            )
            .unwrap();
        });
        let addr = addr_rx.recv().unwrap();
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i, i + 1], 3).unwrap().tokens.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
    }
}
