//! JSON-lines TCP inference server over the incremental decode runtime.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": [1,2,3], "max_new": 16,
//!      "temperature": 0.8, "top_k": 20, "seed": 7}   (sampling optional)
//!   ← {"tokens": [...], "latency_ms": 1.8, "batch": 3}
//!   → {"cmd": "stats"}   ← aggregated metrics
//!   → {"cmd": "info"}    ← static serving metadata (model, compression plan, CR)
//!   → {"cmd": "shutdown"}
//!
//! Thread-per-connection front-end feeds the shared [`Batcher`]; one worker
//! thread runs **continuous batching**: each request becomes a
//! [`DecodeSession`] (prefill once, then O(T) KV-cached decode steps), the
//! worker steps every active session one token per round, and sessions
//! join/leave the running batch as they arrive/finish — a finished request
//! frees its slot for a queued one immediately instead of waiting for the
//! whole batch. Shutdown is graceful: closing the batcher rejects *new*
//! work, but queued requests still admit and every in-flight session decodes
//! to completion and flushes its response. Everything std-only (offline env
//! — no tokio), which is fine at this scale: the model forward dominates.

use super::batcher::{BatchPolicy, Batcher};
use crate::model::decode::{sampler_cfg_from_json, DecodeSession, SamplerCfg};
use crate::model::Model;
use crate::util::json::Json;
use crate::util::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
    pub sampling: SamplerCfg,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u16>,
    pub latency_ms: f64,
    /// Concurrently active sessions when this request finished.
    pub batch: usize,
}

struct Job {
    req: GenRequest,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// One admitted request inside the continuous batch.
struct Active {
    session: DecodeSession,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    pub total_latency_us: AtomicU64,
    /// Admission rounds that brought at least one new session into the batch.
    pub batches: AtomicU64,
    /// Total KV-cached decode steps executed across all sessions.
    pub steps: AtomicU64,
}

impl Metrics {
    pub fn to_json(&self) -> Json {
        let reqs = self.requests.load(Ordering::Relaxed).max(1);
        let mut j = Json::obj();
        j.set("requests", (self.requests.load(Ordering::Relaxed) as f64).into())
            .set("tokens_out", (self.tokens_out.load(Ordering::Relaxed) as f64).into())
            .set("batches", (self.batches.load(Ordering::Relaxed) as f64).into())
            .set("decode_steps", (self.steps.load(Ordering::Relaxed) as f64).into())
            .set(
                "mean_latency_ms",
                (self.total_latency_us.load(Ordering::Relaxed) as f64 / reqs as f64 / 1e3).into(),
            );
        j
    }

    fn finish(
        &self,
        enqueued: &Timer,
        reply: &mpsc::Sender<GenResponse>,
        tokens: Vec<u16>,
        batch: usize,
    ) {
        let latency = enqueued.secs() * 1e3;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        self.total_latency_us.fetch_add((latency * 1e3) as u64, Ordering::Relaxed);
        let _ = reply.send(GenResponse { tokens, latency_ms: latency, batch });
    }
}

/// Run the server until a shutdown command. Returns the bound address
/// through `on_ready` (port 0 = ephemeral). `info` is static serving
/// metadata (model preset, compression plan, achieved CR — whatever the
/// launcher knows) exposed verbatim on `{"cmd":"info"}`.
pub fn serve_blocking(
    model: Arc<Model>,
    addr: &str,
    policy: BatchPolicy,
    info: Json,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);

    // The server always knows its real memory footprint: packed-quantized
    // models report the bytes actually resident, and mmap-loaded models
    // split that into heap-resident vs mapping-borrowed (page-cache-shared)
    // bytes — the numbers capacity planning across serve workers needs.
    let mut info = info;
    info.set("resident_weight_bytes", model.resident_weight_bytes().into());
    info.set("mapped_weight_bytes", model.mapped_weight_bytes().into());
    // Where the weights came from: zero-copy checkpoint mapping ("mmap"),
    // a cold-loaded compressed checkpoint (launcher set "checkpoint"), or
    // an in-process model — so operators can tell a CPT2-restored server
    // from one that recompressed at startup.
    if info.get("weights_source").is_none() {
        let src = if model.weights_mapped() {
            "mmap"
        } else if info.get("checkpoint").is_some() {
            "checkpoint"
        } else {
            "in-memory"
        };
        info.set("weights_source", src.into());
    }
    let info = Arc::new(info);
    let batcher: Arc<Batcher<Job>> = Arc::new(Batcher::new(policy));
    let metrics = Arc::new(Metrics::default());
    let shutdown = Arc::new(AtomicBool::new(false));

    // Worker: continuous batching over decode sessions. One token step per
    // active session per round; new sessions are admitted into free slots
    // between rounds, finished ones flush and leave immediately.
    let worker = {
        let batcher = batcher.clone();
        let metrics = metrics.clone();
        let model = model.clone();
        std::thread::spawn(move || {
            let mut active: Vec<Active> = Vec::new();
            loop {
                let slots = policy.max_batch.saturating_sub(active.len());
                let incoming = if active.is_empty() {
                    let batch = batcher.next_batch();
                    if batch.is_empty() {
                        break; // closed + drained, nothing in flight
                    }
                    batch
                } else if slots > 0 {
                    batcher.try_drain(slots)
                } else {
                    Vec::new()
                };
                if !incoming.is_empty() {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                }
                for job in incoming {
                    if job.req.prompt.is_empty() || job.req.max_new == 0 {
                        metrics.finish(&job.enqueued, &job.reply, Vec::new(), active.len() + 1);
                        continue;
                    }
                    let session = DecodeSession::start(
                        &model,
                        &job.req.prompt,
                        job.req.max_new,
                        job.req.sampling,
                    );
                    active.push(Active { session, enqueued: job.enqueued, reply: job.reply });
                }
                // One decode step per running session, then retire finished
                // sessions so their slots free up for the next admission.
                let bsize = active.len();
                let mut i = 0;
                while i < active.len() {
                    if !active[i].session.is_done() {
                        active[i].session.step(&model);
                        metrics.steps.fetch_add(1, Ordering::Relaxed);
                    }
                    if active[i].session.is_done() {
                        let done = active.swap_remove(i);
                        metrics.finish(
                            &done.enqueued,
                            &done.reply,
                            done.session.generated().to_vec(),
                            bsize,
                        );
                    } else {
                        i += 1;
                    }
                }
            }
        })
    };

    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let info = info.clone();
                let vocab = model.cfg.vocab;
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, &batcher, &metrics, &info, &shutdown, vocab);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    // Graceful drain: no new work, but everything queued or in flight
    // decodes to completion and flushes before the worker exits.
    batcher.close();
    let _ = worker.join();
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    info: &Json,
    shutdown: &AtomicBool,
    vocab: usize,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
            match cmd {
                "stats" => {
                    writeln!(writer, "{}", metrics.to_json().to_string())?;
                }
                "info" => {
                    writeln!(writer, "{}", info.to_string())?;
                }
                "shutdown" => {
                    shutdown.store(true, Ordering::Relaxed);
                    writeln!(writer, "{{\"ok\":true}}")?;
                    break;
                }
                _ => writeln!(writer, "{{\"error\":\"unknown cmd\"}}")?,
            }
            continue;
        }
        // Validate token ids here, at the protocol edge: an out-of-range id
        // would panic the (single) decode worker inside embed_tokens and
        // wedge the whole server.
        let raw: Vec<usize> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        if raw.iter().any(|&t| t >= vocab) {
            writeln!(writer, "{{\"error\":\"prompt token out of range (vocab {vocab})\"}}")?;
            continue;
        }
        let prompt: Vec<u16> = raw.into_iter().map(|t| t as u16).collect();
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let sampling = sampler_cfg_from_json(&j);
        let (tx, rx) = mpsc::channel();
        let accepted = batcher.push(Job {
            req: GenRequest { prompt, max_new, sampling },
            enqueued: Timer::start(),
            reply: tx,
        });
        if !accepted {
            writeln!(writer, "{{\"error\":\"server shutting down\"}}")?;
            continue;
        }
        let resp = rx.recv()?;
        let mut out = Json::obj();
        out.set("tokens", Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("latency_ms", resp.latency_ms.into())
            .set("batch", resp.batch.into());
        writeln!(writer, "{}", out.to_string())?;
    }
    Ok(())
}

/// Simple blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Greedy request (temperature 0).
    pub fn request(&mut self, prompt: &[u16], max_new: usize) -> anyhow::Result<GenResponse> {
        self.request_with(prompt, max_new, SamplerCfg::greedy())
    }

    /// Request with explicit sampling controls.
    pub fn request_with(
        &mut self,
        prompt: &[u16],
        max_new: usize,
        sampling: SamplerCfg,
    ) -> anyhow::Result<GenResponse> {
        let mut j = Json::obj();
        j.set("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("max_new", max_new.into());
        if !sampling.is_greedy() {
            j.set("temperature", (sampling.temperature as f64).into())
                .set("top_k", sampling.top_k.into())
                .set("seed", (sampling.seed as f64).into());
        }
        writeln!(self.stream, "{}", j.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let r = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(GenResponse {
            tokens: r
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_usize().map(|v| v as u16)).collect())
                .unwrap_or_default(),
            latency_ms: r.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch: r.get("batch").and_then(Json::as_usize).unwrap_or(0),
        })
    }

    pub fn stats(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"stats\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad stats: {e}"))
    }

    pub fn info(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"info\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad info: {e}"))
    }

    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        writeln!(self.stream, "{{\"cmd\":\"shutdown\"}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;
    use std::time::Duration;

    fn spawn_server(
        seed: u64,
        policy: BatchPolicy,
        info: Json,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed)));
        let (addr_tx, addr_rx) = mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_blocking(model, "127.0.0.1:0", policy, info, |a| {
                addr_tx.send(a).unwrap();
            })
            .unwrap();
        });
        (addr_rx.recv().unwrap(), server)
    }

    #[test]
    fn end_to_end_serve_and_shutdown() {
        let mut info = Json::obj();
        info.set("model", "test-tiny".into());
        let (addr, server) = spawn_server(1, BatchPolicy::default(), info);
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        assert_eq!(info.get("model").and_then(Json::as_str), Some("test-tiny"));
        // the server injects its real memory footprint into the metadata
        assert!(info.get("resident_weight_bytes").and_then(Json::as_usize).unwrap() > 0);
        let r = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert!(r.latency_ms >= 0.0);
        // deterministic: same prompt → same continuation
        let r2 = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens, r2.tokens);
        // empty prompts are answered (with nothing), not panicked on
        let r3 = client.request(&[], 4).unwrap();
        assert!(r3.tokens.is_empty());
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("requests").and_then(Json::as_usize), Some(3));
        assert!(stats.get("decode_steps").and_then(Json::as_usize).unwrap() >= 6);
        client.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn info_reports_checkpoint_origin() {
        // A launcher serving a CPT2 checkpoint passes its path and plan in
        // the metadata; the server must surface them plus a weights_source
        // tag, and default to "in-memory" otherwise.
        let mut info = Json::obj();
        info.set("model", "test-tiny".into())
            .set("checkpoint", "tiny-t7.cpt2".into())
            .set("plan", "compot@0.25 → gptq4".into());
        let (addr, server) = spawn_server(7, BatchPolicy::default(), info);
        let mut client = Client::connect(addr).unwrap();
        let got = client.info().unwrap();
        assert_eq!(got.get("checkpoint").and_then(Json::as_str), Some("tiny-t7.cpt2"));
        assert_eq!(got.get("plan").and_then(Json::as_str), Some("compot@0.25 → gptq4"));
        assert_eq!(got.get("weights_source").and_then(Json::as_str), Some("checkpoint"));
        client.shutdown().unwrap();
        server.join().unwrap();

        let (addr, server) = spawn_server(8, BatchPolicy::default(), Json::obj());
        let mut client = Client::connect(addr).unwrap();
        let got = client.info().unwrap();
        assert_eq!(got.get("weights_source").and_then(Json::as_str), Some("in-memory"));
        client.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn mmap_loaded_server_is_token_identical_to_owned() {
        // The serve-smoke contract behind `--load-compressed --mmap`: a
        // server whose weights are zero-copy views into the checkpoint
        // mapping answers every request with exactly the tokens the
        // owned-load server produces, and reports weights_source "mmap"
        // with a real mapped-bytes figure.
        use crate::compress::StageConfig;
        use crate::coordinator::plan::CompressionPlan;
        use crate::data::SynthLang;

        let base = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(21));
        let lang = SynthLang::wiki(base.cfg.vocab);
        let calib = lang.gen_batch(6, 48, &mut Rng::new(22));
        let plan = CompressionPlan::parse("compot@0.25+gptq4", &StageConfig::new(0.25, false))
            .unwrap();
        let compressed = plan.run(&base, &calib).unwrap().0;
        let dir = std::env::temp_dir().join("compot_serve_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.cpt2");
        compressed.save_compressed(&path, Some("compot@0.25+gptq4")).unwrap();

        let (owned, _) = Model::load_compressed(&path).unwrap();
        let (mapped, ck) = Model::load_compressed_mmap(&path).unwrap();
        // on hosts without working mmap the loader takes its documented
        // heap fallback; parity must hold either way, the info assertions
        // below only apply to a true mapping
        assert!(ck.source.starts_with("mmap"), "{}", ck.source);
        let true_mmap = ck.source == "mmap";
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[7, 8, 9, 10], &[5]];
        let expected: Vec<Vec<u16>> =
            prompts.iter().map(|p| owned.greedy_decode(p, 6)).collect();

        let (addr_tx, addr_rx) = mpsc::channel();
        let mapped = Arc::new(mapped);
        let server = {
            let mapped = mapped.clone();
            std::thread::spawn(move || {
                serve_blocking(mapped, "127.0.0.1:0", BatchPolicy::default(), Json::obj(), |a| {
                    addr_tx.send(a).unwrap();
                })
                .unwrap();
            })
        };
        let addr = addr_rx.recv().unwrap();
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        if true_mmap {
            assert_eq!(info.get("weights_source").and_then(Json::as_str), Some("mmap"));
            assert!(info.get("mapped_weight_bytes").and_then(Json::as_usize).unwrap() > 0);
        }
        for (p, want) in prompts.iter().zip(expected.iter()) {
            let got = client.request(p, 6).unwrap().tokens;
            assert_eq!(&got, want, "mmap-served continuation diverged for {p:?}");
        }
        client.shutdown().unwrap();
        server.join().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_clients_are_all_served() {
        let (addr, server) = spawn_server(
            2,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) },
            Json::obj(),
        );
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i, i + 1], 3).unwrap().tokens.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn batched_decoding_matches_single_stream_decoding() {
        // Continuous batching must not change any request's continuation:
        // fire the same prompt alone and alongside five others.
        let (addr, server) = spawn_server(
            3,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) },
            Json::obj(),
        );
        let mut alone = Client::connect(addr).unwrap();
        let solo = alone.request(&[7, 8, 9], 6).unwrap().tokens;
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let p: Vec<u16> = if i == 0 { vec![7, 8, 9] } else { vec![i, i * 2, i * 3] };
                (i, c.request(&p, 6).unwrap().tokens)
            }));
        }
        for h in handles {
            let (i, tokens) = h.join().unwrap();
            if i == 0 {
                assert_eq!(tokens, solo, "batched continuation differs from solo");
            }
            assert_eq!(tokens.len(), 6);
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn out_of_range_tokens_are_rejected_without_killing_the_worker() {
        let (addr, server) = spawn_server(6, BatchPolicy::default(), Json::obj());
        let mut c = Client::connect(addr).unwrap();
        // vocab is 64 for test-tiny: 9999 must be rejected at the edge...
        let err = c.request(&[9999, 1], 4);
        assert!(err.is_err(), "out-of-range prompt must be rejected");
        // ...and the worker must still be alive to serve valid requests.
        let ok = c.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(ok.tokens.len(), 4);
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn sampled_requests_are_seed_deterministic() {
        let (addr, server) = spawn_server(4, BatchPolicy::default(), Json::obj());
        let mut c = Client::connect(addr).unwrap();
        let cfg = SamplerCfg { temperature: 0.9, top_k: 4, seed: 11 };
        let a = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        let b = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
        assert!(a.tokens.iter().all(|&t| t < 64));
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn graceful_shutdown_flushes_in_flight_and_queued_requests() {
        // max_batch 2 forces some of the 5 requests to sit in the queue when
        // shutdown lands; all of them must still get full responses.
        let (addr, server) = spawn_server(
            5,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            Json::obj(),
        );
        let mut handles = Vec::new();
        for i in 0..5u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i + 1, i + 2, i + 3], 24)
            }));
        }
        // Let every request reach the queue (the accept loop polls every
        // 2ms), then pull the plug mid-flight.
        std::thread::sleep(Duration::from_millis(50));
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        // The invariant under test: an *accepted* request is never dropped or
        // truncated by shutdown. A client thread scheduled so late that its
        // push lost the race gets the explicit rejection error — allowed, but
        // on any sane scheduler the 50ms head start means most (usually all)
        // requests are accepted, and at least one must be.
        let mut accepted = 0;
        for h in handles {
            match h.join().unwrap() {
                Ok(r) => {
                    assert_eq!(r.tokens.len(), 24, "request dropped during shutdown");
                    accepted += 1;
                }
                Err(e) => {
                    assert!(
                        e.to_string().contains("shutting down"),
                        "unexpected error during shutdown: {e}"
                    );
                }
            }
        }
        assert!(accepted >= 1, "no request beat a 50ms-delayed shutdown");
        server.join().unwrap();
    }
}
