//! JSON-lines TCP inference server over the incremental decode runtime.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": [1,2,3], "max_new": 16, "tier": "spec",
//!      "temperature": 0.8, "top_k": 20, "seed": 7}   (sampling, tier optional)
//!   ← {"tokens": [...], "latency_ms": 1.8, "batch": 3, "tier": "spec"}
//!   → {"cmd": "stats"}   ← aggregated metrics
//!   → {"cmd": "info"}    ← static serving metadata (model, compression plan, CR)
//!   → {"cmd": "shutdown"}
//!
//! A server started with a draft model ([`serve_blocking_tiers`]) routes
//! each request by its `tier`: `"draft"` decodes on the draft alone,
//! `"full"` on the target alone, and `"spec"` (the default when a draft is
//! loaded) runs a [`SpeculativeSession`] — draft-proposed, target-verified,
//! greedy output token-identical to `"full"`. Unknown tiers and
//! draft-requiring tiers on a draftless server get structured errors with a
//! machine-readable `code`; non-greedy `"spec"` requests silently take the
//! full tier (speculative acceptance is argmax-vs-argmax, i.e. greedy), and
//! the response's `tier` field always reports what actually ran.
//!
//! Thread-per-connection front-end feeds the shared [`Batcher`]; one worker
//! thread runs **continuous batching**: each request becomes a
//! [`DecodeSession`] (prefill once, then O(T) KV-cached decode steps), the
//! worker advances every active session one token per round, and sessions
//! join/leave the running batch as they arrive/finish — a finished request
//! frees its slot for a queued one immediately instead of waiting for the
//! whole batch. Each round the plain sessions sharing a model (full-tier on
//! the target, draft-tier on the draft) step through ONE cross-session
//! batched forward ([`Model::decode_step_batch`]): one `LinearWeight::apply`
//! per projection per layer for the whole group — a real blocked GEMM when
//! more than one session is active, the single-row matvec kernel at batch
//! 1 — while speculative sessions keep their own multi-row verify forwards.
//! Shutdown is graceful: closing the batcher rejects *new* work, but queued
//! requests still admit and every in-flight session decodes to completion
//! and flushes its response. Everything std-only (offline env — no tokio),
//! which is fine at this scale: the model forward dominates.

use super::batcher::{BatchPolicy, Batcher};
use super::spec::{SpeculativeSession, Tier};
use crate::model::decode::{sampler_cfg_from_json, DecodeSession, KvCache, SamplerCfg};
use crate::model::Model;
use crate::util::json::Json;
use crate::util::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u16>,
    pub max_new: usize,
    pub sampling: SamplerCfg,
    /// Resolved at the protocol edge: defaults applied, unknown/unavailable
    /// tiers already rejected, non-greedy spec downgraded to full.
    pub tier: Tier,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u16>,
    pub latency_ms: f64,
    /// Concurrently active sessions when this request finished.
    pub batch: usize,
    /// Tier that actually served the request ("draft" | "spec" | "full").
    pub tier: String,
    /// Structured failure for a request the worker could not serve: the
    /// human-readable message plus the stable protocol `code` (e.g.
    /// `"worker_panic"`). `None` on success.
    pub error: Option<(String, String)>,
}

struct Job {
    req: GenRequest,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// One scheduling unit of the continuous batch: a plain decode session on
/// the target or draft, or a speculative draft/verify session. Each gets
/// one "turn" per worker round — a single token for the plain tiers
/// (stepped together through one batched forward per model, see
/// [`step_plain_group`]), up to draft_k + 1 tokens for spec (its verify
/// forward costs about one target step, so per-round work stays balanced
/// across tiers).
enum AnySession {
    Full(DecodeSession),
    Draft(DecodeSession),
    Spec(SpeculativeSession),
}

impl AnySession {
    fn tier(&self) -> Tier {
        match self {
            AnySession::Full(_) => Tier::Full,
            AnySession::Draft(_) => Tier::Draft,
            AnySession::Spec(_) => Tier::Spec,
        }
    }

    fn is_done(&self) -> bool {
        match self {
            AnySession::Full(s) | AnySession::Draft(s) => s.is_done(),
            AnySession::Spec(s) => s.is_done(),
        }
    }

    fn generated(&self) -> &[u16] {
        match self {
            AnySession::Full(s) | AnySession::Draft(s) => s.generated(),
            AnySession::Spec(s) => s.generated(),
        }
    }
}

/// Step every unfinished plain session of one model group — `Full` sessions
/// on the target (`want_draft == false`) or `Draft` sessions on the draft
/// (`want_draft == true`) — through a single cross-session batched forward:
/// collect each session's next input token and KV cache, run one
/// [`Model::decode_step_batch`] (one `LinearWeight::apply` per projection
/// per layer for the whole group; matvec fallback at batch 1), then hand
/// each session its own logits row so sampling and stop logic stay
/// per-session. Output is bit-identical to each session stepping alone —
/// the kernel's parity contract — so continuous batching never changes a
/// continuation.
///
/// The forward runs under `catch_unwind`: a panicking model must cost the
/// sessions in this group a structured error, not the whole server. Returns
/// the `active` indices of sessions lost to a panicked forward (empty on
/// the happy path) so the caller can retire them with `worker_panic`.
fn step_plain_group(
    model: &Model,
    active: &mut [Active],
    want_draft: bool,
    metrics: &Metrics,
) -> Vec<usize> {
    let mut idxs: Vec<usize> = Vec::new();
    let mut tokens: Vec<u16> = Vec::new();
    let mut caches: Vec<&mut KvCache> = Vec::new();
    for (i, a) in active.iter_mut().enumerate() {
        let s = match (&mut a.session, want_draft) {
            (AnySession::Full(s), false) | (AnySession::Draft(s), true) => s,
            _ => continue,
        };
        let Some(tok) = s.next_input() else { continue };
        idxs.push(i);
        tokens.push(tok);
        caches.push(s.cache_mut());
    }
    if tokens.is_empty() {
        return Vec::new();
    }
    let forward = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        model.decode_step_batch(&mut caches, &tokens)
    }));
    drop(caches);
    let logits = match forward {
        Ok(l) => l,
        // The panicked sessions' KV caches are in an unknown state; the
        // caller drops them. Everything else (model weights, metrics) is
        // shared-immutable or atomic, so recovery is safe.
        Err(_) => return idxs,
    };
    metrics.record_batch_forward(tokens.len());
    for (r, &i) in idxs.iter().enumerate() {
        // audit:allow(index): `idxs` holds enumerate() indices of `active`
        // collected above; bounds hold by construction.
        if let AnySession::Full(s) | AnySession::Draft(s) = &mut active[i].session {
            s.consume_logits(logits.row(r));
        }
    }
    Vec::new()
}

/// One admitted request inside the continuous batch.
struct Active {
    session: AnySession,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub tokens_out: AtomicU64,
    pub total_latency_us: AtomicU64,
    /// Admission rounds that brought at least one new session into the batch.
    pub batches: AtomicU64,
    /// Total decode-path forwards: one per batched plain-group forward
    /// (however many session rows it stacks), one per speculative verify
    /// round. Always `gemm_rounds + matvec_rounds + spec_rounds`.
    pub steps: AtomicU64,
    /// Plain-group forwards that stacked more than one session row — real
    /// blocked-GEMM dispatch per projection.
    pub gemm_rounds: AtomicU64,
    /// Plain-group forwards that held a single session row and took the
    /// matvec fallback kernel.
    pub matvec_rounds: AtomicU64,
    /// Total session rows fed through plain-group forwards (Σ batch sizes —
    /// `avg_batch_rows` in stats is this over the forward count).
    pub batched_rows: AtomicU64,
    /// Largest row count any single plain-group forward stacked.
    pub max_batch_rows: AtomicU64,
    /// Speculative verify rounds (multi-row target forwards).
    pub spec_rounds: AtomicU64,
    /// Tokens the draft proposed across all speculative rounds.
    pub draft_proposed: AtomicU64,
    /// Proposed tokens the target accepted.
    pub draft_accepted: AtomicU64,
    /// Sessions lost to a caught panic in the decode worker (each one
    /// answered with a structured `worker_panic` error instead of taking
    /// the server down).
    pub worker_panics: AtomicU64,
}

impl Metrics {
    pub fn to_json(&self) -> Json {
        let reqs = self.requests.load(Ordering::Relaxed).max(1);
        let rounds = self.spec_rounds.load(Ordering::Relaxed);
        let proposed = self.draft_proposed.load(Ordering::Relaxed);
        let accepted = self.draft_accepted.load(Ordering::Relaxed);
        let tokens_out = self.tokens_out.load(Ordering::Relaxed);
        let steps = self.steps.load(Ordering::Relaxed);
        let gemm = self.gemm_rounds.load(Ordering::Relaxed);
        let matvec = self.matvec_rounds.load(Ordering::Relaxed);
        let brows = self.batched_rows.load(Ordering::Relaxed);
        let mut j = Json::obj();
        j.set("requests", (self.requests.load(Ordering::Relaxed) as f64).into())
            .set("tokens_out", (tokens_out as f64).into())
            .set("batches", (self.batches.load(Ordering::Relaxed) as f64).into())
            .set("decode_steps", (steps as f64).into())
            .set("gemm_rounds", (gemm as f64).into())
            .set("matvec_rounds", (matvec as f64).into())
            .set("max_batch_rows", (self.max_batch_rows.load(Ordering::Relaxed) as f64).into())
            // Mean session rows per plain-group forward: the occupancy
            // number — how much of the continuous batch each dispatched
            // apply actually amortizes.
            .set(
                "avg_batch_rows",
                (if gemm + matvec == 0 { 0.0 } else { brows as f64 / (gemm + matvec) as f64 })
                    .into(),
            )
            // Output tokens amortized per decode-path forward across all
            // tiers — batching and speculative acceptance both raise it.
            .set(
                "tokens_per_forward",
                (if steps == 0 { 0.0 } else { tokens_out as f64 / steps as f64 }).into(),
            )
            .set("spec_rounds", (rounds as f64).into())
            .set("draft_proposed", (proposed as f64).into())
            .set("draft_accepted", (accepted as f64).into())
            // Fraction of drafted tokens the target kept: the health number
            // for a draft/target pairing (1.0 = draft always agrees).
            .set(
                "acceptance_rate",
                (if proposed == 0 { 0.0 } else { accepted as f64 / proposed as f64 }).into(),
            )
            // Accepted draft tokens amortized per verify forward: how many
            // target steps speculation saved per round on average.
            .set(
                "draft_tokens_per_target_forward",
                (if rounds == 0 { 0.0 } else { accepted as f64 / rounds as f64 }).into(),
            )
            .set(
                "mean_latency_ms",
                (self.total_latency_us.load(Ordering::Relaxed) as f64 / reqs as f64 / 1e3).into(),
            )
            .set(
                "worker_panics",
                (self.worker_panics.load(Ordering::Relaxed) as f64).into(),
            );
        j
    }

    /// Account one plain-group batched forward that stacked `rows` session
    /// rows: one decode step (steps count forwards, not rows), classified
    /// as a GEMM round (rows > 1) or a matvec-fallback round (rows == 1),
    /// plus the occupancy aggregates behind `avg_batch_rows` /
    /// `max_batch_rows`.
    pub(crate) fn record_batch_forward(&self, rows: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.batched_rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.max_batch_rows.fetch_max(rows as u64, Ordering::Relaxed);
        if rows > 1 {
            self.gemm_rounds.fetch_add(1, Ordering::Relaxed);
        } else {
            self.matvec_rounds.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn finish(
        &self,
        enqueued: &Timer,
        reply: &mpsc::Sender<GenResponse>,
        tokens: Vec<u16>,
        batch: usize,
        tier: Tier,
    ) {
        let latency = enqueued.secs() * 1e3;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        self.total_latency_us.fetch_add((latency * 1e3) as u64, Ordering::Relaxed);
        let _ = reply.send(GenResponse {
            tokens,
            latency_ms: latency,
            batch,
            tier: tier.name().to_string(),
            error: None,
        });
    }

    /// Answer a request the worker could not serve with a structured error
    /// response instead of dropping its reply channel (which would surface
    /// as an opaque disconnect at the protocol edge). Failures still count
    /// as requests so latency aggregates stay honest.
    pub(crate) fn fail(
        &self,
        enqueued: &Timer,
        reply: &mpsc::Sender<GenResponse>,
        tier: Tier,
        msg: String,
        code: &str,
    ) {
        let latency = enqueued.secs() * 1e3;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add((latency * 1e3) as u64, Ordering::Relaxed);
        if code == "worker_panic" {
            self.worker_panics.fetch_add(1, Ordering::Relaxed);
        }
        let _ = reply.send(GenResponse {
            tokens: Vec::new(),
            latency_ms: latency,
            batch: 0,
            tier: tier.name().to_string(),
            error: Some((msg, code.to_string())),
        });
    }
}

/// Run the server until a shutdown command. Returns the bound address
/// through `on_ready` (port 0 = ephemeral). `info` is static serving
/// metadata (model preset, compression plan, achieved CR — whatever the
/// launcher knows) exposed verbatim on `{"cmd":"info"}`.
///
/// Single-tier convenience wrapper: every request runs on `model` (the
/// `tier` protocol field only admits `"full"`). Launchers with a draft
/// checkpoint use [`serve_blocking_tiers`].
pub fn serve_blocking(
    model: Arc<Model>,
    addr: &str,
    policy: BatchPolicy,
    info: Json,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    serve_blocking_tiers(model, None, 4, addr, policy, info, on_ready)
}

/// Run the server with an optional draft model for speculative serving.
/// With `draft` present the process serves three tiers — `draft` (draft
/// model alone), `full` (target alone), and `spec` (draft proposes up to
/// `draft_k` tokens per round, target verifies in one multi-row forward;
/// greedy output token-identical to `full`) — with `spec` the default tier.
#[allow(clippy::too_many_arguments)]
pub fn serve_blocking_tiers(
    model: Arc<Model>,
    draft: Option<Arc<Model>>,
    draft_k: usize,
    addr: &str,
    policy: BatchPolicy,
    info: Json,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    if let Some(d) = &draft {
        anyhow::ensure!(
            d.cfg.vocab == model.cfg.vocab,
            "draft/target vocab mismatch: {} vs {}",
            d.cfg.vocab,
            model.cfg.vocab
        );
    }
    anyhow::ensure!(draft_k >= 1, "draft_k must be >= 1");
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);

    // The server always knows its real memory footprint: packed-quantized
    // models report the bytes actually resident, and mmap-loaded models
    // split that into heap-resident vs mapping-borrowed (page-cache-shared)
    // bytes — the numbers capacity planning across serve workers needs.
    let mut info = info;
    info.set("resident_weight_bytes", model.resident_weight_bytes().into());
    info.set("mapped_weight_bytes", model.mapped_weight_bytes().into());
    // Where the weights came from: zero-copy checkpoint mapping ("mmap"),
    // a cold-loaded compressed checkpoint (launcher set "checkpoint"), or
    // an in-process model — so operators can tell a CPT2-restored server
    // from one that recompressed at startup.
    if info.get("weights_source").is_none() {
        let src = if model.weights_mapped() {
            "mmap"
        } else if info.get("checkpoint").is_some() {
            "checkpoint"
        } else {
            "in-memory"
        };
        info.set("weights_source", src.into());
    }
    // Tier routing metadata: which tiers this process serves and the
    // default applied when a request omits the `tier` field.
    let has_draft = draft.is_some();
    info.set("tier_default", if has_draft { "spec" } else { "full" }.into());
    if let Some(d) = &draft {
        info.set("draft_k", draft_k.into());
        info.set("draft_resident_weight_bytes", d.resident_weight_bytes().into());
        info.set("draft_mapped_weight_bytes", d.mapped_weight_bytes().into());
    }
    let info = Arc::new(info);
    let batcher: Arc<Batcher<Job>> = Arc::new(Batcher::new(policy));
    let metrics = Arc::new(Metrics::default());
    let shutdown = Arc::new(AtomicBool::new(false));

    // Worker: continuous batching over decode sessions. One token step per
    // active session per round; new sessions are admitted into free slots
    // between rounds, finished ones flush and leave immediately.
    let worker = {
        let batcher = batcher.clone();
        let metrics = metrics.clone();
        let model = model.clone();
        let draft = draft.clone();
        std::thread::spawn(move || {
            let mut active: Vec<Active> = Vec::new();
            loop {
                let slots = policy.max_batch.saturating_sub(active.len());
                let incoming = if active.is_empty() {
                    let batch = batcher.next_batch();
                    if batch.is_empty() {
                        break; // closed + drained, nothing in flight
                    }
                    batch
                } else if slots > 0 {
                    batcher.try_drain(slots)
                } else {
                    Vec::new()
                };
                if !incoming.is_empty() {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                }
                for job in incoming {
                    if job.req.prompt.is_empty() || job.req.max_new == 0 {
                        metrics.finish(
                            &job.enqueued,
                            &job.reply,
                            Vec::new(),
                            active.len() + 1,
                            job.req.tier,
                        );
                        continue;
                    }
                    // The protocol edge already resolved the tier against
                    // the loaded models, so `None` here (a draft tier on a
                    // draftless worker) is a defensive belt: it answers with
                    // a structured error rather than panicking the worker.
                    // Prefill runs under catch_unwind for the same reason —
                    // a model that panics on this prompt must cost exactly
                    // this request.
                    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        match job.req.tier {
                            Tier::Full => Some(AnySession::Full(DecodeSession::start(
                                &model,
                                &job.req.prompt,
                                job.req.max_new,
                                job.req.sampling,
                            ))),
                            Tier::Draft => draft.as_deref().map(|d| {
                                AnySession::Draft(DecodeSession::start(
                                    d,
                                    &job.req.prompt,
                                    job.req.max_new,
                                    job.req.sampling,
                                ))
                            }),
                            Tier::Spec => draft.as_deref().map(|d| {
                                AnySession::Spec(SpeculativeSession::start(
                                    &model,
                                    d,
                                    &job.req.prompt,
                                    job.req.max_new,
                                    draft_k,
                                ))
                            }),
                        }
                    }));
                    match built {
                        Ok(Some(session)) => active.push(Active {
                            session,
                            enqueued: job.enqueued,
                            reply: job.reply,
                        }),
                        Ok(None) => metrics.fail(
                            &job.enqueued,
                            &job.reply,
                            job.req.tier,
                            format!(
                                "tier '{}' admitted without a draft model",
                                job.req.tier.name()
                            ),
                            "tier_unavailable",
                        ),
                        Err(_) => metrics.fail(
                            &job.enqueued,
                            &job.reply,
                            job.req.tier,
                            "model panicked during prefill".to_string(),
                            "worker_panic",
                        ),
                    }
                }
                // One turn per running session per round. The plain tiers
                // step through one batched forward per model — all full
                // sessions stack into a single target forward, all draft
                // sessions into a single draft forward (one apply per
                // projection per layer each; matvec at batch 1) — while
                // spec sessions run their own draft/verify rounds. Then
                // retire finished sessions so their slots free up for the
                // next admission.
                let mut failed = step_plain_group(&model, &mut active, false, &metrics);
                if let Some(d) = draft.as_deref() {
                    failed.extend(step_plain_group(d, &mut active, true, &metrics));
                    // Spec sessions only exist on draft-loaded servers (the
                    // protocol edge rejects the tier otherwise), so their
                    // rounds live under this branch — no expect needed.
                    for (i, a) in active.iter_mut().enumerate() {
                        if let AnySession::Spec(s) = &mut a.session {
                            if s.is_done() {
                                continue;
                            }
                            let round = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| s.round(&model, d)),
                            );
                            match round {
                                Ok(Some(r)) => {
                                    metrics.steps.fetch_add(1, Ordering::Relaxed);
                                    metrics.spec_rounds.fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .draft_proposed
                                        .fetch_add(r.proposed as u64, Ordering::Relaxed);
                                    metrics
                                        .draft_accepted
                                        .fetch_add(r.accepted as u64, Ordering::Relaxed);
                                }
                                Ok(None) => {}
                                Err(_) => failed.push(i),
                            }
                        }
                    }
                }
                // Retire panicked sessions with a structured error. Indices
                // come from disjoint passes over the same `active`; removing
                // in descending order keeps the remaining ones valid across
                // swap_remove.
                failed.sort_unstable_by(|a, b| b.cmp(a));
                for i in failed {
                    let dead = active.swap_remove(i);
                    let tier = dead.session.tier();
                    metrics.fail(
                        &dead.enqueued,
                        &dead.reply,
                        tier,
                        "model panicked during decode".to_string(),
                        "worker_panic",
                    );
                }
                let bsize = active.len();
                active.retain_mut(|a| {
                    if !a.session.is_done() {
                        return true;
                    }
                    let tier = a.session.tier();
                    metrics.finish(
                        &a.enqueued,
                        &a.reply,
                        a.session.generated().to_vec(),
                        bsize,
                        tier,
                    );
                    false
                });
            }
        })
    };

    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let info = info.clone();
                let vocab = model.cfg.vocab;
                conns.push(std::thread::spawn(move || {
                    let _ =
                        handle_conn(stream, &batcher, &metrics, &info, &shutdown, vocab, has_draft);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    // Graceful drain: no new work, but everything queued or in flight
    // decodes to completion and flushes before the worker exits.
    batcher.close();
    let _ = worker.join();
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Structured protocol error: a human-readable `error` plus a stable
/// machine-readable `code` clients can branch on.
pub(crate) fn protocol_error(msg: String, code: &str) -> String {
    let mut e = Json::obj();
    e.set("error", msg.into()).set("code", code.into());
    e.to_string()
}

fn handle_conn(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    info: &Json,
    shutdown: &AtomicBool,
    vocab: usize,
    has_draft: bool,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
            match cmd {
                "stats" => {
                    writeln!(writer, "{}", metrics.to_json().to_string())?;
                }
                "info" => {
                    writeln!(writer, "{}", info.to_string())?;
                }
                "shutdown" => {
                    shutdown.store(true, Ordering::Relaxed);
                    writeln!(writer, "{{\"ok\":true}}")?;
                    break;
                }
                _ => writeln!(writer, "{{\"error\":\"unknown cmd\"}}")?,
            }
            continue;
        }
        // Validate token ids here, at the protocol edge: an out-of-range id
        // would panic the (single) decode worker inside embed_tokens and
        // wedge the whole server.
        let raw: Vec<usize> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        if raw.iter().any(|&t| t >= vocab) {
            writeln!(writer, "{{\"error\":\"prompt token out of range (vocab {vocab})\"}}")?;
            continue;
        }
        let prompt: Vec<u16> = raw.into_iter().map(|t| t as u16).collect();
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let sampling = sampler_cfg_from_json(&j);
        // Resolve the requested tier at the edge, with structured errors —
        // a silently ignored `tier` field would let a client believe it got
        // draft-speed or spec-verified output it never did.
        let tier = match j.get("tier").and_then(Json::as_str) {
            None => {
                if has_draft {
                    Tier::Spec
                } else {
                    Tier::Full
                }
            }
            Some(s) => match Tier::parse(s) {
                Some(t) => t,
                None => {
                    writeln!(
                        writer,
                        "{}",
                        protocol_error(
                            format!("unknown tier '{s}' (expected draft | spec | full)"),
                            "unknown_tier",
                        )
                    )?;
                    continue;
                }
            },
        };
        if tier != Tier::Full && !has_draft {
            writeln!(
                writer,
                "{}",
                protocol_error(
                    format!("tier '{}' requires a server started with --draft", tier.name()),
                    "tier_unavailable",
                )
            )?;
            continue;
        }
        // Speculative acceptance is argmax-vs-argmax, i.e. greedy; sampled
        // requests take the full tier (the response reports what ran).
        let tier = if tier == Tier::Spec && !sampling.is_greedy() { Tier::Full } else { tier };
        let (tx, rx) = mpsc::channel();
        let accepted = batcher.push(Job {
            req: GenRequest { prompt, max_new, sampling, tier },
            enqueued: Timer::start(),
            reply: tx,
        });
        if !accepted {
            writeln!(writer, "{{\"error\":\"server shutting down\"}}")?;
            continue;
        }
        let resp = rx.recv()?;
        if let Some((msg, code)) = resp.error {
            writeln!(writer, "{}", protocol_error(msg, &code))?;
            continue;
        }
        let mut out = Json::obj();
        out.set("tokens", Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("latency_ms", resp.latency_ms.into())
            .set("batch", resp.batch.into())
            .set("tier", resp.tier.into());
        writeln!(writer, "{}", out.to_string())?;
    }
    Ok(())
}

/// Simple blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Greedy request (temperature 0).
    pub fn request(&mut self, prompt: &[u16], max_new: usize) -> anyhow::Result<GenResponse> {
        self.request_with(prompt, max_new, SamplerCfg::greedy())
    }

    /// Greedy request pinned to a specific tier (`"draft"` | `"spec"` |
    /// `"full"`).
    pub fn request_tier(
        &mut self,
        prompt: &[u16],
        max_new: usize,
        tier: &str,
    ) -> anyhow::Result<GenResponse> {
        let mut j = Json::obj();
        j.set("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("max_new", max_new.into())
            .set("tier", tier.into());
        let r = self.request_raw(&j)?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(Self::parse_response(&r))
    }

    /// Request with explicit sampling controls.
    pub fn request_with(
        &mut self,
        prompt: &[u16],
        max_new: usize,
        sampling: SamplerCfg,
    ) -> anyhow::Result<GenResponse> {
        let mut j = Json::obj();
        j.set("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("max_new", max_new.into());
        if !sampling.is_greedy() {
            j.set("temperature", (sampling.temperature as f64).into())
                .set("top_k", sampling.top_k.into())
                .set("seed", (sampling.seed as f64).into());
        }
        let r = self.request_raw(&j)?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(Self::parse_response(&r))
    }

    /// Send an arbitrary request object and return the raw response JSON
    /// without interpreting `error` fields — the hook protocol-hardening
    /// tests use to inspect structured error codes.
    pub fn request_raw(&mut self, j: &Json) -> anyhow::Result<Json> {
        writeln!(self.stream, "{}", j.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    fn parse_response(r: &Json) -> GenResponse {
        GenResponse {
            tokens: r
                .get("tokens")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_usize().map(|v| v as u16)).collect())
                .unwrap_or_default(),
            latency_ms: r.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch: r.get("batch").and_then(Json::as_usize).unwrap_or(0),
            tier: r.get("tier").and_then(Json::as_str).unwrap_or("").to_string(),
            error: r.get("error").and_then(Json::as_str).map(|e| {
                let code = r.get("code").and_then(Json::as_str).unwrap_or("");
                (e.to_string(), code.to_string())
            }),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"stats\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad stats: {e}"))
    }

    pub fn info(&mut self) -> anyhow::Result<Json> {
        writeln!(self.stream, "{{\"cmd\":\"info\"}}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad info: {e}"))
    }

    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        writeln!(self.stream, "{{\"cmd\":\"shutdown\"}}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;
    use std::time::Duration;

    fn spawn_server(
        seed: u64,
        policy: BatchPolicy,
        info: Json,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed)));
        let (addr_tx, addr_rx) = mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_blocking(model, "127.0.0.1:0", policy, info, |a| {
                addr_tx.send(a).unwrap();
            })
            .unwrap();
        });
        (addr_rx.recv().unwrap(), server)
    }

    #[test]
    fn end_to_end_serve_and_shutdown() {
        let mut info = Json::obj();
        info.set("model", "test-tiny".into());
        let (addr, server) = spawn_server(1, BatchPolicy::default(), info);
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        assert_eq!(info.get("model").and_then(Json::as_str), Some("test-tiny"));
        // the server injects its real memory footprint into the metadata
        assert!(info.get("resident_weight_bytes").and_then(Json::as_usize).unwrap() > 0);
        let r = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert!(r.latency_ms >= 0.0);
        // deterministic: same prompt → same continuation
        let r2 = client.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tokens, r2.tokens);
        // empty prompts are answered (with nothing), not panicked on
        let r3 = client.request(&[], 4).unwrap();
        assert!(r3.tokens.is_empty());
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("requests").and_then(Json::as_usize), Some(3));
        assert!(stats.get("decode_steps").and_then(Json::as_usize).unwrap() >= 6);
        client.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn info_reports_checkpoint_origin() {
        // A launcher serving a CPT2 checkpoint passes its path and plan in
        // the metadata; the server must surface them plus a weights_source
        // tag, and default to "in-memory" otherwise.
        let mut info = Json::obj();
        info.set("model", "test-tiny".into())
            .set("checkpoint", "tiny-t7.cpt2".into())
            .set("plan", "compot@0.25 → gptq4".into());
        let (addr, server) = spawn_server(7, BatchPolicy::default(), info);
        let mut client = Client::connect(addr).unwrap();
        let got = client.info().unwrap();
        assert_eq!(got.get("checkpoint").and_then(Json::as_str), Some("tiny-t7.cpt2"));
        assert_eq!(got.get("plan").and_then(Json::as_str), Some("compot@0.25 → gptq4"));
        assert_eq!(got.get("weights_source").and_then(Json::as_str), Some("checkpoint"));
        client.shutdown().unwrap();
        server.join().unwrap();

        let (addr, server) = spawn_server(8, BatchPolicy::default(), Json::obj());
        let mut client = Client::connect(addr).unwrap();
        let got = client.info().unwrap();
        assert_eq!(got.get("weights_source").and_then(Json::as_str), Some("in-memory"));
        client.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn mmap_loaded_server_is_token_identical_to_owned() {
        // The serve-smoke contract behind `--load-compressed --mmap`: a
        // server whose weights are zero-copy views into the checkpoint
        // mapping answers every request with exactly the tokens the
        // owned-load server produces, and reports weights_source "mmap"
        // with a real mapped-bytes figure.
        use crate::compress::StageConfig;
        use crate::coordinator::plan::CompressionPlan;
        use crate::data::SynthLang;

        let base = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(21));
        let lang = SynthLang::wiki(base.cfg.vocab);
        let calib = lang.gen_batch(6, 48, &mut Rng::new(22));
        let plan = CompressionPlan::parse("compot@0.25+gptq4", &StageConfig::new(0.25, false))
            .unwrap();
        let compressed = plan.run(&base, &calib).unwrap().0;
        let dir = std::env::temp_dir().join("compot_serve_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.cpt2");
        compressed.save_compressed(&path, Some("compot@0.25+gptq4")).unwrap();

        let (owned, _) = Model::load_compressed(&path).unwrap();
        let (mapped, ck) = Model::load_compressed_mmap(&path).unwrap();
        // on hosts without working mmap the loader takes its documented
        // heap fallback; parity must hold either way, the info assertions
        // below only apply to a true mapping
        assert!(ck.source.starts_with("mmap"), "{}", ck.source);
        let true_mmap = ck.source == "mmap";
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[7, 8, 9, 10], &[5]];
        let expected: Vec<Vec<u16>> =
            prompts.iter().map(|p| owned.greedy_decode(p, 6)).collect();

        let (addr_tx, addr_rx) = mpsc::channel();
        let mapped = Arc::new(mapped);
        let server = {
            let mapped = mapped.clone();
            std::thread::spawn(move || {
                serve_blocking(mapped, "127.0.0.1:0", BatchPolicy::default(), Json::obj(), |a| {
                    addr_tx.send(a).unwrap();
                })
                .unwrap();
            })
        };
        let addr = addr_rx.recv().unwrap();
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        if true_mmap {
            assert_eq!(info.get("weights_source").and_then(Json::as_str), Some("mmap"));
            assert!(info.get("mapped_weight_bytes").and_then(Json::as_usize).unwrap() > 0);
        }
        for (p, want) in prompts.iter().zip(expected.iter()) {
            let got = client.request(p, 6).unwrap().tokens;
            assert_eq!(&got, want, "mmap-served continuation diverged for {p:?}");
        }
        client.shutdown().unwrap();
        server.join().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_clients_are_all_served() {
        let (addr, server) = spawn_server(
            2,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) },
            Json::obj(),
        );
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i, i + 1], 3).unwrap().tokens.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn batched_decoding_matches_single_stream_decoding() {
        // Continuous batching must not change any request's continuation:
        // fire the same prompt alone and alongside five others.
        let (addr, server) = spawn_server(
            3,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) },
            Json::obj(),
        );
        let mut alone = Client::connect(addr).unwrap();
        let solo = alone.request(&[7, 8, 9], 6).unwrap().tokens;
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let p: Vec<u16> = if i == 0 { vec![7, 8, 9] } else { vec![i, i * 2, i * 3] };
                (i, c.request(&p, 6).unwrap().tokens)
            }));
        }
        for h in handles {
            let (i, tokens) = h.join().unwrap();
            if i == 0 {
                assert_eq!(tokens, solo, "batched continuation differs from solo");
            }
            assert_eq!(tokens.len(), 6);
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn stats_report_batch_occupancy() {
        // Six concurrent full-tier requests against a max_batch-8 worker:
        // the batched rounds must show up in the occupancy metrics, and the
        // forward classification must exactly partition decode_steps.
        let (addr, server) = spawn_server(
            12,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
            Json::obj(),
        );
        let mut handles = Vec::new();
        for i in 0..6u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i + 1, i + 2], 8).unwrap().tokens.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 8);
        }
        let mut c = Client::connect(addr).unwrap();
        let stats = c.stats().unwrap();
        let gemm = stats.get("gemm_rounds").and_then(Json::as_usize).unwrap();
        let matvec = stats.get("matvec_rounds").and_then(Json::as_usize).unwrap();
        let spec = stats.get("spec_rounds").and_then(Json::as_usize).unwrap();
        let steps = stats.get("decode_steps").and_then(Json::as_usize).unwrap();
        assert_eq!(gemm + matvec + spec, steps, "round classes must partition decode_steps");
        // the 50ms admission window makes truly serialized execution of six
        // concurrent 8-token requests effectively impossible
        assert!(gemm >= 1, "no multi-session GEMM round recorded");
        let maxb = stats.get("max_batch_rows").and_then(Json::as_usize).unwrap();
        assert!((2..=8).contains(&maxb), "max_batch_rows {maxb}");
        let avg = stats.get("avg_batch_rows").and_then(Json::as_f64).unwrap();
        assert!((1.0..=8.0).contains(&avg), "avg_batch_rows {avg}");
        assert!(stats.get("tokens_per_forward").and_then(Json::as_f64).unwrap() > 0.0);
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn out_of_range_tokens_are_rejected_without_killing_the_worker() {
        let (addr, server) = spawn_server(6, BatchPolicy::default(), Json::obj());
        let mut c = Client::connect(addr).unwrap();
        // vocab is 64 for test-tiny: 9999 must be rejected at the edge...
        let err = c.request(&[9999, 1], 4);
        assert!(err.is_err(), "out-of-range prompt must be rejected");
        // ...and the worker must still be alive to serve valid requests.
        let ok = c.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(ok.tokens.len(), 4);
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn sampled_requests_are_seed_deterministic() {
        let (addr, server) = spawn_server(4, BatchPolicy::default(), Json::obj());
        let mut c = Client::connect(addr).unwrap();
        let cfg = SamplerCfg { temperature: 0.9, top_k: 4, seed: 11 };
        let a = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        let b = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens.len(), 8);
        assert!(a.tokens.iter().all(|&t| t < 64));
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    /// 4-bit-pack every dense projection: the cheap same-network draft the
    /// speculative tier is designed around.
    fn quantized_draft(target: &Model) -> Model {
        use crate::compress::LinearWeight;
        use crate::linalg::QuantMat;
        use crate::model::config::ProjKind;
        use crate::model::transformer::Stage;
        let mut d = target.clone();
        for stage in d.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let packed = match b.proj(p) {
                        LinearWeight::Dense(w) => Some(QuantMat::quantize_from(w, 4)),
                        _ => None,
                    };
                    if let Some(q) = packed {
                        *b.proj_mut(p) = LinearWeight::QuantDense(q);
                    }
                }
            }
        }
        d
    }

    fn spawn_tier_server(
        target: Arc<Model>,
        draft: Option<Arc<Model>>,
        draft_k: usize,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let (addr_tx, addr_rx) = mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_blocking_tiers(
                target,
                draft,
                draft_k,
                "127.0.0.1:0",
                BatchPolicy::default(),
                Json::obj(),
                |a| {
                    addr_tx.send(a).unwrap();
                },
            )
            .unwrap();
        });
        (addr_rx.recv().unwrap(), server)
    }

    #[test]
    fn tier_requests_without_draft_get_structured_errors() {
        // Protocol hardening: a draftless server must refuse — with a
        // machine-readable code, not silence — both unknown tier names and
        // tiers it cannot serve.
        let (addr, server) = spawn_server(9, BatchPolicy::default(), Json::obj());
        let mut c = Client::connect(addr).unwrap();

        let mut req = Json::obj();
        req.set("prompt", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]))
            .set("max_new", 3.into())
            .set("tier", "turbo".into());
        let r = c.request_raw(&req).unwrap();
        assert!(r.get("error").is_some(), "unknown tier must be an error");
        assert_eq!(r.get("code").and_then(Json::as_str), Some("unknown_tier"));

        for t in ["spec", "draft"] {
            let mut req = Json::obj();
            req.set("prompt", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]))
                .set("max_new", 3.into())
                .set("tier", t.into());
            let r = c.request_raw(&req).unwrap();
            assert!(r.get("error").is_some(), "tier '{t}' without --draft must be an error");
            assert_eq!(r.get("code").and_then(Json::as_str), Some("tier_unavailable"), "{t}");
        }

        // explicit "full" and the default both still work, and the worker
        // survived the rejected requests
        let r = c.request_tier(&[1, 2, 3], 4, "full").unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.tier, "full");
        let r = c.request(&[1, 2, 3], 4).unwrap();
        assert_eq!(r.tier, "full", "draftless default tier must be full");
        let info = c.info().unwrap();
        assert_eq!(info.get("tier_default").and_then(Json::as_str), Some("full"));
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn panicking_session_degrades_one_request_not_the_server() {
        // The mutex-poison cascade regression: a session that panics inside
        // the decode worker must cost exactly that request — answered with a
        // structured `worker_panic` error — while the server keeps serving
        // other tiers and `stats` keeps answering. We provoke the panic with
        // a deliberately broken draft model whose embedding table has one
        // row: any admitted draft-tier token >= 1 indexes out of range.
        let target = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(41));
        let mut broken = quantized_draft(&target);
        broken.embed = broken.embed.rows_range(0, 1);
        let (addr, server) = spawn_tier_server(Arc::new(target), Some(Arc::new(broken)), 2);
        let mut c = Client::connect(addr).unwrap();

        let mut req = Json::obj();
        req.set("prompt", Json::Arr(vec![Json::Num(2.0), Json::Num(3.0)]))
            .set("max_new", 4.into())
            .set("tier", "draft".into());
        let r = c.request_raw(&req).unwrap();
        assert!(r.get("error").is_some(), "panicked session must answer with an error");
        assert_eq!(r.get("code").and_then(Json::as_str), Some("worker_panic"));

        // The worker survived: the full tier (healthy target model) serves...
        let ok = c.request_tier(&[2, 3], 4, "full").unwrap();
        assert_eq!(ok.tokens.len(), 4);
        assert_eq!(ok.tier, "full");
        // ...and stats still answers, with the panic on the books.
        let stats = c.stats().unwrap();
        assert!(stats.get("worker_panics").and_then(Json::as_usize).unwrap() >= 1);
        assert!(stats.get("requests").and_then(Json::as_usize).unwrap() >= 2);
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn draft_server_serves_three_tiers_with_spec_identical_to_full() {
        // The PR's acceptance contract: one process, three tiers; greedy
        // spec output token-identical to full; acceptance metrics in stats.
        let target = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(31));
        let draft = quantized_draft(&target);
        let want_full = target.greedy_decode(&[3, 1, 4, 1, 5], 10);
        let want_draft = draft.greedy_decode(&[3, 1, 4, 1, 5], 10);
        let (addr, server) = spawn_tier_server(Arc::new(target), Some(Arc::new(draft)), 4);
        let mut c = Client::connect(addr).unwrap();

        let info = c.info().unwrap();
        assert_eq!(info.get("tier_default").and_then(Json::as_str), Some("spec"));
        assert_eq!(info.get("draft_k").and_then(Json::as_usize), Some(4));

        let full = c.request_tier(&[3, 1, 4, 1, 5], 10, "full").unwrap();
        assert_eq!(full.tokens, want_full);
        assert_eq!(full.tier, "full");
        let spec = c.request_tier(&[3, 1, 4, 1, 5], 10, "spec").unwrap();
        assert_eq!(spec.tokens, want_full, "spec output diverged from full");
        assert_eq!(spec.tier, "spec");
        let draft_r = c.request_tier(&[3, 1, 4, 1, 5], 10, "draft").unwrap();
        assert_eq!(draft_r.tokens, want_draft);
        assert_eq!(draft_r.tier, "draft");
        // omitted tier defaults to spec on a draft-loaded server
        let default_r = c.request(&[3, 1, 4, 1, 5], 10).unwrap();
        assert_eq!(default_r.tier, "spec");
        assert_eq!(default_r.tokens, want_full);

        let stats = c.stats().unwrap();
        assert!(stats.get("spec_rounds").and_then(Json::as_usize).unwrap() >= 1);
        assert!(stats.get("draft_proposed").and_then(Json::as_usize).unwrap() >= 1);
        let rate = stats.get("acceptance_rate").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&rate), "acceptance_rate {rate}");
        assert!(
            stats.get("draft_tokens_per_target_forward").and_then(Json::as_f64).unwrap() >= 0.0
        );
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn non_greedy_spec_requests_fall_back_to_full_tier() {
        // Speculative acceptance is argmax-vs-argmax; a sampled request on
        // the spec tier must run (and report) the full tier instead, with
        // the same seed-determinism as a direct full-tier request.
        let target = Model::random(&ModelConfig::test_tiny(), &mut Rng::new(33));
        let draft = quantized_draft(&target);
        let (addr, server) = spawn_tier_server(Arc::new(target), Some(Arc::new(draft)), 4);
        let mut c = Client::connect(addr).unwrap();
        let mut req = Json::obj();
        req.set("prompt", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0), Json::Num(3.0)]))
            .set("max_new", 8.into())
            .set("tier", "spec".into())
            .set("temperature", 0.9.into())
            .set("top_k", 4.into())
            .set("seed", 11.into());
        let a = c.request_raw(&req).unwrap();
        assert_eq!(a.get("tier").and_then(Json::as_str), Some("full"));
        let sampled =
            c.request_with(&[1, 2, 3], 8, SamplerCfg { temperature: 0.9, top_k: 4, seed: 11 });
        let b = sampled.unwrap();
        let a_tokens: Vec<u16> = a
            .get("tokens")
            .and_then(Json::as_arr)
            .map(|v| v.iter().filter_map(|x| x.as_usize().map(|t| t as u16)).collect())
            .unwrap();
        assert_eq!(a_tokens, b.tokens);
        c.shutdown().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn graceful_shutdown_flushes_in_flight_and_queued_requests() {
        // max_batch 2 forces some of the 5 requests to sit in the queue when
        // shutdown lands; all of them must still get full responses.
        let (addr, server) = spawn_server(
            5,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            Json::obj(),
        );
        let mut handles = Vec::new();
        for i in 0..5u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.request(&[i + 1, i + 2, i + 3], 24)
            }));
        }
        // Let every request reach the queue (the accept loop polls every
        // 2ms), then pull the plug mid-flight.
        std::thread::sleep(Duration::from_millis(50));
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        // The invariant under test: an *accepted* request is never dropped or
        // truncated by shutdown. A client thread scheduled so late that its
        // push lost the race gets the explicit rejection error — allowed, but
        // on any sane scheduler the 50ms head start means most (usually all)
        // requests are accepted, and at least one must be.
        let mut accepted = 0;
        for h in handles {
            match h.join().unwrap() {
                Ok(r) => {
                    assert_eq!(r.tokens.len(), 24, "request dropped during shutdown");
                    accepted += 1;
                }
                Err(e) => {
                    assert!(
                        e.to_string().contains("shutting down"),
                        "unexpected error during shutdown: {e}"
                    );
                }
            }
        }
        assert!(accepted >= 1, "no request beat a 50ms-delayed shutdown");
        server.join().unwrap();
    }
}
