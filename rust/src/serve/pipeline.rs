//! Two-stage pipeline serving: one process per stage range of a (sharded)
//! checkpoint, hidden states relayed between them over JSON-lines TCP.
//!
//! A pipeline process is launched with `serve --stages LO..HI` on a partial
//! model ([`Model::load_stage_range`]) and plays one of two roles:
//!
//! - **head** (`LO == 0`, `--next HOST:PORT` given): owns the embedding and
//!   the client-facing serve protocol (the same JSON-lines request shape
//!   [`super::server`] speaks, so [`super::Client`] works unchanged). It
//!   embeds tokens, runs its stage range against per-session KV caches, and
//!   relays the resulting f32 hidden rows to the next hop.
//! - **tail** (`HI == n_stages`, no `--next`): owns the final norm, the LM
//!   head, and each session's [`Sampler`]. It advances its stage range on
//!   the relayed rows, samples the next token, and answers back along the
//!   same connection.
//!
//! Middle hops (`LO > 0` with `--next`) are rejected with a structured
//! error — >2-host pipelines (and relay retry/timeout) are a recorded
//! ROADMAP follow-up.
//!
//! ## Relay frame protocol (head → tail, one JSON object per line)
//!
//! ```text
//! {"op":"open","sid":7,"temperature":0.8,"top_k":20,"seed":9} → {"ok":true}
//! {"op":"prefill","sid":7,"pos":0,"rows":T,"cols":D,"h":[..]} → {"token":t}
//! {"op":"round","sids":[7,9],"pos":[5,3],"cols":D,"h":[..]}   → {"tokens":[..]}
//! {"op":"truncate","sid":7,"len":4}                           → {"ok":true}
//! {"op":"close","sid":7}                                      → {"ok":true}
//! {"op":"stats"}                                              → {"sessions":n}
//! {"op":"shutdown"}                                           → {"ok":true}
//! errors: {"error":"...","code":"bad_frame|unknown_session|worker_panic"}
//! ```
//!
//! Hidden rows cross the wire as the `u32` bit patterns of their f32 values
//! (`f32::to_bits`, row-major in `"h"`), because JSON decimal round-trips
//! are lossy and the whole point is **bit-identity**: a 2-process pipeline
//! must produce exactly the tokens single-host serve produces. That holds
//! because each stage runs the same kernels the single-host path runs —
//! batched rounds go through [`Model::decode_hidden_batch`] (one GEMM per
//! projection per layer per round, the PR 7 shape, falling back to per-row
//! kernels at batch 1) and the tail finishes with the same per-row
//! norm+head kernel [`Model::decode_step`] ends with. Parity is tested in
//! `model/decode.rs` (kernel level), below (socket level), and in
//! `tests/integration.rs` (all six `LinearWeight` variants, owned + mmap).
//!
//! Failure modes are structured, never panics (audit rule L3 applies to
//! this file): a dead relay fails the in-flight requests with
//! `relay_error` and the head keeps answering; a panicking model forward is
//! caught and costs exactly the sessions in that round (`worker_panic`);
//! malformed or out-of-order frames get `bad_frame`/`unknown_session`
//! responses and the relay connection stays up. The tail's session table is
//! an `RwLock` map accessed only through the poison-recovering
//! [`super::read_recover`]/[`super::write_recover`] helpers (audit rule
//! L4).

use super::batcher::{BatchPolicy, Batcher};
use super::server::{protocol_error, GenResponse, Metrics};
use super::spec::Tier;
use super::{read_recover, write_recover};
use crate::linalg::Mat;
use crate::model::decode::{sampler_cfg_from_json, KvCache, Sampler, SamplerCfg};
use crate::model::Model;
use crate::util::json::Json;
use crate::util::Timer;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, RwLock};

/// Role of one pipeline process, derived from its `--stages` range and
/// whether `--next` was given.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineRole {
    Head,
    Tail,
}

/// Parse a `--stages LO..HI` flag value (half-open, absolute stage
/// indices).
pub fn parse_stage_range(s: &str) -> anyhow::Result<Range<usize>> {
    let (lo, hi) = s
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("--stages wants a half-open range LO..HI, got '{s}'"))?;
    let lo: usize = lo
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--stages: '{lo}' is not a stage index"))?;
    let hi: usize = hi
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--stages: '{hi}' is not a stage index"))?;
    Ok(lo..hi)
}

/// Decide which pipeline role a `--stages LO..HI` process plays against a
/// checkpoint with `n_stages` stages. Every unsupported combination is a
/// structured error: middle hops (a range touching neither end) are
/// explicitly not supported yet — >2-host relaying is a recorded ROADMAP
/// follow-up.
pub fn pipeline_role(
    range: &Range<usize>,
    n_stages: usize,
    has_next: bool,
) -> anyhow::Result<PipelineRole> {
    anyhow::ensure!(
        range.start < range.end,
        "--stages {}..{} is an empty range",
        range.start,
        range.end
    );
    anyhow::ensure!(
        range.end <= n_stages,
        "--stages {}..{} is outside the checkpoint's {n_stages} stages",
        range.start,
        range.end
    );
    match (range.start == 0, range.end == n_stages, has_next) {
        (true, true, _) => anyhow::bail!(
            "--stages 0..{n_stages} covers the whole model — drop --stages for single-host serve"
        ),
        (true, false, true) => Ok(PipelineRole::Head),
        (true, false, false) => anyhow::bail!(
            "the head stage (--stages 0..{}) needs --next HOST:PORT to relay hidden states to",
            range.end
        ),
        (false, true, false) => Ok(PipelineRole::Tail),
        (false, true, true) => anyhow::bail!(
            "the tail stage holds the LM head and answers on the return path — it takes no --next"
        ),
        (false, false, _) => anyhow::bail!(
            "middle pipeline hops (--stages {}..{} of {n_stages}) are not supported yet: \
             only 2-stage head/tail pipelines run today (>2 hosts with relay retry/timeout \
             is a ROADMAP follow-up)",
            range.start,
            range.end
        ),
    }
}

/// Encode a hidden-row matrix as the row-major `u32` bit patterns of its
/// f32 values — exact over JSON, where decimal floats are not.
fn bits_of_rows(m: &Mat) -> Json {
    let mut a = Vec::with_capacity(m.rows() * m.cols());
    for r in 0..m.rows() {
        for &v in m.row(r) {
            a.push(Json::Num(f32::to_bits(v) as f64));
        }
    }
    Json::Arr(a)
}

/// Decode a `"h"` frame field back into a rows×cols matrix.
fn rows_from_bits(arr: &[Json], rows: usize, cols: usize) -> anyhow::Result<Mat> {
    anyhow::ensure!(
        arr.len() == rows * cols,
        "hidden frame holds {} values, expected {rows}×{cols}",
        arr.len()
    );
    let mut data = Vec::with_capacity(arr.len());
    for v in arr {
        // strict: as_usize would silently truncate 0.5 → 0
        let x = v
            .as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= u32::MAX as f64)
            .ok_or_else(|| anyhow::anyhow!("hidden frame holds a non-u32 bit pattern"))?;
        data.push(f32::from_bits(x as u32));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Head-side client for the relay protocol: one persistent connection to
/// the next hop, strictly synchronous frame → response. Also the raw
/// handle the protocol tests drive the tail with.
pub struct RelayClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RelayClient {
    pub fn connect(addr: &str) -> anyhow::Result<RelayClient> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(RelayClient { stream, reader })
    }

    /// Send one frame and wait for its response line; a structured error
    /// response becomes an `Err` carrying the relay's message and code.
    fn call(&mut self, j: &Json) -> anyhow::Result<Json> {
        writeln!(self.stream, "{}", j.to_string())?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "relay connection closed mid-call");
        let r = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad relay response: {e}"))?;
        if let Some(err) = r.get("error").and_then(Json::as_str) {
            let code = r.get("code").and_then(Json::as_str).unwrap_or("relay_error");
            anyhow::bail!("relay error ({code}): {err}");
        }
        Ok(r)
    }

    /// Open a session on the tail: it allocates the sampler stream the
    /// session's tokens will be drawn from.
    pub fn open(&mut self, sid: u64, sampling: SamplerCfg) -> anyhow::Result<()> {
        let mut j = Json::obj();
        j.set("op", "open".into())
            .set("sid", (sid as usize).into())
            .set("temperature", (sampling.temperature as f64).into())
            .set("top_k", sampling.top_k.into())
            .set("seed", (sampling.seed as f64).into());
        self.call(&j).map(|_| ())
    }

    /// Relay a session's prefill hidden rows; returns the first sampled
    /// token. `pos` is the session's cache position before these rows.
    pub fn prefill(&mut self, sid: u64, pos: usize, h: &Mat) -> anyhow::Result<u16> {
        let mut j = Json::obj();
        j.set("op", "prefill".into())
            .set("sid", (sid as usize).into())
            .set("pos", pos.into())
            .set("rows", h.rows().into())
            .set("cols", h.cols().into())
            .set("h", bits_of_rows(h));
        let r = self.call(&j)?;
        let tok = r
            .get("token")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("relay prefill response without a token"))?;
        anyhow::ensure!(tok <= u16::MAX as usize, "relay token {tok} exceeds u16");
        Ok(tok as u16)
    }

    /// Relay one batched decode round: row `b` of `h` belongs to session
    /// `sids[b]` at position `positions[b]`. Returns one sampled token per
    /// session, in order.
    pub fn round(
        &mut self,
        sids: &[u64],
        positions: &[usize],
        h: &Mat,
    ) -> anyhow::Result<Vec<u16>> {
        let mut j = Json::obj();
        j.set("op", "round".into())
            .set("sids", Json::Arr(sids.iter().map(|&s| Json::Num(s as f64)).collect()))
            .set(
                "pos",
                Json::Arr(positions.iter().map(|&p| Json::Num(p as f64)).collect()),
            )
            .set("cols", h.cols().into())
            .set("h", bits_of_rows(h));
        let r = self.call(&j)?;
        let toks: Vec<u16> = r
            .get("tokens")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|x| x.as_usize().map(|t| t as u16)).collect())
            .unwrap_or_default();
        anyhow::ensure!(
            toks.len() == sids.len(),
            "relay round returned {} tokens for {} sessions",
            toks.len(),
            sids.len()
        );
        Ok(toks)
    }

    /// Roll a session's tail cache back to `len` rows (cache-control op —
    /// the pipeline twin of [`KvCache::truncate`]).
    pub fn truncate(&mut self, sid: u64, len: usize) -> anyhow::Result<()> {
        let mut j = Json::obj();
        j.set("op", "truncate".into())
            .set("sid", (sid as usize).into())
            .set("len", len.into());
        self.call(&j).map(|_| ())
    }

    /// Retire a session (idempotent).
    pub fn close(&mut self, sid: u64) -> anyhow::Result<()> {
        let mut j = Json::obj();
        j.set("op", "close".into()).set("sid", (sid as usize).into());
        self.call(&j).map(|_| ())
    }

    /// Tail-side session count (reads the table through `read_recover`).
    pub fn stats(&mut self) -> anyhow::Result<Json> {
        let mut j = Json::obj();
        j.set("op", "stats".into());
        self.call(&j)
    }

    /// Ask the tail process to exit once its connections drain.
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        let mut j = Json::obj();
        j.set("op", "shutdown".into());
        self.call(&j).map(|_| ())
    }
}

// ---------------------------------------------------------------------------
// Tail: relay listener over the final stage range + LM head.
// ---------------------------------------------------------------------------

/// Tail-side state of one pipeline session: the sampler stream (opened
/// before the first hidden rows arrive) and the stage-range KV cache
/// (created lazily at prefill, when the row count is known).
struct TailSession {
    sampler: Sampler,
    cache: Option<KvCache>,
}

/// Run the tail stage: listen for relay connections, advance the final
/// stage range on each hidden frame, sample, and answer tokens until a
/// `shutdown` frame arrives. The partial model must hold the LM head
/// ([`Model::load_stage_range`] with the range ending at the last stage).
pub fn serve_pipeline_tail(
    model: Arc<Model>,
    addr: &str,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    anyhow::ensure!(
        model.lm_head.rows() > 0,
        "pipeline tail needs the LM head — load a stage range ending at the last stage"
    );
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);
    let sessions: Arc<RwLock<HashMap<u64, TailSession>>> = Arc::new(RwLock::new(HashMap::new()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let model = model.clone();
                let sessions = sessions.clone();
                let shutdown = shutdown.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_relay_conn(stream, &model, &sessions, &shutdown);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_relay_conn(
    stream: TcpStream,
    model: &Model,
    sessions: &RwLock<HashMap<u64, TailSession>>,
    shutdown: &AtomicBool,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Json::parse(&line) {
            Ok(j) => handle_frame(model, sessions, shutdown, &j),
            Err(e) => protocol_error(format!("bad relay frame: {e}"), "bad_frame"),
        };
        writeln!(writer, "{resp}")?;
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

fn ok_true() -> String {
    "{\"ok\":true}".to_string()
}

fn frame_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("relay frame without a valid '{key}'"))
}

/// Dispatch one relay frame against the tail state; every outcome —
/// success or failure — is a serialized response line.
fn handle_frame(
    model: &Model,
    sessions: &RwLock<HashMap<u64, TailSession>>,
    shutdown: &AtomicBool,
    j: &Json,
) -> String {
    let Some(op) = j.get("op").and_then(Json::as_str) else {
        return protocol_error("relay frame without an op".to_string(), "bad_frame");
    };
    match op {
        "open" => frame_open(sessions, j),
        "prefill" => frame_prefill(model, sessions, j),
        "round" => frame_round(model, sessions, j),
        "truncate" => frame_truncate(sessions, j),
        "close" => match frame_usize(j, "sid") {
            Ok(sid) => {
                write_recover(sessions).remove(&(sid as u64));
                ok_true()
            }
            Err(e) => protocol_error(e.to_string(), "bad_frame"),
        },
        "stats" => {
            let n = read_recover(sessions).len();
            let mut r = Json::obj();
            r.set("sessions", n.into());
            r.to_string()
        }
        "shutdown" => {
            shutdown.store(true, Ordering::Relaxed);
            ok_true()
        }
        other => protocol_error(format!("unknown relay op '{other}'"), "bad_frame"),
    }
}

fn frame_open(sessions: &RwLock<HashMap<u64, TailSession>>, j: &Json) -> String {
    let sid = match frame_usize(j, "sid") {
        Ok(s) => s as u64,
        Err(e) => return protocol_error(e.to_string(), "bad_frame"),
    };
    let cfg = sampler_cfg_from_json(j);
    let mut guard = write_recover(sessions);
    if guard.contains_key(&sid) {
        return protocol_error(format!("session {sid} is already open"), "bad_frame");
    }
    guard.insert(sid, TailSession { sampler: Sampler::new(cfg), cache: None });
    ok_true()
}

/// Parse and validate the shared hidden-payload fields of a frame.
fn frame_hidden(j: &Json, rows: usize, d_model: usize) -> anyhow::Result<Mat> {
    let cols = frame_usize(j, "cols")?;
    anyhow::ensure!(
        cols == d_model,
        "hidden width {cols} does not match the model's d_model {d_model}"
    );
    let arr = j
        .get("h")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("relay frame without an 'h' payload"))?;
    rows_from_bits(arr, rows, cols)
}

fn frame_prefill(
    model: &Model,
    sessions: &RwLock<HashMap<u64, TailSession>>,
    j: &Json,
) -> String {
    let parsed = frame_usize(j, "sid").and_then(|sid| {
        let rows = frame_usize(j, "rows")?;
        anyhow::ensure!(rows > 0, "prefill frame with zero rows");
        let pos = frame_usize(j, "pos")?;
        let x = frame_hidden(j, rows, model.cfg.d_model)?;
        Ok((sid as u64, rows, pos, x))
    });
    let (sid, rows, pos, x) = match parsed {
        Ok(p) => p,
        Err(e) => return protocol_error(e.to_string(), "bad_frame"),
    };
    let Some(mut sess) = write_recover(sessions).remove(&sid) else {
        return protocol_error(format!("unknown session {sid}"), "unknown_session");
    };
    let cur = sess.cache.as_ref().map(KvCache::len).unwrap_or(0);
    if cur != pos {
        let msg =
            format!("session {sid}: relay position {pos} does not match the {cur} cached rows");
        write_recover(sessions).insert(sid, sess);
        return protocol_error(msg, "bad_frame");
    }
    let mut cache = match sess.cache.take() {
        Some(c) => c,
        None => model.new_cache_with(rows.max(model.cfg.max_seq)),
    };
    // A panicking forward costs exactly this session (its cache is in an
    // unknown state, so it stays removed), never the relay connection.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        let th = model.forward_hidden_cached(&mut cache, x);
        let logits = model.logits_from_hidden_row(th.row(th.rows() - 1));
        let tok = sess.sampler.pick(&logits);
        sess.cache = Some(cache);
        (sess, tok)
    }));
    match run {
        Ok((sess, tok)) => {
            write_recover(sessions).insert(sid, sess);
            let mut r = Json::obj();
            r.set("token", (tok as usize).into());
            r.to_string()
        }
        Err(_) => protocol_error(
            format!("model panicked during pipeline prefill of session {sid}"),
            "worker_panic",
        ),
    }
}

fn frame_round(
    model: &Model,
    sessions: &RwLock<HashMap<u64, TailSession>>,
    j: &Json,
) -> String {
    let parsed = (|| -> anyhow::Result<(Vec<u64>, Vec<usize>, Mat)> {
        let sarr = j
            .get("sids")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("round frame without 'sids'"))?;
        let sids: Vec<u64> =
            sarr.iter().filter_map(|v| v.as_usize().map(|s| s as u64)).collect();
        anyhow::ensure!(
            !sids.is_empty() && sids.len() == sarr.len(),
            "round frame with empty or non-integer 'sids'"
        );
        let unique: std::collections::BTreeSet<u64> = sids.iter().copied().collect();
        anyhow::ensure!(unique.len() == sids.len(), "duplicate sid in round frame");
        let parr = j
            .get("pos")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("round frame without 'pos'"))?;
        let positions: Vec<usize> = parr.iter().filter_map(Json::as_usize).collect();
        anyhow::ensure!(
            positions.len() == sids.len(),
            "round frame carries {} positions for {} sessions",
            positions.len(),
            sids.len()
        );
        let x = frame_hidden(j, sids.len(), model.cfg.d_model)?;
        Ok((sids, positions, x))
    })();
    let (sids, positions, x) = match parsed {
        Ok(p) => p,
        Err(e) => return protocol_error(e.to_string(), "bad_frame"),
    };
    // Pop every named session under one write guard so the batch sees a
    // consistent table, then run the forward without holding the lock.
    let mut popped: Vec<(u64, TailSession)> = Vec::with_capacity(sids.len());
    {
        let mut guard = write_recover(sessions);
        if let Some(missing) = sids.iter().find(|s| !guard.contains_key(s)) {
            return protocol_error(format!("unknown session {missing}"), "unknown_session");
        }
        for &sid in &sids {
            if let Some(s) = guard.remove(&sid) {
                popped.push((sid, s));
            }
        }
    }
    let reinsert = |popped: Vec<(u64, TailSession)>| {
        let mut guard = write_recover(sessions);
        for (k, v) in popped {
            guard.insert(k, v);
        }
    };
    let missing_cache = popped
        .iter()
        .find(|(_, s)| s.cache.is_none())
        .map(|(sid, _)| *sid);
    if let Some(sid) = missing_cache {
        reinsert(popped);
        return protocol_error(format!("session {sid} has no prefilled cache"), "bad_frame");
    }
    let drift = popped
        .iter()
        .zip(positions.iter())
        .find(|((_, s), &p)| s.cache.as_ref().map(KvCache::len).unwrap_or(0) != p)
        .map(|((sid, s), &p)| (*sid, s.cache.as_ref().map(KvCache::len).unwrap_or(0), p));
    if let Some((sid, cur, p)) = drift {
        reinsert(popped);
        return protocol_error(
            format!("session {sid}: relay position {p} does not match the {cur} cached rows"),
            "bad_frame",
        );
    }
    // One hidden round over the whole batch (per-row kernels at B == 1),
    // then the per-row norm+head kernel and each session's own sampler.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        let mut popped = popped;
        let th = {
            let mut caches: Vec<&mut KvCache> =
                popped.iter_mut().filter_map(|(_, s)| s.cache.as_mut()).collect();
            model.decode_hidden_batch(&mut caches, x)
        };
        let mut toks: Vec<u16> = Vec::with_capacity(popped.len());
        for (i, (_, s)) in popped.iter_mut().enumerate() {
            toks.push(s.sampler.pick(&model.logits_from_hidden_row(th.row(i))));
        }
        (popped, toks)
    }));
    match run {
        Ok((done, toks)) => {
            reinsert(done);
            let mut r = Json::obj();
            r.set("tokens", Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect()));
            r.to_string()
        }
        // The panicked round's caches are in an unknown state; the popped
        // sessions stay dropped and the head fails those requests.
        Err(_) => protocol_error(
            "model panicked during a pipeline round — the affected sessions were dropped"
                .to_string(),
            "worker_panic",
        ),
    }
}

fn frame_truncate(sessions: &RwLock<HashMap<u64, TailSession>>, j: &Json) -> String {
    let parsed = frame_usize(j, "sid").and_then(|sid| Ok((sid as u64, frame_usize(j, "len")?)));
    let (sid, len) = match parsed {
        Ok(p) => p,
        Err(e) => return protocol_error(e.to_string(), "bad_frame"),
    };
    let mut guard = write_recover(sessions);
    let Some(sess) = guard.get_mut(&sid) else {
        return protocol_error(format!("unknown session {sid}"), "unknown_session");
    };
    let Some(cache) = sess.cache.as_mut() else {
        return protocol_error(format!("session {sid} has no prefilled cache"), "bad_frame");
    };
    if len > cache.len() {
        return protocol_error(
            format!("session {sid}: cannot truncate {} cached rows to {len}", cache.len()),
            "bad_frame",
        );
    }
    cache.truncate(len);
    ok_true()
}

// ---------------------------------------------------------------------------
// Head: client-facing server over the first stage range + relay driver.
// ---------------------------------------------------------------------------

struct HeadJob {
    prompt: Vec<u16>,
    max_new: usize,
    sampling: SamplerCfg,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// Head-side state of one in-flight request: the stage-range KV cache plus
/// the token list the single-host [`crate::model::DecodeSession`] would
/// keep — the sampler itself lives with the logits, on the tail.
struct HeadSession {
    sid: u64,
    cache: KvCache,
    tokens: Vec<u16>,
    prompt_len: usize,
    max_new: usize,
    max_total: usize,
    done: bool,
}

impl HeadSession {
    fn generated(&self) -> &[u16] {
        self.tokens.get(self.prompt_len..).unwrap_or(&[])
    }

    /// Record the tail's sampled token and update the stop state — the
    /// same rule `DecodeSession::consume_logits` applies.
    fn push(&mut self, tok: u16) {
        self.tokens.push(tok);
        if self.tokens.len() - self.prompt_len >= self.max_new
            || self.tokens.len() >= self.max_total
        {
            self.done = true;
        }
    }
}

struct HeadActive {
    sess: HeadSession,
    enqueued: Timer,
    reply: mpsc::Sender<GenResponse>,
}

/// Open a session on the tail and run the head half of its prefill: embed
/// the prompt, advance the head stages, relay the hidden rows, and record
/// the first sampled token.
fn admit_session(
    model: &Model,
    relay: &mut RelayClient,
    sid: u64,
    job: &HeadJob,
) -> anyhow::Result<HeadSession> {
    relay.open(sid, job.sampling)?;
    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut cache = model.new_cache_with(job.prompt.len().max(model.cfg.max_seq));
        let h = model.forward_hidden_cached(&mut cache, model.embed_tokens(&job.prompt));
        (cache, h)
    }));
    let (cache, h) = match built {
        Ok(b) => b,
        Err(_) => {
            let _ = relay.close(sid);
            anyhow::bail!("model panicked during pipeline prefill");
        }
    };
    let tok = match relay.prefill(sid, 0, &h) {
        Ok(t) => t,
        Err(e) => {
            let _ = relay.close(sid);
            return Err(e);
        }
    };
    let mut tokens = job.prompt.clone();
    tokens.push(tok);
    let max_total = model.cfg.max_seq;
    let done = tokens.len() - job.prompt.len() >= job.max_new || tokens.len() >= max_total;
    Ok(HeadSession {
        sid,
        cache,
        tokens,
        prompt_len: job.prompt.len(),
        max_new: job.max_new,
        max_total,
        done,
    })
}

/// Fail every in-flight session with one structured error — the relay
/// connection is the pipeline's spine, so losing it loses the batch.
fn fail_all(active: &mut Vec<HeadActive>, metrics: &Metrics, msg: &str, code: &str) {
    for a in active.drain(..) {
        metrics.fail(&a.enqueued, &a.reply, Tier::Full, msg.to_string(), code);
    }
}

/// Run the pipeline head until a client `shutdown` command: the same
/// client-facing JSON-lines protocol as [`super::server::serve_blocking`]
/// (full tier only), continuous batching over head-stage sessions, one
/// relayed hidden round per token. After draining, the head asks the tail
/// to shut down too, so one client `shutdown` winds down the whole
/// pipeline.
pub fn serve_pipeline_head(
    model: Arc<Model>,
    addr: &str,
    next: &str,
    policy: BatchPolicy,
    info: Json,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    anyhow::ensure!(
        model.embed.rows() > 0,
        "pipeline head needs the embedding — load a stage range starting at 0"
    );
    let relay = RelayClient::connect(next)
        .map_err(|e| anyhow::anyhow!("cannot reach the next pipeline hop at {next}: {e}"))?;
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_ready(listener.local_addr()?);

    let mut info = info;
    info.set("resident_weight_bytes", model.resident_weight_bytes().into());
    info.set("mapped_weight_bytes", model.mapped_weight_bytes().into());
    if info.get("weights_source").is_none() {
        let src = if model.weights_mapped() {
            "mmap"
        } else if info.get("checkpoint").is_some() {
            "checkpoint"
        } else {
            "in-memory"
        };
        info.set("weights_source", src.into());
    }
    info.set("pipeline_role", "head".into());
    info.set("pipeline_next", next.into());
    info.set("tier_default", "full".into());
    let info = Arc::new(info);
    let batcher: Arc<Batcher<HeadJob>> = Arc::new(Batcher::new(policy));
    let metrics = Arc::new(Metrics::default());
    let shutdown = Arc::new(AtomicBool::new(false));

    // Worker: continuous batching over head-stage sessions, mirroring the
    // single-host worker round for round — admit into free slots, one
    // batched hidden forward + one relay round per token, retire finished
    // sessions immediately.
    let worker = {
        let batcher = batcher.clone();
        let metrics = metrics.clone();
        let model = model.clone();
        let mut relay = relay;
        std::thread::spawn(move || {
            let mut active: Vec<HeadActive> = Vec::new();
            let mut next_sid: u64 = 0;
            loop {
                let slots = policy.max_batch.saturating_sub(active.len());
                let incoming = if active.is_empty() {
                    let batch = batcher.next_batch();
                    if batch.is_empty() {
                        break; // closed + drained, nothing in flight
                    }
                    batch
                } else if slots > 0 {
                    batcher.try_drain(slots)
                } else {
                    Vec::new()
                };
                if !incoming.is_empty() {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                }
                for job in incoming {
                    if job.prompt.is_empty() || job.max_new == 0 {
                        metrics.finish(
                            &job.enqueued,
                            &job.reply,
                            Vec::new(),
                            active.len() + 1,
                            Tier::Full,
                        );
                        continue;
                    }
                    next_sid += 1;
                    let sid = next_sid;
                    match admit_session(&model, &mut relay, sid, &job) {
                        Ok(sess) => {
                            if sess.done {
                                let _ = relay.close(sid);
                                metrics.finish(
                                    &job.enqueued,
                                    &job.reply,
                                    sess.generated().to_vec(),
                                    active.len() + 1,
                                    Tier::Full,
                                );
                            } else {
                                active.push(HeadActive {
                                    sess,
                                    enqueued: job.enqueued,
                                    reply: job.reply,
                                });
                            }
                        }
                        Err(e) => metrics.fail(
                            &job.enqueued,
                            &job.reply,
                            Tier::Full,
                            format!("pipeline prefill failed: {e}"),
                            "relay_error",
                        ),
                    }
                }
                if active.is_empty() {
                    continue;
                }
                // One pipeline round: embed every session's last token,
                // advance the head stages in one batched hidden forward
                // (the PR 7 round shape over this stage range), relay, and
                // hand each session its sampled token.
                let mut toks: Vec<u16> = Vec::with_capacity(active.len());
                let mut sids: Vec<u64> = Vec::with_capacity(active.len());
                let mut positions: Vec<usize> = Vec::with_capacity(active.len());
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(active.len());
                for a in active.iter_mut() {
                    let Some(t) = a.sess.tokens.last().copied() else { continue };
                    toks.push(t);
                    sids.push(a.sess.sid);
                    positions.push(a.sess.cache.len());
                    caches.push(&mut a.sess.cache);
                }
                let forward = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let x = model.embed_tokens(&toks);
                    model.decode_hidden_batch(&mut caches, x)
                }));
                drop(caches);
                let h = match forward {
                    Ok(h) => h,
                    Err(_) => {
                        fail_all(
                            &mut active,
                            &metrics,
                            "model panicked during pipeline decode",
                            "worker_panic",
                        );
                        continue;
                    }
                };
                metrics.record_batch_forward(toks.len());
                let next_toks = match relay.round(&sids, &positions, &h) {
                    Ok(t) => t,
                    Err(e) => {
                        fail_all(
                            &mut active,
                            &metrics,
                            &format!("pipeline relay failed mid-decode: {e}"),
                            "relay_error",
                        );
                        continue;
                    }
                };
                if next_toks.len() != active.len() {
                    fail_all(
                        &mut active,
                        &metrics,
                        "pipeline relay answered the wrong batch size",
                        "relay_error",
                    );
                    continue;
                }
                for (a, t) in active.iter_mut().zip(next_toks) {
                    a.sess.push(t);
                }
                let bsize = active.len();
                active.retain_mut(|a| {
                    if !a.sess.done {
                        return true;
                    }
                    let _ = relay.close(a.sess.sid);
                    metrics.finish(
                        &a.enqueued,
                        &a.reply,
                        a.sess.generated().to_vec(),
                        bsize,
                        Tier::Full,
                    );
                    false
                });
            }
            // Drained: wind the tail down along the relay before it drops.
            let _ = relay.shutdown();
        })
    };

    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let info = info.clone();
                let vocab = model.cfg.vocab;
                conns.push(std::thread::spawn(move || {
                    let _ = handle_head_conn(stream, &batcher, &metrics, &info, &shutdown, vocab);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    batcher.close();
    let _ = worker.join();
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Client-facing connection handler: the [`super::server`] request shape,
/// full tier only (other tiers get the same structured errors a draftless
/// single-host server gives).
fn handle_head_conn(
    stream: TcpStream,
    batcher: &Batcher<HeadJob>,
    metrics: &Metrics,
    info: &Json,
    shutdown: &AtomicBool,
    vocab: usize,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
        if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
            match cmd {
                "stats" => {
                    writeln!(writer, "{}", metrics.to_json().to_string())?;
                }
                "info" => {
                    writeln!(writer, "{}", info.to_string())?;
                }
                "shutdown" => {
                    shutdown.store(true, Ordering::Relaxed);
                    writeln!(writer, "{{\"ok\":true}}")?;
                    break;
                }
                _ => writeln!(writer, "{{\"error\":\"unknown cmd\"}}")?,
            }
            continue;
        }
        let raw: Vec<usize> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();
        if raw.iter().any(|&t| t >= vocab) {
            writeln!(writer, "{{\"error\":\"prompt token out of range (vocab {vocab})\"}}")?;
            continue;
        }
        if let Some(s) = j.get("tier").and_then(Json::as_str) {
            match Tier::parse(s) {
                Some(Tier::Full) => {}
                Some(t) => {
                    writeln!(
                        writer,
                        "{}",
                        protocol_error(
                            format!("tier '{}' is not served by a pipeline head", t.name()),
                            "tier_unavailable",
                        )
                    )?;
                    continue;
                }
                None => {
                    writeln!(
                        writer,
                        "{}",
                        protocol_error(
                            format!("unknown tier '{s}' (expected draft | spec | full)"),
                            "unknown_tier",
                        )
                    )?;
                    continue;
                }
            }
        }
        let prompt: Vec<u16> = raw.into_iter().map(|t| t as u16).collect();
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let sampling = sampler_cfg_from_json(&j);
        let (tx, rx) = mpsc::channel();
        let accepted = batcher.push(HeadJob {
            prompt,
            max_new,
            sampling,
            enqueued: Timer::start(),
            reply: tx,
        });
        if !accepted {
            writeln!(writer, "{{\"error\":\"server shutting down\"}}")?;
            continue;
        }
        let resp = rx.recv()?;
        if let Some((msg, code)) = resp.error {
            writeln!(writer, "{}", protocol_error(msg, &code))?;
            continue;
        }
        let mut out = Json::obj();
        out.set("tokens", Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()))
            .set("latency_ms", resp.latency_ms.into())
            .set("batch", resp.batch.into())
            .set("tier", resp.tier.into());
        writeln!(writer, "{}", out.to_string())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::super::Client;
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed))
    }

    /// The 2-stage split a sharded checkpoint's `load_stage_range` builds.
    fn split_at(model: &Model, k: usize) -> (Model, Model) {
        let d = model.cfg.d_model;
        let head = Model {
            cfg: model.cfg.clone(),
            embed: model.embed.clone(),
            stages: model.stages[..k].to_vec(),
            final_norm: Vec::new(),
            lm_head: Mat::zeros(0, 0),
        };
        let tail = Model {
            cfg: model.cfg.clone(),
            embed: Mat::zeros(0, d),
            stages: model.stages[k..].to_vec(),
            final_norm: model.final_norm.clone(),
            lm_head: model.lm_head.clone(),
        };
        (head, tail)
    }

    fn spawn_tail(tail: Model) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let t = std::thread::spawn(move || {
            serve_pipeline_tail(Arc::new(tail), "127.0.0.1:0", |a| tx.send(a).unwrap()).unwrap();
        });
        (rx.recv().unwrap(), t)
    }

    fn spawn_pipeline(
        model: &Model,
        k: usize,
        policy: BatchPolicy,
    ) -> (std::net::SocketAddr, std::thread::JoinHandle<()>, std::thread::JoinHandle<()>) {
        let (head, tail) = split_at(model, k);
        let (tail_addr, tail_thread) = spawn_tail(tail);
        let (tx, rx) = mpsc::channel();
        let head_thread = std::thread::spawn(move || {
            serve_pipeline_head(
                Arc::new(head),
                "127.0.0.1:0",
                &tail_addr.to_string(),
                policy,
                Json::obj(),
                |a| tx.send(a).unwrap(),
            )
            .unwrap();
        });
        (rx.recv().unwrap(), head_thread, tail_thread)
    }

    #[test]
    fn stage_range_and_role_parsing() {
        assert_eq!(parse_stage_range("0..3").unwrap(), 0..3);
        assert_eq!(parse_stage_range(" 1 .. 2 ").unwrap(), 1..2);
        assert!(parse_stage_range("3").is_err());
        assert!(parse_stage_range("a..b").is_err());

        assert_eq!(pipeline_role(&(0..1), 2, true).unwrap(), PipelineRole::Head);
        assert_eq!(pipeline_role(&(1..2), 2, false).unwrap(), PipelineRole::Tail);
        let err = pipeline_role(&(0..1), 2, false).unwrap_err().to_string();
        assert!(err.contains("--next"), "{err}");
        let err = pipeline_role(&(1..2), 2, true).unwrap_err().to_string();
        assert!(err.contains("no --next"), "{err}");
        let err = pipeline_role(&(0..2), 2, true).unwrap_err().to_string();
        assert!(err.contains("whole model"), "{err}");
        let err = pipeline_role(&(1..2), 3, false).unwrap_err().to_string();
        assert!(err.contains("not supported"), "{err}");
        let err = pipeline_role(&(1..1), 2, false).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");
        let err = pipeline_role(&(1..5), 2, false).unwrap_err().to_string();
        assert!(err.contains("outside"), "{err}");
    }

    #[test]
    fn hidden_bits_roundtrip_the_wire_exactly() {
        // f32 → u32 bits → JSON text → parse → f32 must be the identity,
        // including the values decimal JSON would mangle.
        let vals: Vec<f32> = vec![
            0.0,
            -0.0,
            1.5,
            -3.0714285e-5,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 8.0, // subnormal
            f32::MAX,
            f32::NAN,
            f32::NEG_INFINITY,
            0.1,
        ];
        let m = Mat::from_vec(2, 5, vals.clone());
        let mut frame = Json::obj();
        frame.set("h", bits_of_rows(&m));
        let wire = frame.to_string();
        let back = Json::parse(&wire).unwrap();
        let arr = back.get("h").and_then(Json::as_arr).unwrap();
        let m2 = rows_from_bits(arr, 2, 5).unwrap();
        for r in 0..2 {
            for c in 0..5 {
                assert_eq!(
                    m[(r, c)].to_bits(),
                    m2[(r, c)].to_bits(),
                    "bit pattern changed at ({r},{c})"
                );
            }
        }
        // structural errors, not panics
        assert!(rows_from_bits(arr, 3, 5).is_err());
        let bad = vec![Json::Num(0.5)];
        assert!(rows_from_bits(&bad, 1, 1).is_err());
    }

    #[test]
    fn two_stage_pipeline_matches_single_host_tokens() {
        let model = tiny_model(91);
        let (addr, head_t, tail_t) = spawn_pipeline(&model, 1, BatchPolicy::default());
        let mut c = Client::connect(addr).unwrap();

        // greedy continuations must be exactly the single-host tokens
        for p in [vec![3u16, 1, 4, 1, 5], vec![9, 8], vec![40, 41, 42, 43]] {
            let want = model.greedy_decode(&p, 8);
            let got = c.request(&p, 8).unwrap();
            assert_eq!(got.tokens, want, "pipeline diverged for {p:?}");
            assert_eq!(got.tier, "full");
        }
        // sampled requests are seed-deterministic through the relay and
        // match the single-host sampler stream (tail-side Sampler)
        let cfg = SamplerCfg { temperature: 0.9, top_k: 4, seed: 11 };
        let a = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        let b = c.request_with(&[1, 2, 3], 8, cfg).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tokens, model.generate(&[1, 2, 3], 8, cfg));
        // empty prompts answered, not panicked on
        let e = c.request(&[], 4).unwrap();
        assert!(e.tokens.is_empty());
        // protocol hardening: non-full tiers and bad tokens are rejected
        let mut req = Json::obj();
        req.set("prompt", Json::Arr(vec![Json::Num(1.0)]))
            .set("max_new", 2.into())
            .set("tier", "spec".into());
        let r = c.request_raw(&req).unwrap();
        assert_eq!(r.get("code").and_then(Json::as_str), Some("tier_unavailable"));
        assert!(c.request(&[9999], 2).is_err());
        // info reports the pipeline role; stats count the rounds
        let info = c.info().unwrap();
        assert_eq!(info.get("pipeline_role").and_then(Json::as_str), Some("head"));
        assert!(info.get("resident_weight_bytes").and_then(Json::as_usize).unwrap() > 0);
        let stats = c.stats().unwrap();
        assert!(stats.get("decode_steps").and_then(Json::as_usize).unwrap() > 0);

        // one client shutdown winds down head AND tail
        c.shutdown().unwrap();
        head_t.join().unwrap();
        tail_t.join().unwrap();
    }

    #[test]
    fn pipeline_batched_rounds_match_solo_requests() {
        use std::time::Duration;
        let model = tiny_model(92);
        let (addr, head_t, tail_t) = spawn_pipeline(
            &model,
            1,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) },
        );
        let mut alone = Client::connect(addr).unwrap();
        let solo = alone.request(&[7, 8, 9], 6).unwrap().tokens;
        assert_eq!(solo, model.greedy_decode(&[7, 8, 9], 6));
        drop(alone); // its conn thread must exit before shutdown joins
        let mut handles = Vec::new();
        for i in 0..5u16 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let p: Vec<u16> = if i == 0 { vec![7, 8, 9] } else { vec![i, i * 2, i * 3] };
                (i, p.clone(), c.request(&p, 6).unwrap().tokens)
            }));
        }
        for h in handles {
            let (i, p, tokens) = h.join().unwrap();
            if i == 0 {
                assert_eq!(tokens, solo, "batched pipeline continuation differs from solo");
            }
            assert_eq!(tokens, model.greedy_decode(&p, 6), "request {i}");
        }
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        head_t.join().unwrap();
        tail_t.join().unwrap();
    }

    #[test]
    fn relay_rejects_bad_frames_with_structured_errors() {
        let model = tiny_model(93);
        let (_, tail) = split_at(&model, 1);
        let (addr, tail_t) = spawn_tail(tail);
        let mut r = RelayClient::connect(&addr.to_string()).unwrap();

        // round against a session that was never opened
        let h = Mat::zeros(1, model.cfg.d_model);
        let err = r.round(&[5], &[0], &h).unwrap_err().to_string();
        assert!(err.contains("unknown session"), "{err}");
        // double open
        r.open(1, SamplerCfg::greedy()).unwrap();
        let err = r.open(1, SamplerCfg::greedy()).unwrap_err().to_string();
        assert!(err.contains("already open"), "{err}");
        // round before any prefill
        let err = r.round(&[1], &[0], &h).unwrap_err().to_string();
        assert!(err.contains("no prefilled cache"), "{err}");
        // prefill with the wrong hidden width
        let bad = Mat::zeros(2, model.cfg.d_model + 1);
        let err = r.prefill(1, 0, &bad).unwrap_err().to_string();
        assert!(err.contains("hidden width"), "{err}");
        // a real prefill works and later frames validate against it
        let good = Mat::zeros(3, model.cfg.d_model);
        let tok = r.prefill(1, 0, &good).unwrap();
        assert!((tok as usize) < model.cfg.vocab);
        // position drift is caught
        let one = Mat::zeros(1, model.cfg.d_model);
        let err = r.round(&[1], &[7], &one).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        // truncate beyond the cached rows is a structured error...
        let err = r.truncate(1, 9).unwrap_err().to_string();
        assert!(err.contains("cannot truncate"), "{err}");
        // ...and a valid truncate plus re-advance works
        r.truncate(1, 2).unwrap();
        let stats = r.stats().unwrap();
        assert_eq!(stats.get("sessions").and_then(Json::as_usize), Some(1));
        r.close(1).unwrap();
        r.close(1).unwrap(); // idempotent
        let stats = r.stats().unwrap();
        assert_eq!(stats.get("sessions").and_then(Json::as_usize), Some(0));
        // malformed json gets an error response, not a dropped connection
        let mut raw = Json::obj();
        raw.set("nonsense", true.into());
        let err = r.call(&raw).unwrap_err().to_string();
        assert!(err.contains("without an op"), "{err}");
        r.shutdown().unwrap();
        tail_t.join().unwrap();
    }
}
