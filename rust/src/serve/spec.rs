//! Self-speculative decoding: a cheap **draft** model proposes k tokens,
//! the **target** model verifies all k in one multi-row cached forward
//! ([`Model::decode_step_multi`]), and greedy output stays token-identical
//! to decoding with the target alone.
//!
//! COMPOT's composed compression plans deliberately produce several
//! fidelity points of the same network (e.g. `compot@0.15+rtn2` vs `gptq4`
//! vs dense, Table 7); CPT2 + mmap made holding two of them at once nearly
//! free (shared page cache). [`SpeculativeSession`] turns that pair into a
//! latency feature: per generated token the target runs `1/k`-th as many
//! forwards when the draft agrees with it, and exactly corrects it when it
//! does not.
//!
//! ## The round invariant
//!
//! Between rounds, both KV caches hold every token of `tokens` except the
//! last (the cache length is "rows appended", and the last token has been
//! *chosen* but not yet *fed*). One round then:
//!
//! 1. syncs the draft cache to that invariant ([`KvCache::truncate`] if it
//!    ran ahead on a rejected draft, catch-up `decode_step`s if the target
//!    out-generated it on an accepted one);
//! 2. lets the draft propose up to k tokens via sequential cached
//!    [`Model::decode_step`]s (greedy argmax);
//! 3. feeds the last committed token plus all k proposals to the target as
//!    **one** k+1-row [`Model::decode_step_multi`] — row `i` is the
//!    target's next-token distribution after the proposals' `i`-prefix;
//! 4. accepts the longest prefix on which the draft's choice equals the
//!    target's argmax, then appends one more target-chosen token: the
//!    correction at the first divergence (rolling the target cache back
//!    over the rejected rows), or the "bonus" token from the last verify
//!    row when everything was accepted.
//!
//! **Greedy parity, by induction:** every token this session ever appends
//! is the argmax of a target logits row at its position — accepted
//! proposals are accepted *because* they equal that argmax, and the
//! correction/bonus token *is* that argmax. Since `decode_step_multi` is
//! bit-identical to sequential target `decode_step`s (parity-tested in
//! `model/decode.rs`) and `truncate` + re-decode is bit-exact, the token
//! sequence equals [`Model::greedy_decode`] on the target, token for token
//! — no matter how good or bad the draft is. The draft only moves the
//! *cost*, never the *output*. Tested below with a self-draft (accepts
//! everything), a quantized draft, and an adversarial unrelated draft
//! (rejects almost everything).

use crate::model::decode::{argmax, KvCache};
use crate::model::Model;

/// Request routing tier for a serve process holding a target and
/// (optionally) a draft model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Draft model only: cheapest and fastest, draft-fidelity output.
    Draft,
    /// Speculative: draft proposes, target verifies — target-fidelity
    /// greedy output at draft-ish latency.
    Spec,
    /// Target model only, stepped token by token.
    Full,
}

impl Tier {
    /// Parse a protocol `tier` value. `None` for unknown strings — the
    /// server turns that into a structured error, not a silent default.
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "draft" => Some(Tier::Draft),
            "spec" => Some(Tier::Spec),
            "full" => Some(Tier::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Draft => "draft",
            Tier::Spec => "spec",
            Tier::Full => "full",
        }
    }
}

/// What one speculative round did — the per-round deltas the serving
/// metrics aggregate.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecRound {
    /// Tokens committed this round (accepted prefix + correction/bonus).
    pub appended: usize,
    /// Tokens the draft proposed this round (≤ draft_k).
    pub proposed: usize,
    /// Proposals the target accepted this round.
    pub accepted: usize,
}

/// One in-flight speculative generation: the target/draft KV-cache pair,
/// the committed token sequence, and stop conditions — the speculative
/// counterpart of [`crate::model::DecodeSession`], scheduled the same way
/// by the continuous batcher (one [`round`](SpeculativeSession::round) per
/// scheduling turn; a round may commit up to draft_k + 1 tokens).
///
/// Greedy-only by construction: speculative acceptance compares the
/// draft's argmax against the target's argmax, which is exactly the greedy
/// sampler. The server routes non-greedy requests to the full tier
/// instead.
#[derive(Clone, Debug)]
pub struct SpeculativeSession {
    target_cache: KvCache,
    draft_cache: KvCache,
    tokens: Vec<u16>,
    prompt_len: usize,
    max_new: usize,
    max_total: usize,
    draft_k: usize,
    done: bool,
    proposed: u64,
    accepted: u64,
    rounds: u64,
}

impl SpeculativeSession {
    /// Prefill both models over `prompt` and commit the first target-chosen
    /// token (exactly [`crate::model::DecodeSession::start`]'s greedy
    /// behavior on the target). `draft_k` is the per-round proposal budget.
    pub fn start(
        target: &Model,
        draft: &Model,
        prompt: &[u16],
        max_new: usize,
        draft_k: usize,
    ) -> SpeculativeSession {
        assert!(!prompt.is_empty(), "SpeculativeSession: empty prompt");
        assert!(draft_k >= 1, "SpeculativeSession: draft_k must be >= 1");
        assert_eq!(
            target.cfg.vocab, draft.cfg.vocab,
            "SpeculativeSession: draft/target vocab mismatch"
        );
        let capacity = prompt.len().max(target.cfg.max_seq);
        let mut target_cache = target.new_cache_with(capacity);
        let mut draft_cache = draft.new_cache_with(capacity);
        let mut tokens = prompt.to_vec();
        let max_total = target.cfg.max_seq;
        let mut done = max_new == 0;
        if !done {
            let logits = target.prefill(&mut target_cache, prompt);
            tokens.push(argmax(logits.row(logits.rows() - 1)));
            draft.prefill(&mut draft_cache, prompt);
            done = tokens.len() - prompt.len() >= max_new || tokens.len() >= max_total;
        }
        SpeculativeSession {
            target_cache,
            draft_cache,
            tokens,
            prompt_len: prompt.len(),
            max_new,
            max_total,
            draft_k,
            done,
            proposed: 0,
            accepted: 0,
            rounds: 0,
        }
    }

    /// One draft-propose / target-verify round; commits 1..=draft_k+1
    /// tokens. Returns `None` once the session has finished.
    pub fn round(&mut self, target: &Model, draft: &Model) -> Option<SpecRound> {
        if self.done {
            return None;
        }
        let t_len = self.tokens.len();
        // audit:allow(index): start() asserts a non-empty prompt and always
        // appends the first target-chosen token, so tokens is never empty.
        let last = self.tokens[t_len - 1];
        // Proposal budget: never draft past the request/model limits — the
        // verify step always commits at least one token beyond the
        // proposals' accepted prefix, so k is capped at remaining - 1.
        let remaining =
            (self.max_new - self.generated_len()).min(self.max_total - t_len);
        let k = self.draft_k.min(remaining - 1);

        // 1. Sync the draft cache to the round invariant (all committed
        //    tokens except the last are fed). After a rejection it ran
        //    ahead on tokens that no longer exist — roll it back; after a
        //    fully accepted round the target committed a bonus token the
        //    draft never saw — catch it up.
        if k > 0 {
            if self.draft_cache.len() > t_len - 1 {
                self.draft_cache.truncate(t_len - 1);
            }
            while self.draft_cache.len() < t_len - 1 {
                // audit:allow(index): the loop condition bounds the cache
                // length below t_len - 1 < tokens.len().
                let tok = self.tokens[self.draft_cache.len()];
                draft.decode_step(&mut self.draft_cache, tok);
            }
        }

        // 2. Draft proposes k tokens, sequential greedy decode steps.
        let mut proposals: Vec<u16> = Vec::with_capacity(k);
        let mut cur = last;
        for _ in 0..k {
            let logits = draft.decode_step(&mut self.draft_cache, cur);
            cur = argmax(&logits);
            proposals.push(cur);
        }

        // 3. Target verifies all proposals in ONE multi-row cached forward:
        //    row i is the target's next-token logits after the committed
        //    tokens plus proposals[..i].
        let mut rows: Vec<u16> = Vec::with_capacity(k + 1);
        rows.push(last);
        rows.extend_from_slice(&proposals);
        let logits = target.decode_step_multi(&mut self.target_cache, &rows);

        // 4. Accept the longest agreeing prefix, then commit one more
        //    target-chosen token (correction at the divergence, or the
        //    bonus token from the last row when everything was accepted).
        let mut a = 0;
        // audit:allow(index): a < k == proposals.len() is the loop guard.
        while a < k && argmax(logits.row(a)) == proposals[a] {
            a += 1;
        }
        // audit:allow(index): the loop above stops with a <= k, so the
        // prefix slice is in range.
        let mut appended: Vec<u16> = proposals[..a].to_vec();
        appended.push(argmax(logits.row(a.min(k))));
        if a < k {
            // Rows a+1..=k were computed from rejected proposals — roll the
            // target cache back so it holds exactly the committed tokens
            // minus the new last one (the round invariant).
            self.target_cache.truncate(t_len + a);
        }
        self.tokens.extend_from_slice(&appended);
        self.rounds += 1;
        self.proposed += k as u64;
        self.accepted += a as u64;
        if self.generated_len() >= self.max_new || self.tokens.len() >= self.max_total {
            self.done = true;
        }
        Some(SpecRound { appended: appended.len(), proposed: k, accepted: a })
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Prompt + generated tokens.
    pub fn tokens(&self) -> &[u16] {
        &self.tokens
    }

    /// Generated continuation only.
    pub fn generated(&self) -> &[u16] {
        // audit:allow(index): prompt_len is the length tokens started with
        // and the sequence only ever grows.
        &self.tokens[self.prompt_len..]
    }

    pub fn generated_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// Tokens the draft has proposed across all rounds.
    pub fn draft_proposed(&self) -> u64 {
        self.proposed
    }

    /// Proposed tokens the target accepted across all rounds.
    pub fn draft_accepted(&self) -> u64 {
        self.accepted
    }

    /// Target verify forwards run (one multi-row step per round).
    pub fn verify_rounds(&self) -> u64 {
        self.rounds
    }

    /// Fraction of drafted tokens the target accepted (1.0 when the draft
    /// always agrees — e.g. a self-draft; low for a bad draft, which costs
    /// speed, never correctness).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::LinearWeight;
    use crate::linalg::QuantMat;
    use crate::model::config::{ModelConfig, ProjKind};
    use crate::model::transformer::Stage;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed))
    }

    /// 4-bit-pack every dense projection — a realistic cheap draft of the
    /// same network.
    fn rtn4(model: &Model) -> Model {
        let mut m = model.clone();
        for stage in m.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let packed = match b.proj(p) {
                        LinearWeight::Dense(w) => Some(QuantMat::quantize_from(w, 4)),
                        _ => None,
                    };
                    if let Some(q) = packed {
                        *b.proj_mut(p) = LinearWeight::QuantDense(q);
                    }
                }
            }
        }
        m
    }

    fn run_spec(target: &Model, draft: &Model, prompt: &[u16], max_new: usize, k: usize) -> SpeculativeSession {
        let mut s = SpeculativeSession::start(target, draft, prompt, max_new, k);
        while s.round(target, draft).is_some() {}
        s
    }

    #[test]
    fn self_draft_accepts_everything_and_matches_greedy() {
        // draft == target: every proposal is the target's own argmax, so
        // acceptance is exactly 100% and each round commits k+1 tokens.
        let model = tiny_model(70);
        let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
        let want = model.greedy_decode(&prompt, 12);
        for k in [1usize, 2, 4, 8] {
            let s = run_spec(&model, &model, &prompt, 12, k);
            assert_eq!(s.generated(), &want[..], "k={k}");
            assert_eq!(s.generated_len(), 12, "k={k}");
            assert_eq!(s.draft_accepted(), s.draft_proposed(), "k={k}: self-draft rejected");
            assert!(s.draft_proposed() > 0, "k={k}");
            assert!((s.acceptance_rate() - 1.0).abs() < 1e-12, "k={k}");
        }
        // with k=4 and full acceptance, 12 tokens need far fewer than 12
        // target forwards (1 prefill pick + ceil(11/5) rounds = 4)
        let s = run_spec(&model, &model, &prompt, 12, 4);
        assert!(s.verify_rounds() <= 4, "rounds {}", s.verify_rounds());
    }

    #[test]
    fn quantized_draft_is_token_identical_to_target_alone() {
        let target = tiny_model(71);
        let draft = rtn4(&target);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[7, 8, 9, 10], &[5]];
        for prompt in prompts {
            let want = target.greedy_decode(prompt, 14);
            for k in [1usize, 3, 4] {
                let s = run_spec(&target, &draft, prompt, 14, k);
                assert_eq!(s.generated(), &want[..], "prompt {prompt:?} k={k}");
                assert!(s.draft_accepted() <= s.draft_proposed());
            }
        }
    }

    #[test]
    fn unrelated_draft_still_matches_target_exactly() {
        // An adversarial draft (a different random model) disagrees with
        // the target almost everywhere, hammering the rejection + rollback
        // path — output must STILL be token-identical to the target alone.
        let target = tiny_model(72);
        let draft = tiny_model(973);
        let prompt: Vec<u16> = vec![2, 7, 1, 8, 2, 8];
        let want = target.greedy_decode(&prompt, 16);
        let s = run_spec(&target, &draft, &prompt, 16, 4);
        assert_eq!(s.generated(), &want[..]);
        assert_eq!(s.generated_len(), 16);
        // sanity: the adversarial draft really was mostly rejected (if this
        // ever fails the two "random" models agree suspiciously often)
        assert!(
            s.draft_accepted() < s.draft_proposed(),
            "unrelated draft was never rejected: {}/{}",
            s.draft_accepted(),
            s.draft_proposed()
        );
    }

    #[test]
    fn respects_max_new_and_max_seq_stops() {
        let target = tiny_model(73);
        let draft = rtn4(&target);
        // exact max_new, never overshoots regardless of k
        for (max_new, k) in [(1usize, 4usize), (2, 4), (5, 3), (9, 2)] {
            let s = run_spec(&target, &draft, &[4, 2], max_new, k);
            assert_eq!(s.generated_len(), max_new, "max_new={max_new} k={k}");
            assert_eq!(
                s.generated(),
                &target.greedy_decode(&[4, 2], max_new)[..],
                "max_new={max_new} k={k}"
            );
        }
        // max_seq cap: prompt of 60 on a max_seq-64 config stops at 4
        let prompt: Vec<u16> = (0..60u16).collect();
        let s = run_spec(&target, &draft, &prompt, 50, 4);
        assert_eq!(s.generated_len(), 4);
        assert_eq!(s.generated(), &target.greedy_decode(&prompt, 50)[..]);
    }

    #[test]
    fn max_new_zero_is_immediately_done() {
        let target = tiny_model(74);
        let mut s = SpeculativeSession::start(&target, &target, &[1, 2], 0, 4);
        assert!(s.is_done());
        assert!(s.round(&target, &target).is_none());
        assert!(s.generated().is_empty());
    }

    #[test]
    fn tier_parses_known_names_only() {
        assert_eq!(Tier::parse("draft"), Some(Tier::Draft));
        assert_eq!(Tier::parse("spec"), Some(Tier::Spec));
        assert_eq!(Tier::parse("full"), Some(Tier::Full));
        assert_eq!(Tier::parse("turbo"), None);
        assert_eq!(Tier::parse(""), None);
        for t in [Tier::Draft, Tier::Spec, Tier::Full] {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
    }
}
