//! Continuously batched inference service over a (compressed) model.
//!
//! Request path is Rust-only: a TCP front-end accepts JSON-line requests
//! (prompt + optional sampling controls), the [`batcher`] queues them, and
//! one worker steps a set of KV-cached [`crate::model::DecodeSession`]s —
//! one token per session per round, sessions joining and leaving the batch
//! as they arrive and finish (continuous batching). Latency/throughput
//! metrics come back per response and aggregated — the substrate for the
//! serving comparison in `examples/serve_compressed.rs` and the decode
//! benchmark (`benches/decode.rs`).
//!
//! With a second (cheaper) checkpoint loaded as a draft, the same worker
//! also serves speculative decoding ([`spec`]): requests pick a `tier` —
//! draft-only, target-only, or draft-proposed/target-verified — and the
//! spec tier's greedy output is token-identical to the target alone.

pub mod batcher;
pub mod server;
pub mod spec;

pub use batcher::{BatchPolicy, Batcher};
pub use server::{serve_blocking, serve_blocking_tiers, Client, GenRequest, GenResponse};
pub use spec::{SpecRound, SpeculativeSession, Tier};
