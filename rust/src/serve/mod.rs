//! Batched inference service over a (compressed) model.
//!
//! Request path is Rust-only: a TCP front-end accepts JSON-line requests,
//! the [`batcher`] groups them under a max-batch/max-wait policy, and the
//! worker decodes greedily over the in-memory model. Latency/throughput
//! metrics come back per response and aggregated — the substrate for the
//! serving comparison in `examples/serve_compressed.rs`.

pub mod batcher;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use server::{serve_blocking, GenRequest, GenResponse};
