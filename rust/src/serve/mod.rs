//! Continuously batched inference service over a (compressed) model.
//!
//! Request path is Rust-only: a TCP front-end accepts JSON-line requests
//! (prompt + optional sampling controls), the [`batcher`] queues them, and
//! one worker steps a set of KV-cached [`crate::model::DecodeSession`]s —
//! one token per session per round, sessions joining and leaving the batch
//! as they arrive and finish (continuous batching). Latency/throughput
//! metrics come back per response and aggregated — the substrate for the
//! serving comparison in `examples/serve_compressed.rs` and the decode
//! benchmark (`benches/decode.rs`).
//!
//! With a second (cheaper) checkpoint loaded as a draft, the same worker
//! also serves speculative decoding ([`spec`]): requests pick a `tier` —
//! draft-only, target-only, or draft-proposed/target-verified — and the
//! spec tier's greedy output is token-identical to the target alone.
//!
//! The request path is panic-hardened and statically gated: `compot audit`
//! (rule L3/L4, CI-enforced) forbids unwrap/expect/panic/indexing here
//! unless annotated, and the clippy attributes below promote stray
//! unwraps to warnings (CI runs clippy with `-D warnings`). Lock results
//! go through [`lock_recover`]/[`wait_timeout_recover`] so a panicked
//! worker poisons nothing: the panic is caught, the one request fails
//! with a structured error, and the server keeps answering.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
use std::time::Duration;

pub mod batcher;
pub mod pipeline;
pub mod server;
pub mod spec;

pub use batcher::{BatchPolicy, Batcher};
pub use pipeline::{
    parse_stage_range, pipeline_role, serve_pipeline_head, serve_pipeline_tail, PipelineRole,
    RelayClient,
};
pub use server::{serve_blocking, serve_blocking_tiers, Client, GenRequest, GenResponse};
pub use spec::{SpecRound, SpeculativeSession, Tier};

/// Poison-recovering `Mutex::lock`: a `PoisonError` only means some thread
/// panicked while holding the guard — the protected data (queues, counters)
/// is still structurally valid here, and refusing service forever because
/// one request died is the worse failure mode. Required in `serve/` by
/// audit rule L4.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-recovering `RwLock::read` — [`lock_recover`]'s reader twin for the
/// shared tables the pipeline tail keeps per session. Required in `serve/`
/// by audit rule L4, which flags unwrapped `.read()`/`.write()` results the
/// same way it flags `.lock()`.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-recovering `RwLock::write` — see [`read_recover`].
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-recovering `Condvar::wait_timeout`: returns the reacquired guard
/// and whether the wait timed out, recovering the guard from a poisoned
/// wait the same way [`lock_recover`] does.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}
