//! Continuously batched inference service over a (compressed) model.
//!
//! Request path is Rust-only: a TCP front-end accepts JSON-line requests
//! (prompt + optional sampling controls), the [`batcher`] queues them, and
//! one worker steps a set of KV-cached [`crate::model::DecodeSession`]s —
//! one token per session per round, sessions joining and leaving the batch
//! as they arrive and finish (continuous batching). Latency/throughput
//! metrics come back per response and aggregated — the substrate for the
//! serving comparison in `examples/serve_compressed.rs` and the decode
//! benchmark (`benches/decode.rs`).

pub mod batcher;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use server::{serve_blocking, Client, GenRequest, GenResponse};
