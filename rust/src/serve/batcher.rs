//! Request queueing for the continuous-batching worker: a blocking batch
//! drain (up to `max_batch` items or `max_wait`, whichever first — the
//! standard dynamic-batching admission policy) plus a non-blocking
//! [`Batcher::try_drain`] the worker uses to admit new sessions into a
//! running token-step batch without stalling the sessions already decoding.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{lock_recover, wait_timeout_recover};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A thread-safe FIFO with batch draining. `T` is the queued work item.
pub struct Batcher<T> {
    queue: Mutex<VecDeque<T>>,
    signal: Condvar,
    policy: BatchPolicy,
    closed: AtomicBool,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            signal: Condvar::new(),
            policy,
            closed: AtomicBool::new(false),
        }
    }

    /// Enqueue an item. Returns `false` (item dropped) once the batcher is
    /// closed — the closed check happens under the queue lock, so an item
    /// accepted here is guaranteed to be seen by the draining worker before
    /// it observes the closed-and-empty exit condition.
    pub fn push(&self, item: T) -> bool {
        let mut q = lock_recover(&self.queue);
        if self.closed.load(Ordering::SeqCst) {
            return false;
        }
        q.push_back(item);
        drop(q);
        self.signal.notify_one();
        true
    }

    /// Close the queue: already-enqueued items still drain (graceful
    /// shutdown), new pushes are rejected.
    pub fn close(&self) {
        // Take the lock so close serializes against in-flight pushes; after
        // this returns, every accepted item is in the queue.
        let _q = lock_recover(&self.queue);
        self.closed.store(true, Ordering::SeqCst);
        self.signal.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.queue).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until at least one item is available (or closed), then drain up
    /// to `max_batch` items, waiting at most `max_wait` to fill the batch.
    /// Returns an empty vec only when closed and drained.
    pub fn next_batch(&self) -> Vec<T> {
        let mut q = lock_recover(&self.queue);
        while q.is_empty() {
            if self.closed.load(Ordering::SeqCst) {
                return Vec::new();
            }
            let (guard, _) = wait_timeout_recover(&self.signal, q, Duration::from_millis(50));
            q = guard;
        }
        // First item arrived; give stragglers up to max_wait.
        let deadline = Instant::now() + self.policy.max_wait;
        while q.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timed_out) = wait_timeout_recover(&self.signal, q, deadline - now);
            q = guard;
            if timed_out {
                break;
            }
        }
        let take = q.len().min(self.policy.max_batch);
        q.drain(..take).collect()
    }

    /// Non-blocking drain of up to `max` items — how the continuous-batching
    /// worker tops up a running batch between token steps.
    pub fn try_drain(&self, max: usize) -> Vec<T> {
        let mut q = lock_recover(&self.queue);
        let take = q.len().min(max);
        q.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::sync::Arc;

    #[test]
    fn drains_in_fifo_order_up_to_max_batch() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
        });
        for i in 0..5 {
            assert!(b.push(i));
        }
        assert_eq!(b.next_batch(), vec![0, 1, 2]);
        assert_eq!(b.next_batch(), vec![3, 4]);
    }

    #[test]
    fn never_exceeds_max_batch_property() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        });
        for i in 0..23 {
            b.push(i);
        }
        let mut seen = Vec::new();
        while !b.is_empty() {
            let batch = b.next_batch();
            assert!(batch.len() <= 4 && !batch.is_empty());
            seen.extend(batch);
        }
        assert_eq!(seen, (0..23).collect::<Vec<_>>());
    }

    #[test]
    fn close_unblocks_waiters() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_empty());
    }

    #[test]
    fn close_drains_queued_items_but_rejects_new_ones() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        assert!(b.push(1));
        assert!(b.push(2));
        b.close();
        assert!(!b.push(3), "push after close must be rejected");
        assert_eq!(b.next_batch(), vec![1, 2]);
        assert!(b.next_batch().is_empty());
        assert_eq!(b.len(), 0);
    }

    #[test]
    fn try_drain_is_non_blocking_and_bounded() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(b.try_drain(4).is_empty());
        for i in 0..6 {
            b.push(i);
        }
        assert_eq!(b.try_drain(4), vec![0, 1, 2, 3]);
        assert_eq!(b.try_drain(4), vec![4, 5]);
        assert!(b.try_drain(4).is_empty());
    }

    #[test]
    fn poisoned_queue_recovers_instead_of_cascading() {
        // A thread that panics while holding the queue lock poisons the
        // mutex; lock_recover must shrug that off so the batcher keeps
        // accepting and draining work (the regression behind serve's
        // whole-server stats outage).
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        }));
        let b2 = b.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = b2.queue.lock().unwrap();
            panic!("deliberate poison");
        });
        assert!(poisoner.join().is_err(), "poisoner must have panicked");
        assert!(b.push(1), "push must survive a poisoned mutex");
        assert!(b.push(2));
        assert_eq!(b.len(), 2);
        assert_eq!(b.next_batch(), vec![1, 2]);
        b.close();
        assert!(b.next_batch().is_empty());
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        }));
        let mut handles = Vec::new();
        for t in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    assert!(b.push(t * 100 + i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut total = 0;
        while !b.is_empty() {
            total += b.next_batch().len();
        }
        assert_eq!(total, 100);
    }
}
