//! Request batching: collect up to `max_batch` requests or wait at most
//! `max_wait`, whichever first — the standard dynamic-batching policy.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A thread-safe FIFO with batch draining. `T` is the queued work item.
pub struct Batcher<T> {
    queue: Mutex<VecDeque<T>>,
    signal: Condvar,
    policy: BatchPolicy,
    closed: Mutex<bool>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            signal: Condvar::new(),
            policy,
            closed: Mutex::new(false),
        }
    }

    pub fn push(&self, item: T) {
        self.queue.lock().unwrap().push_back(item);
        self.signal.notify_one();
    }

    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.signal.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        *self.closed.lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until at least one item is available (or closed), then drain up
    /// to `max_batch` items, waiting at most `max_wait` to fill the batch.
    /// Returns an empty vec only when closed and drained.
    pub fn next_batch(&self) -> Vec<T> {
        let mut q = self.queue.lock().unwrap();
        while q.is_empty() {
            if *self.closed.lock().unwrap() {
                return Vec::new();
            }
            let (guard, _) = self.signal.wait_timeout(q, Duration::from_millis(50)).unwrap();
            q = guard;
        }
        // First item arrived; give stragglers up to max_wait.
        let deadline = Instant::now() + self.policy.max_wait;
        while q.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.signal.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = q.len().min(self.policy.max_batch);
        q.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn drains_in_fifo_order_up_to_max_batch() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
        });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.next_batch(), vec![0, 1, 2]);
        assert_eq!(b.next_batch(), vec![3, 4]);
    }

    #[test]
    fn never_exceeds_max_batch_property() {
        let b: Batcher<usize> = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        });
        for i in 0..23 {
            b.push(i);
        }
        let mut seen = Vec::new();
        while !b.is_empty() {
            let batch = b.next_batch();
            assert!(batch.len() <= 4 && !batch.is_empty());
            seen.extend(batch);
        }
        assert_eq!(seen, (0..23).collect::<Vec<_>>());
    }

    #[test]
    fn close_unblocks_waiters() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_empty());
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        }));
        let mut handles = Vec::new();
        for t in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    b.push(t * 100 + i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut total = 0;
        while !b.is_empty() {
            total += b.next_batch().len();
        }
        assert_eq!(total, 100);
    }
}
