//! Binary weight format shared with `python/compile/pretrain.py`.
//!
//! Layout:
//! ```text
//! b"CPT1" | u32 header_len | header JSON (utf-8) | f32-LE tensor data
//! ```
//! Header: `{"config": {...}, "tensors": [{"name", "rows", "cols", "offset"}]}`
//! with `offset` in f32 elements from the start of the data section.
//! Vector tensors (norms) are stored as 1×n matrices.
//!
//! CPT1 carries dense f32 tensors only. Compressed models serialize through
//! the `CPT2` format in [`super::cpt2`]; [`Model::load_checkpoint`]
//! (`super::cpt2`) sniffs the magic and accepts both.

use super::config::ModelConfig;
use crate::linalg::Mat;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"CPT1";

/// An on-disk bundle of named tensors plus the model config.
#[derive(Clone, Debug)]
pub struct TensorFile {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Mat>,
}

impl TensorFile {
    pub fn new(config: ModelConfig) -> TensorFile {
        TensorFile { config, tensors: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, m: Mat) {
        self.tensors.insert(name.to_string(), m);
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Mat> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' missing from weight file"))
    }

    /// Vector tensor (1×n) as a Vec.
    pub fn get_vec(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.get(name)?;
        anyhow::ensure!(m.rows() == 1, "tensor '{name}' is not a vector");
        Ok(m.row(0).to_vec())
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut tensor_list = Vec::new();
        let mut offset = 0usize;
        for (name, m) in &self.tensors {
            let mut t = Json::obj();
            t.set("name", name.as_str().into())
                .set("rows", m.rows().into())
                .set("cols", m.cols().into())
                .set("offset", offset.into());
            tensor_list.push(t);
            offset += m.rows() * m.cols();
        }
        let mut header = Json::obj();
        header.set("config", self.config.to_json()).set("tensors", Json::Arr(tensor_list));
        let header_bytes = header.to_string().into_bytes();

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
        f.write_all(&header_bytes)?;
        for m in self.tensors.values() {
            for &v in m.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<TensorFile> {
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut f = std::io::BufReader::new(file);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}");
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        // Never trust the header length field: bound it by the actual file
        // size *before* allocating, so a corrupt or adversarial file cannot
        // drive a huge allocation or a short-read panic.
        anyhow::ensure!(
            8 + hlen as u64 <= file_len,
            "header length {hlen} exceeds file size {file_len} in {path:?}"
        );
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)
            .map_err(|e| anyhow::anyhow!("bad header json: {e}"))?;
        let config = ModelConfig::from_json(
            header.get("config").ok_or_else(|| anyhow::anyhow!("no config"))?,
        )?;
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;
        anyhow::ensure!(data.len() % 4 == 0, "data not f32-aligned");
        let floats: Vec<f32> = data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut tensors = BTreeMap::new();
        for t in header
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("no tensors"))?
        {
            let name = t.get("name").and_then(Json::as_str).unwrap_or_default().to_string();
            let rows = t.get("rows").and_then(Json::as_usize).unwrap_or(0);
            let cols = t.get("cols").and_then(Json::as_usize).unwrap_or(0);
            let off = t.get("offset").and_then(Json::as_usize).unwrap_or(0);
            // Element counts come from the header too: checked arithmetic so
            // oversized claims fail cleanly instead of wrapping, then bound
            // against the floats actually read from the file.
            let count = rows
                .checked_mul(cols)
                .ok_or_else(|| anyhow::anyhow!("tensor '{name}' shape overflows"))?;
            let end = off
                .checked_add(count)
                .ok_or_else(|| anyhow::anyhow!("tensor '{name}' offset overflows"))?;
            anyhow::ensure!(end <= floats.len(), "tensor '{name}' out of range");
            tensors.insert(name, Mat::from_vec(rows, cols, floats[off..end].to_vec()));
        }
        Ok(TensorFile { config, tensors })
    }
}

/// Names used for the decoder-only LM.
pub mod names {
    use crate::model::config::ProjKind;

    pub fn block(i: usize, p: ProjKind) -> String {
        format!("blocks.{i}.{}", p.group())
    }

    pub fn block_norm(i: usize, which: &str) -> String {
        format!("blocks.{i}.{which}")
    }
}

impl super::transformer::Model {
    /// Serialize (dense projections only — compressed models are an
    /// in-memory concept; artifacts store the pretrained dense model).
    pub fn to_tensor_file(&self) -> TensorFile {
        use super::config::ProjKind;
        use super::transformer::Stage;
        let mut tf = TensorFile::new(self.cfg.clone());
        tf.insert("embed", self.embed.clone());
        tf.insert("lm_head", self.lm_head.clone());
        tf.insert("final_norm", Mat::from_vec(1, self.final_norm.len(), self.final_norm.clone()));
        for (i, stage) in self.stages.iter().enumerate() {
            let Stage::Block(b) = stage else {
                panic!("to_tensor_file: only dense block models are serializable")
            };
            tf.insert(
                &names::block_norm(i, "attn_norm"),
                Mat::from_vec(1, b.attn_norm.len(), b.attn_norm.clone()),
            );
            tf.insert(
                &names::block_norm(i, "mlp_norm"),
                Mat::from_vec(1, b.mlp_norm.len(), b.mlp_norm.clone()),
            );
            for p in ProjKind::DECODER_SET {
                tf.insert(&names::block(i, p), b.proj(p).to_dense());
            }
        }
        tf
    }

    pub fn from_tensor_file(tf: &TensorFile) -> anyhow::Result<Self> {
        use super::config::ProjKind;
        use super::transformer::{Block, Stage};
        use crate::compress::LinearWeight;
        let cfg = tf.config.clone();
        let mut stages = Vec::new();
        for i in 0..cfg.n_layers {
            let mk = |p: ProjKind| -> anyhow::Result<LinearWeight> {
                Ok(LinearWeight::Dense(tf.get(&names::block(i, p))?.clone()))
            };
            stages.push(Stage::Block(Block {
                attn_norm: tf.get_vec(&names::block_norm(i, "attn_norm"))?,
                q: mk(ProjKind::Q)?,
                k: mk(ProjKind::K)?,
                v: mk(ProjKind::V)?,
                o: mk(ProjKind::O)?,
                mlp_norm: tf.get_vec(&names::block_norm(i, "mlp_norm"))?,
                gate: mk(ProjKind::Gate)?,
                up: mk(ProjKind::Up)?,
                down: mk(ProjKind::Down)?,
                n_heads: cfg.n_heads,
                n_kv_heads: cfg.n_kv_heads,
            }));
        }
        Ok(Self {
            embed: tf.get("embed")?.clone(),
            lm_head: tf.get("lm_head")?.clone(),
            final_norm: tf.get_vec("final_norm")?,
            stages,
            cfg,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.to_tensor_file().save(path)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_tensor_file(&TensorFile::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::Model;
    use crate::util::Rng;

    #[test]
    fn model_roundtrip_through_disk() {
        let cfg = ModelConfig::test_tiny();
        let m = Model::random(&cfg, &mut Rng::new(1));
        let dir = std::env::temp_dir().join("compot_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.bin");
        m.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        assert_eq!(back.cfg, m.cfg);
        let tokens: Vec<u16> = vec![3, 1, 4, 1, 5];
        assert!(back.forward(&tokens).rel_err(&m.forward(&tokens)) < 1e-6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let cfg = ModelConfig::test_tiny();
        let m = Model::random(&cfg, &mut Rng::new(2));
        let mut tf = m.to_tensor_file();
        tf.tensors.remove("embed");
        assert!(Model::from_tensor_file(&tf).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("compot_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorFile::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_len_is_bounded_by_file_size() {
        // A 4 GB header-length claim on an 8-byte file must error cleanly
        // before any allocation, not attempt a huge Vec or short-read panic.
        let dir = std::env::temp_dir().join("compot_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hugelen.bin");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = TensorFile::load(&path).unwrap_err().to_string();
        assert!(err.contains("exceeds file size"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn oversized_tensor_claims_are_errors() {
        let cfg = ModelConfig::test_tiny();
        let m = Model::random(&cfg, &mut Rng::new(3));
        let dir = std::env::temp_dir().join("compot_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("oversized.bin");
        m.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = String::from_utf8(bytes[8..8 + hlen].to_vec()).unwrap();
        let rewrite = |patched: &str| {
            let mut out = MAGIC.to_vec();
            out.extend_from_slice(&(patched.len() as u32).to_le_bytes());
            out.extend_from_slice(patched.as_bytes());
            out.extend_from_slice(&bytes[8 + hlen..]);
            std::fs::write(&path, &out).unwrap();
        };
        // Claim a vastly larger row count for one tensor: far beyond the
        // data section, so the bound check must reject it. ("rows" is the
        // last key of a record in the BTreeMap serialization, hence "}".)
        let patched = header.replacen("\"rows\":1}", "\"rows\":99999999}", 1);
        assert_ne!(patched, header, "expected a 1-row tensor in the header");
        rewrite(&patched);
        let err = TensorFile::load(&path).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // Shapes that overflow usize arithmetic are errors, not wraps.
        rewrite(&header.replacen(
            "\"rows\":1}",
            "\"rows\":9999999999999999999}",
            1,
        ));
        let err = TensorFile::load(&path).unwrap_err().to_string();
        assert!(
            err.contains("overflows") || err.contains("out of range"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn vector_tensor_helpers() {
        let mut tf = TensorFile::new(ModelConfig::test_tiny());
        tf.insert("v", Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]));
        assert_eq!(tf.get_vec("v").unwrap(), vec![1.0, 2.0, 3.0]);
        tf.insert("m", Mat::zeros(2, 2));
        assert!(tf.get_vec("m").is_err());
        assert!(tf.get("nothere").is_err());
    }
}
