//! Incremental decoding runtime: per-layer KV caches, compressed-native
//! decode steps, and resumable [`DecodeSession`]s.
//!
//! The batched forward in [`super::transformer`] recomputes the full O(T²)
//! attention over the whole sequence for every generated token. This module
//! splits generation into the standard two phases:
//!
//! - **prefill** — one batched pass over the prompt that populates a
//!   [`KvCache`] with every layer's post-RoPE K and V rows;
//! - **decode step** — one token per call: each projection runs natively in
//!   its stored representation ([`LinearWeight::apply_row`] — dense mat-vec,
//!   low-rank double mat-vec, dictionary mat-vec + sparse gather, or the
//!   fused-dequant matvec straight off b-bit packed buffers for the
//!   quantized variants; never a densified weight), and attention reads the
//!   cache, costing O(T) instead of O(T²).
//!
//! Both phases reuse the exact per-row arithmetic of the batched path
//! (`rmsnorm_row`, `rope_row`, `attention_head`, `matvec_row` mirroring
//! GEMM's accumulation order), so cached greedy decoding is bit-identical to
//! [`Model::greedy_decode_full`] — asserted by the parity tests here and in
//! `tests/integration.rs`.
//!
//! [`DecodeSession`] packages cache + sampler + stop conditions so the
//! serving layer can step many sessions round-robin and admit/retire them
//! mid-flight (continuous batching, see `serve::server`).

use super::transformer::{rmsnorm, rmsnorm_row, rope_row, silu, Block, Model, Stage};
use crate::linalg::{gemm, Mat};
use crate::util::Rng;

/// Cached K/V rows of one decoder block. Storage is preallocated to the
/// cache capacity; the model-level [`KvCache::len`] says how many rows are
/// valid.
#[derive(Clone, Debug)]
pub struct LayerKv {
    /// capacity × (n_kv_heads · head_dim), post-RoPE keys.
    k: Mat,
    /// capacity × (n_kv_heads · head_dim), values.
    v: Mat,
}

impl LayerKv {
    fn new(capacity: usize, kv_width: usize) -> LayerKv {
        LayerKv { k: Mat::zeros(capacity, kv_width), v: Mat::zeros(capacity, kv_width) }
    }

    /// Append a batch of rows starting at `pos0` (prefill).
    pub(crate) fn append(&mut self, pos0: usize, k_new: &Mat, v_new: &Mat) {
        debug_assert_eq!(k_new.shape(), v_new.shape());
        for t in 0..k_new.rows() {
            self.k.row_mut(pos0 + t).copy_from_slice(k_new.row(t));
            self.v.row_mut(pos0 + t).copy_from_slice(v_new.row(t));
        }
    }

    /// First `len` cached key rows as a len×width matrix.
    pub(crate) fn k_rows(&self, len: usize) -> Mat {
        self.k.rows_range(0, len)
    }

    pub(crate) fn v_rows(&self, len: usize) -> Mat {
        self.v.rows_range(0, len)
    }

    /// Append one row at `pos` (decode step).
    fn append_row(&mut self, pos: usize, k: &[f32], v: &[f32]) {
        self.k.row_mut(pos).copy_from_slice(k);
        self.v.row_mut(pos).copy_from_slice(v);
    }
}

/// Per-model KV cache: one [`LayerKv`] per [`Stage::Block`] (Linear
/// replacement stages are stateless), plus the shared token position.
#[derive(Clone, Debug)]
pub struct KvCache {
    layers: Vec<Option<LayerKv>>,
    len: usize,
    capacity: usize,
}

impl KvCache {
    /// Tokens currently cached (= absolute position of the next token).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Roll the cache back to `len` tokens. O(1): [`LayerKv`] storage is
    /// preallocated and rows are written in place by position, so shrinking
    /// the valid length is all a rollback takes — the stale rows beyond
    /// `len` are overwritten by whatever is decoded next, and re-decoding
    /// the same tokens reproduces bit-identical state (tested). This is the
    /// rollback primitive speculative decoding needs when the target model
    /// rejects part of a drafted run ([`crate::serve::spec`]).
    pub fn truncate(&mut self, len: usize) {
        assert!(
            len <= self.len,
            "KvCache::truncate: cannot extend ({len} > {} cached rows)",
            self.len
        );
        self.len = len;
    }
}

impl Model {
    /// Fresh KV cache sized for the config's `max_seq`.
    pub fn new_cache(&self) -> KvCache {
        self.new_cache_with(self.cfg.max_seq)
    }

    /// Fresh KV cache with an explicit row capacity (long-sequence eval).
    pub fn new_cache_with(&self, capacity: usize) -> KvCache {
        let layers = self
            .stages
            .iter()
            .map(|s| match s {
                Stage::Block(b) => Some(LayerKv::new(capacity, b.k.out_dim())),
                Stage::Linear(_) => None,
            })
            .collect();
        KvCache { layers, len: 0, capacity }
    }

    /// Batched pass over `tokens` starting at the cache's current position:
    /// fills every layer's K/V rows and returns the T×vocab logits. With an
    /// empty cache this computes exactly [`Model::forward`] (bit-identical),
    /// plus the side effect of populating the cache.
    pub fn prefill(&self, cache: &mut KvCache, tokens: &[u16]) -> Mat {
        assert!(!tokens.is_empty(), "prefill: empty token sequence");
        assert_eq!(cache.layers.len(), self.stages.len(), "cache built for a different model");
        assert!(
            cache.len + tokens.len() <= cache.capacity,
            "prefill: {} + {} tokens exceed cache capacity {}",
            cache.len,
            tokens.len(),
            cache.capacity
        );
        let hd = self.cfg.head_dim();
        let pos0 = cache.len;
        let mut x = self.embed_tokens(tokens);
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    // audit:allow(panic): KvCache::new builds one LayerKv
                    // per Block stage from this same stage list, so a Block
                    // always finds its cache entry.
                    let kv = cache.layers[layer].as_mut().expect("block stage has a cache");
                    b.forward_cached(&x, hd, self.cfg.rope_theta, kv, pos0)
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        cache.len += tokens.len();
        gemm::matmul(&rmsnorm(&x, &self.final_norm), &self.lm_head)
    }

    /// One incremental decode step: feed a single token at the cache's
    /// current position and return its logits row. Every projection executes
    /// in compressed form via [`LinearWeight::apply_row`]; attention runs
    /// against the cached K/V only — O(T) per token.
    pub fn decode_step(&self, cache: &mut KvCache, token: u16) -> Vec<f32> {
        let pos = cache.len;
        assert!(pos < cache.capacity, "decode_step: KV cache full ({pos} rows)");
        let hd = self.cfg.head_dim();
        let mut x: Vec<f32> = self.embed.row(token as usize).to_vec();
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    // audit:allow(panic): KvCache::new builds one LayerKv
                    // per Block stage from this same stage list, so a Block
                    // always finds its cache entry.
                    let kv = cache.layers[layer].as_mut().expect("block stage has a cache");
                    b.decode_step(&x, hd, self.cfg.rope_theta, kv, pos)
                }
                Stage::Linear(t) => gemm::matvec_row(&x, t),
            };
        }
        cache.len += 1;
        let xn = rmsnorm_row(&x, &self.final_norm);
        gemm::matvec_row(&xn, &self.lm_head)
    }

    /// Multi-row decode step: feed `tokens` starting at the cache's current
    /// position and return their k×vocab logits — row `t` is exactly what
    /// [`Model::decode_step`] would have returned after feeding
    /// `tokens[..t]`. One call, one activation matrix per layer: every
    /// projection dispatches [`LinearWeight::apply`] (blocked GEMM) for
    /// k > 1 and falls back to the single-row [`decode_step`] kernel for
    /// k == 1, while attention stays per-row against the cache so the
    /// arithmetic is shared with the sequential path (bit-identical —
    /// parity-tested below). This is the target-verify kernel of
    /// speculative decoding ([`crate::serve::spec`]) and the first batched
    /// GEMM on the decode path (groundwork for batched decode, ROADMAP).
    pub fn decode_step_multi(&self, cache: &mut KvCache, tokens: &[u16]) -> Mat {
        assert!(!tokens.is_empty(), "decode_step_multi: empty token batch");
        if tokens.len() == 1 {
            // k == 1 is the plain decode step: per-row kernels, no GEMM.
            let row = self.decode_step(cache, tokens[0]);
            return Mat::from_vec(1, row.len(), row);
        }
        let pos0 = cache.len;
        assert!(
            pos0 + tokens.len() <= cache.capacity,
            "decode_step_multi: {pos0} + {} tokens exceed cache capacity {}",
            tokens.len(),
            cache.capacity
        );
        let hd = self.cfg.head_dim();
        let mut x = self.embed_tokens(tokens);
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    // audit:allow(panic): KvCache::new builds one LayerKv
                    // per Block stage from this same stage list, so a Block
                    // always finds its cache entry.
                    let kv = cache.layers[layer].as_mut().expect("block stage has a cache");
                    b.decode_step_multi(&x, hd, self.cfg.rope_theta, kv, pos0)
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        cache.len += tokens.len();
        gemm::matmul(&rmsnorm(&x, &self.final_norm), &self.lm_head)
    }

    /// Cross-session batched decode step: feed one token per session, each
    /// against its *own* cache at its *own* position, and return the
    /// B×vocab logits — row `b` is exactly what [`Model::decode_step`] on
    /// `caches[b]` alone would have returned. One call, one activation
    /// matrix per layer: every projection dispatches a single
    /// [`LinearWeight::apply`] (blocked GEMM) across the whole batch, while
    /// RoPE, KV appends, and attention stay per-row against each session's
    /// cache ([`Block::decode_step_batch`]). B == 1 falls back to the plain
    /// matvec [`decode_step`] kernel. This is the serve worker's round
    /// kernel ([`crate::serve::server`]): N active sessions cost one GEMM
    /// per projection per layer per round instead of N matvecs.
    ///
    /// Bit-identity with each session stepping alone rests on the
    /// `apply`/`apply_row` accumulation-order invariant (see
    /// `linalg::gemm::matvec_row`) and is parity-tested for every
    /// `LinearWeight` variant at heterogeneous cache positions.
    pub fn decode_step_batch(&self, caches: &mut [&mut KvCache], tokens: &[u16]) -> Mat {
        assert!(!tokens.is_empty(), "decode_step_batch: empty batch");
        assert_eq!(
            caches.len(),
            tokens.len(),
            "decode_step_batch: {} caches for {} tokens",
            caches.len(),
            tokens.len()
        );
        if tokens.len() == 1 {
            // B == 1 is the plain decode step: per-row kernels, no GEMM.
            let row = self.decode_step(&mut *caches[0], tokens[0]);
            return Mat::from_vec(1, row.len(), row);
        }
        // Read every session's position once up front — all stages of this
        // round see the same snapshot; lengths advance only at the end.
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        for (b, c) in caches.iter().enumerate() {
            assert_eq!(
                c.layers.len(),
                self.stages.len(),
                "decode_step_batch: cache {b} built for a different model"
            );
            assert!(
                positions[b] < c.capacity,
                "decode_step_batch: KV cache {b} full ({} rows)",
                positions[b]
            );
        }
        let hd = self.cfg.head_dim();
        let mut x = self.embed_tokens(tokens);
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    let mut rows: Vec<(&mut LayerKv, usize)> = caches
                        .iter_mut()
                        .zip(positions.iter())
                        .map(|(c, &p)| {
                            // audit:allow(panic): every cache was asserted
                            // above to mirror this model's stage list.
                            (c.layers[layer].as_mut().expect("block stage has a cache"), p)
                        })
                        .collect();
                    b.decode_step_batch(&x, hd, self.cfg.rope_theta, &mut rows)
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        gemm::matmul(&rmsnorm(&x, &self.final_norm), &self.lm_head)
    }

    /// Hidden-state prefill for one pipeline stage range: run `x` (T×d
    /// hidden rows, e.g. [`Model::embed_tokens`] on the head stage or the
    /// previous hop's relayed rows downstream) through this model's stages
    /// starting at the cache's current position, filling every layer's K/V
    /// rows, and return the T×d output hidden rows — **no** final norm and
    /// **no** LM head, so partial models built by
    /// [`Model::load_stage_range`] (`lm_head` empty on non-tail stages) run
    /// it unchanged. On a full model, `forward_hidden_cached(embed_tokens(
    /// toks))` followed by the tail logits helper reproduces
    /// [`Model::prefill`] bit-identically — the pipeline parity spine,
    /// tested below for every `LinearWeight` variant.
    pub fn forward_hidden_cached(&self, cache: &mut KvCache, x: Mat) -> Mat {
        assert!(x.rows() > 0, "forward_hidden_cached: empty hidden batch");
        assert_eq!(x.cols(), self.cfg.d_model, "forward_hidden_cached: hidden width");
        assert_eq!(cache.layers.len(), self.stages.len(), "cache built for a different model");
        assert!(
            cache.len + x.rows() <= cache.capacity,
            "forward_hidden_cached: {} + {} rows exceed cache capacity {}",
            cache.len,
            x.rows(),
            cache.capacity
        );
        let hd = self.cfg.head_dim();
        let pos0 = cache.len;
        let rows = x.rows();
        let mut x = x;
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    // audit:allow(panic): KvCache::new builds one LayerKv
                    // per Block stage from this same stage list, so a Block
                    // always finds its cache entry.
                    let kv = cache.layers[layer].as_mut().expect("block stage has a cache");
                    b.forward_cached(&x, hd, self.cfg.rope_theta, kv, pos0)
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        cache.len += rows;
        x
    }

    /// Single-row hidden decode step: one hidden row at the cache's current
    /// position, per-row kernels only ([`LinearWeight::apply_row`]) — the
    /// stage-range slice of [`Model::decode_step`] between the embedding
    /// and the LM head. Chaining the head stage's output into the tail
    /// stage reproduces `decode_step` on the unsplit model bitwise.
    pub fn decode_hidden_row(&self, cache: &mut KvCache, x: &[f32]) -> Vec<f32> {
        let pos = cache.len;
        assert!(pos < cache.capacity, "decode_hidden_row: KV cache full ({pos} rows)");
        assert_eq!(x.len(), self.cfg.d_model, "decode_hidden_row: hidden width");
        let hd = self.cfg.head_dim();
        let mut x: Vec<f32> = x.to_vec();
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    // audit:allow(panic): KvCache::new builds one LayerKv
                    // per Block stage from this same stage list, so a Block
                    // always finds its cache entry.
                    let kv = cache.layers[layer].as_mut().expect("block stage has a cache");
                    b.decode_step(&x, hd, self.cfg.rope_theta, kv, pos)
                }
                Stage::Linear(t) => gemm::matvec_row(&x, t),
            };
        }
        cache.len += 1;
        x
    }

    /// Cross-session batched hidden decode step: row `b` of `x` is one
    /// session's hidden row, advanced against `caches[b]` at its own
    /// position — [`Model::decode_step_batch`] without the embedding or the
    /// LM head, so one pipeline stage can keep PR 7's one-GEMM-per-layer
    /// round shape over its slice of the model. B == 1 falls back to the
    /// per-row [`Model::decode_hidden_row`] kernels, keeping single-session
    /// pipeline serving bit-identical to single-host `decode_step`.
    pub fn decode_hidden_batch(&self, caches: &mut [&mut KvCache], x: Mat) -> Mat {
        assert!(x.rows() > 0, "decode_hidden_batch: empty batch");
        assert_eq!(
            caches.len(),
            x.rows(),
            "decode_hidden_batch: {} caches for {} rows",
            caches.len(),
            x.rows()
        );
        if x.rows() == 1 {
            // B == 1 is the plain hidden decode step: per-row kernels.
            let row = self.decode_hidden_row(&mut *caches[0], x.row(0));
            return Mat::from_vec(1, row.len(), row);
        }
        assert_eq!(x.cols(), self.cfg.d_model, "decode_hidden_batch: hidden width");
        // Read every session's position once up front — all stages of this
        // round see the same snapshot; lengths advance only at the end.
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        for (b, c) in caches.iter().enumerate() {
            assert_eq!(
                c.layers.len(),
                self.stages.len(),
                "decode_hidden_batch: cache {b} built for a different model"
            );
            assert!(
                positions[b] < c.capacity,
                "decode_hidden_batch: KV cache {b} full ({} rows)",
                positions[b]
            );
        }
        let hd = self.cfg.head_dim();
        let mut x = x;
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    let mut rows: Vec<(&mut LayerKv, usize)> = caches
                        .iter_mut()
                        .zip(positions.iter())
                        .map(|(c, &p)| {
                            // audit:allow(panic): every cache was asserted
                            // above to mirror this model's stage list.
                            (c.layers[layer].as_mut().expect("block stage has a cache"), p)
                        })
                        .collect();
                    b.decode_step_batch(&x, hd, self.cfg.rope_theta, &mut rows)
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        x
    }

    /// Tail-of-pipeline logits for one hidden row: final RMSNorm + LM head
    /// through the same per-row kernels [`Model::decode_step`] ends with,
    /// so the pipeline tail's logits are bit-identical to the single-host
    /// path (the `matvec_row`/`matmul` accumulation-order invariant makes
    /// this hold for batched prefill rows too).
    pub fn logits_from_hidden_row(&self, x: &[f32]) -> Vec<f32> {
        assert!(
            self.lm_head.rows() > 0,
            "logits_from_hidden_row: this partial model has no LM head (not the tail stage)"
        );
        gemm::matvec_row(&rmsnorm_row(x, &self.final_norm), &self.lm_head)
    }

    /// Sampled continuation of `prompt` by up to `max_new` tokens through
    /// the incremental runtime. Returns `[]` for an empty prompt or
    /// `max_new == 0`; stops early at the config's `max_seq` (matching
    /// [`Model::greedy_decode_full`]'s stop rule).
    pub fn generate(&self, prompt: &[u16], max_new: usize, sampling: SamplerCfg) -> Vec<u16> {
        if prompt.is_empty() || max_new == 0 {
            return Vec::new();
        }
        let mut session = DecodeSession::start(self, prompt, max_new, sampling);
        while session.step(self).is_some() {}
        session.generated().to_vec()
    }
}

impl Block {
    /// Batched forward that also appends this block's post-RoPE K and V rows
    /// to `kv` (rows `pos0..pos0+T`). Attention runs causally over *all*
    /// cached rows, so suffix prefills (`pos0 > 0`) see the earlier context.
    /// Delegates to the single shared block body
    /// ([`Block::forward_core`]) — the cached and stateless paths cannot
    /// drift apart.
    pub fn forward_cached(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        kv: &mut LayerKv,
        pos0: usize,
    ) -> Mat {
        self.forward_core(x, head_dim, theta, true, 0, None, Some((kv, pos0)))
    }

    /// Single-token forward at absolute position `pos`, attending to the
    /// `pos` cached rows plus itself. Projections run through
    /// [`LinearWeight::apply_row`] — the compressed-native decode step.
    pub fn decode_step(
        &self,
        x: &[f32],
        head_dim: usize,
        theta: f32,
        kv: &mut LayerKv,
        pos: usize,
    ) -> Vec<f32> {
        // ---- attention ----
        let xn = rmsnorm_row(x, &self.attn_norm);
        let mut q = self.q.apply_row(&xn);
        let mut k = self.k.apply_row(&xn);
        let v = self.v.apply_row(&xn);
        rope_row(&mut q, head_dim, theta, pos);
        rope_row(&mut k, head_dim, theta, pos);
        kv.append_row(pos, &k, &v);
        let concat = self.attend_row(&q, kv, head_dim, pos + 1);
        let attn_out = self.o.apply_row(&concat);
        let x1: Vec<f32> = x.iter().zip(attn_out.iter()).map(|(a, b)| a + b).collect();

        // ---- MLP (SwiGLU) ----
        let xn2 = rmsnorm_row(&x1, &self.mlp_norm);
        let g = self.gate.apply_row(&xn2);
        let u = self.up.apply_row(&xn2);
        let h: Vec<f32> = g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv).collect();
        let mlp_out = self.down.apply_row(&h);
        x1.iter().zip(mlp_out.iter()).map(|(a, b)| a + b).collect()
    }

    /// Cached attention for one query row against the first `total` cached
    /// rows, reading K/V head slices straight out of the cache storage — no
    /// per-head `Mat` materialization. The only per-call scratch is one
    /// `total`-length scores buffer, reused across every head; scores run
    /// through the same dot kernel GEMM uses ([`gemm::dot_f32`]) and the
    /// softmax + weighted-V accumulation mirrors
    /// [`super::transformer::attention_head`] operation for operation, so
    /// this stays bit-identical to the batched reference path. The one
    /// attention body [`Block::decode_step`], [`Block::decode_step_multi`],
    /// and [`Block::decode_step_batch`] all run, so the sequential and
    /// batched decode paths cannot drift apart.
    fn attend_row(&self, q: &[f32], kv: &LayerKv, head_dim: usize, total: usize) -> Vec<f32> {
        let q_per_kv = self.n_heads / self.n_kv_heads;
        let scale = 1.0 / (head_dim as f32).sqrt();
        let mut concat = vec![0f32; self.n_heads * head_dim];
        let mut scores = vec![0f32; total];
        for h in 0..self.n_heads {
            let off = (h / q_per_kv) * head_dim;
            let qh = &q[h * head_dim..(h + 1) * head_dim];
            for (j, s) in scores.iter_mut().enumerate() {
                *s = gemm::dot_f32(qh, &kv.k.row(j)[off..off + head_dim]);
            }
            let mut maxv = f32::NEG_INFINITY;
            for s in scores.iter_mut() {
                *s *= scale;
                maxv = maxv.max(*s);
            }
            let mut denom = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - maxv).exp();
                denom += *s;
            }
            let inv = 1.0 / denom.max(1e-20);
            let orow = &mut concat[h * head_dim..(h + 1) * head_dim];
            for (j, &s) in scores.iter().enumerate() {
                let w = s * inv;
                if w == 0.0 {
                    continue;
                }
                let vrow = &kv.v.row(j)[off..off + head_dim];
                for (oc, vc) in orow.iter_mut().zip(vrow.iter()) {
                    *oc += w * vc;
                }
            }
        }
        concat
    }

    /// Multi-row decode step at positions `pos0..pos0+k`: projections run
    /// batched through [`LinearWeight::apply`] (one blocked GEMM per
    /// projection instead of k matvecs), while RoPE, KV appends, and
    /// attention run per row through exactly the code [`Block::decode_step`]
    /// runs — row `t` attends to the `pos0 + t + 1` cached rows its
    /// sequential twin would see. Bit-identity with k sequential steps rests
    /// on the `apply`/`apply_row` accumulation-order invariant the per-row
    /// kernels are built on (see `linalg::gemm::matvec_row`) and is
    /// parity-tested for every `LinearWeight` variant.
    pub fn decode_step_multi(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        kv: &mut LayerKv,
        pos0: usize,
    ) -> Mat {
        // ---- attention ----
        let xn = rmsnorm(x, &self.attn_norm);
        let mut q = self.q.apply(&xn);
        let mut k = self.k.apply(&xn);
        let v = self.v.apply(&xn);
        for t in 0..x.rows() {
            rope_row(q.row_mut(t), head_dim, theta, pos0 + t);
            rope_row(k.row_mut(t), head_dim, theta, pos0 + t);
            kv.append_row(pos0 + t, k.row(t), v.row(t));
        }
        let mut concat = Mat::zeros(x.rows(), self.n_heads * head_dim);
        for t in 0..x.rows() {
            let row = self.attend_row(q.row(t), kv, head_dim, pos0 + t + 1);
            concat.row_mut(t).copy_from_slice(&row);
        }
        let attn_out = self.o.apply(&concat);
        let x1 = x.add(&attn_out);

        // ---- MLP (SwiGLU) ----
        let xn2 = rmsnorm(&x1, &self.mlp_norm);
        let g = self.gate.apply(&xn2);
        let u = self.up.apply(&xn2);
        let mut h = g;
        for i in 0..h.rows() {
            let hrow = h.row_mut(i);
            for (hv, uv) in hrow.iter_mut().zip(u.row(i).iter()) {
                *hv = silu(*hv) * uv;
            }
        }
        let mlp_out = self.down.apply(&h);
        x1.add(&mlp_out)
    }

    /// Cross-session decode step: row `t` of `x` is one session's hidden
    /// row, and `rows[t]` is that session's layer cache plus its absolute
    /// position. Generalizes [`Block::decode_step_multi`] from "one cache,
    /// consecutive positions" to "one cache *per row*, arbitrary positions":
    /// projections run batched through [`LinearWeight::apply`] (one blocked
    /// GEMM per projection for the whole batch), while RoPE, the KV append,
    /// and attention run per row against each row's own cache — exactly the
    /// kernels [`Block::decode_step`] runs, so every output row is
    /// bit-identical to that session stepping alone (the `apply`/`apply_row`
    /// accumulation-order invariant; parity-tested for every `LinearWeight`
    /// variant).
    pub fn decode_step_batch(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        rows: &mut [(&mut LayerKv, usize)],
    ) -> Mat {
        debug_assert_eq!(x.rows(), rows.len());
        // ---- attention ----
        let xn = rmsnorm(x, &self.attn_norm);
        let mut q = self.q.apply(&xn);
        let mut k = self.k.apply(&xn);
        let v = self.v.apply(&xn);
        for (t, (kv, pos)) in rows.iter_mut().enumerate() {
            rope_row(q.row_mut(t), head_dim, theta, *pos);
            rope_row(k.row_mut(t), head_dim, theta, *pos);
            kv.append_row(*pos, k.row(t), v.row(t));
        }
        let mut concat = Mat::zeros(x.rows(), self.n_heads * head_dim);
        for (t, (kv, pos)) in rows.iter().enumerate() {
            let row = self.attend_row(q.row(t), &**kv, head_dim, pos + 1);
            concat.row_mut(t).copy_from_slice(&row);
        }
        let attn_out = self.o.apply(&concat);
        let x1 = x.add(&attn_out);

        // ---- MLP (SwiGLU) ----
        let xn2 = rmsnorm(&x1, &self.mlp_norm);
        let g = self.gate.apply(&xn2);
        let u = self.up.apply(&xn2);
        let mut h = g;
        for i in 0..h.rows() {
            let hrow = h.row_mut(i);
            for (hv, uv) in hrow.iter_mut().zip(u.row(i).iter()) {
                *hv = silu(*hv) * uv;
            }
        }
        let mlp_out = self.down.apply(&h);
        x1.add(&mlp_out)
    }
}

/// First index of the maximum logit (strict-greater rule — matches the
/// original greedy loop, first max wins).
pub fn argmax(logits: &[f32]) -> u16 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u16
}

/// Sampling controls for the decode path. `temperature <= 0` is greedy
/// (argmax); otherwise softmax sampling at the given temperature over the
/// `top_k` highest logits (`top_k == 0` keeps the full vocabulary). `seed`
/// makes every continuation reproducible through [`crate::util::Rng`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplerCfg {
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl SamplerCfg {
    pub fn greedy() -> SamplerCfg {
        SamplerCfg { temperature: 0.0, top_k: 0, seed: 0 }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg::greedy()
    }
}

/// Stateful sampler: config + its deterministic RNG stream.
#[derive(Clone, Debug)]
pub struct Sampler {
    cfg: SamplerCfg,
    rng: Rng,
}

impl Sampler {
    pub fn new(cfg: SamplerCfg) -> Sampler {
        Sampler { cfg, rng: Rng::new(cfg.seed) }
    }

    /// Pick the next token from a logits row.
    pub fn pick(&mut self, logits: &[f32]) -> u16 {
        if self.cfg.is_greedy() {
            return argmax(logits);
        }
        let vocab = logits.len();
        // top_k == 0 must mean "no top-k filtering", never an empty
        // candidate set — `select_nth_unstable_by(k - 1, ..)` below would
        // underflow on k == 0, and truncating to zero candidates would make
        // the weighted draw panic.
        let k = match self.cfg.top_k {
            0 => vocab,
            k => k.min(vocab),
        };
        let mut order: Vec<u32> = (0..vocab as u32).collect();
        if k < vocab {
            order.select_nth_unstable_by(k - 1, |&a, &b| {
                logits[b as usize].total_cmp(&logits[a as usize]).then(a.cmp(&b))
            });
            order.truncate(k);
        }
        let inv_t = 1.0 / self.cfg.temperature as f64;
        let maxv = order
            .iter()
            .map(|&i| logits[i as usize])
            .fold(f32::NEG_INFINITY, f32::max) as f64;
        let weights: Vec<f64> = order
            .iter()
            .map(|&i| ((logits[i as usize] as f64 - maxv) * inv_t).exp())
            .collect();
        order[self.rng.weighted(&weights)] as u16
    }
}

/// One in-flight generation: KV cache, sampler state, and stop conditions.
/// Built by `start` (prefill + first sampled token), advanced one token at a
/// time by `step` — the unit the continuous batcher schedules.
#[derive(Clone, Debug)]
pub struct DecodeSession {
    cache: KvCache,
    sampler: Sampler,
    tokens: Vec<u16>,
    prompt_len: usize,
    max_new: usize,
    max_total: usize,
    done: bool,
}

impl DecodeSession {
    /// Prefill `prompt` and sample the first new token (unless
    /// `max_new == 0`). The cache is sized `max(prompt len, max_seq)`, the
    /// most generation can ever feed given the stop rule.
    pub fn start(
        model: &Model,
        prompt: &[u16],
        max_new: usize,
        sampling: SamplerCfg,
    ) -> DecodeSession {
        assert!(!prompt.is_empty(), "DecodeSession: empty prompt");
        let mut cache = model.new_cache_with(prompt.len().max(model.cfg.max_seq));
        let mut sampler = Sampler::new(sampling);
        let mut tokens = prompt.to_vec();
        let max_total = model.cfg.max_seq;
        let mut done = max_new == 0;
        if !done {
            let logits = model.prefill(&mut cache, prompt);
            tokens.push(sampler.pick(logits.row(logits.rows() - 1)));
            done = tokens.len() - prompt.len() >= max_new || tokens.len() >= max_total;
        }
        DecodeSession {
            cache,
            sampler,
            tokens,
            prompt_len: prompt.len(),
            max_new,
            max_total,
            done,
        }
    }

    /// Advance one decode step; returns the newly generated token, or `None`
    /// once the session has finished. Composed from the two batched-decode
    /// halves below: produce the input token, run the single-session
    /// forward, consume the logits row.
    pub fn step(&mut self, model: &Model) -> Option<u16> {
        let last = self.next_input()?;
        let logits = model.decode_step(&mut self.cache, last);
        Some(self.consume_logits(&logits))
    }

    /// First half of [`DecodeSession::step`]: the token this session feeds
    /// on its next decode step, or `None` once it has finished. The serving
    /// layer collects these across sessions, runs one
    /// [`Model::decode_step_batch`] over the group, then hands each session
    /// its logits row via [`DecodeSession::consume_logits`].
    pub fn next_input(&self) -> Option<u16> {
        if self.done {
            return None;
        }
        // audit:allow(panic): start() asserts a non-empty prompt, and tokens
        // only ever grows from there.
        Some(*self.tokens.last().expect("session holds at least the prompt"))
    }

    /// This session's KV cache, for stepping it through a batched forward.
    pub fn cache_mut(&mut self) -> &mut KvCache {
        &mut self.cache
    }

    /// Second half of [`DecodeSession::step`]: sample from a freshly
    /// computed logits row, record the token, and update the stop state.
    /// Call exactly once after a forward that advanced this session's cache
    /// by the row [`DecodeSession::next_input`] produced.
    pub fn consume_logits(&mut self, logits: &[f32]) -> u16 {
        let next = self.sampler.pick(logits);
        self.tokens.push(next);
        if self.generated_len() >= self.max_new || self.tokens.len() >= self.max_total {
            self.done = true;
        }
        next
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Prompt + generated tokens.
    pub fn tokens(&self) -> &[u16] {
        &self.tokens
    }

    /// Generated continuation only.
    pub fn generated(&self) -> &[u16] {
        &self.tokens[self.prompt_len..]
    }

    pub fn generated_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// Absolute position of the next token (= rows cached so far).
    pub fn position(&self) -> usize {
        self.cache.len()
    }
}

/// Convenience: parse a [`SamplerCfg`] out of a serve-protocol JSON object
/// (`temperature`, `top_k`, `seed`; all optional, defaults are greedy).
pub fn sampler_cfg_from_json(j: &crate::util::json::Json) -> SamplerCfg {
    use crate::util::json::Json;
    SamplerCfg {
        temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        top_k: j.get("top_k").and_then(Json::as_usize).unwrap_or(0),
        seed: j.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparse::{ColumnSparse, QuantColumnSparse};
    use crate::compress::LinearWeight;
    use crate::linalg::QuantMat;
    use crate::model::config::{ModelConfig, ProjKind};

    fn tiny_model(seed: u64) -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed))
    }

    fn assert_same_mat(a: &Mat, b: &Mat, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape");
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert!(
                    (a[(i, j)] - b[(i, j)]).abs() == 0.0,
                    "{what}: ({i},{j}) {} vs {}",
                    a[(i, j)],
                    b[(i, j)]
                );
            }
        }
    }

    /// Swap every projection of every block for a LowRank / Factorized
    /// stand-in (random factors — parity is about execution, not quality).
    fn lowrank_model(seed: u64) -> Model {
        let mut m = tiny_model(seed);
        let mut rng = Rng::new(seed + 100);
        for stage in m.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let w = b.proj(p);
                    let (din, dout) = (w.in_dim(), w.out_dim());
                    let r = din.min(dout) / 2;
                    let std = 0.6 / (din as f32).sqrt();
                    *b.proj_mut(p) = LinearWeight::LowRank {
                        b: Mat::randn(&mut rng, din, r, std),
                        c: Mat::randn(&mut rng, r, dout, std),
                    };
                }
            }
        }
        m
    }

    fn factorized_model(seed: u64) -> Model {
        let mut m = tiny_model(seed);
        let mut rng = Rng::new(seed + 200);
        for stage in m.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let w = b.proj(p);
                    let (din, dout) = (w.in_dim(), w.out_dim());
                    let k = (din / 2).max(1);
                    let s = (k / 2).max(1);
                    let std = 0.6 / (din as f32).sqrt();
                    *b.proj_mut(p) = LinearWeight::Factorized {
                        a: Mat::randn(&mut rng, din, k, std),
                        s: ColumnSparse::hard_threshold(&Mat::randn(&mut rng, k, dout, std), s),
                    };
                }
            }
        }
        m
    }

    /// Every projection swapped for its 4-bit packed form (rtn on whatever
    /// the base model stores) — the packed-native decode acceptance matrix.
    fn quantized(model: &Model) -> Model {
        let mut m = model.clone();
        for stage in m.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let packed = match b.proj(p) {
                        LinearWeight::Dense(w) => {
                            LinearWeight::QuantDense(QuantMat::quantize_from(w, 4))
                        }
                        LinearWeight::LowRank { b: lb, c } => LinearWeight::QuantLowRank {
                            b: QuantMat::quantize_from(lb, 4),
                            c: QuantMat::quantize_from(c, 4),
                        },
                        LinearWeight::Factorized { a, s } => LinearWeight::QuantFactorized {
                            a: QuantMat::quantize_from(a, 4),
                            s: QuantColumnSparse::quantize_from(s, 4),
                        },
                        other => other.clone(),
                    };
                    *b.proj_mut(p) = packed;
                }
            }
        }
        m
    }

    #[test]
    fn prefill_matches_forward_bitwise() {
        for model in [tiny_model(21), lowrank_model(21), factorized_model(21)] {
            let tokens: Vec<u16> = (0..20u16).map(|i| i * 5 % 64).collect();
            let full = model.forward(&tokens);
            let mut cache = model.new_cache();
            let pre = model.prefill(&mut cache, &tokens);
            assert_same_mat(&full, &pre, "prefill logits");
            assert_eq!(cache.len(), tokens.len());
        }
    }

    #[test]
    fn decode_step_matches_full_forward_last_row() {
        for model in [tiny_model(22), lowrank_model(22), factorized_model(22)] {
            let tokens: Vec<u16> = (0..16u16).map(|i| (i * 7 + 3) % 64).collect();
            let mut cache = model.new_cache();
            model.prefill(&mut cache, &tokens[..tokens.len() - 1]);
            let step = model.decode_step(&mut cache, tokens[tokens.len() - 1]);
            let full = model.forward(&tokens);
            let last = full.row(full.rows() - 1);
            assert_eq!(step.len(), last.len());
            for j in 0..last.len() {
                assert!(
                    (step[j] - last[j]).abs() == 0.0,
                    "logit {j}: {} vs {}",
                    step[j],
                    last[j]
                );
            }
            assert_eq!(cache.len(), tokens.len());
        }
    }

    #[test]
    fn cached_greedy_parity_dense_lowrank_factorized() {
        for (name, model) in [
            ("dense", tiny_model(23)),
            ("lowrank", lowrank_model(24)),
            ("factorized", factorized_model(25)),
        ] {
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
            let cached = model.greedy_decode(&prompt, 12);
            let full = model.greedy_decode_full(&prompt, 12);
            assert_eq!(cached, full, "{name}: cached vs full-forward continuation");
            assert_eq!(cached.len(), 12);
        }
    }

    #[test]
    fn cached_greedy_parity_quantized_variants() {
        // Packed-native decode: for every quantized LinearWeight variant the
        // KV-cached greedy continuation must equal both the full forward and
        // the fake-quant f32 reference model, token for token.
        for (name, model) in [
            ("quant-dense", quantized(&tiny_model(33))),
            ("quant-lowrank", quantized(&lowrank_model(34))),
            ("quant-factorized", quantized(&factorized_model(35))),
        ] {
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
            let cached = model.greedy_decode(&prompt, 12);
            let full = model.greedy_decode_full(&prompt, 12);
            assert_eq!(cached, full, "{name}: cached vs full-forward continuation");
            let reference = model.dequantize_projections();
            assert_eq!(
                cached,
                reference.greedy_decode(&prompt, 12),
                "{name}: packed decode vs fake-quant f32 reference"
            );
            assert_eq!(cached.len(), 12);
            // packing must actually shrink the resident weights
            assert!(model.resident_weight_bytes() < reference.resident_weight_bytes());
        }
    }

    #[test]
    fn quantized_decode_step_matches_full_forward_bitwise() {
        // The fused per-row dequant kernel vs the fused batched panels: one
        // decode step must reproduce the batched forward's last logits row
        // exactly for every packed variant.
        for (name, model) in [
            ("quant-dense", quantized(&tiny_model(36))),
            ("quant-lowrank", quantized(&lowrank_model(37))),
            ("quant-factorized", quantized(&factorized_model(38))),
        ] {
            let tokens: Vec<u16> = (0..16u16).map(|i| (i * 7 + 3) % 64).collect();
            let mut cache = model.new_cache();
            model.prefill(&mut cache, &tokens[..tokens.len() - 1]);
            let step = model.decode_step(&mut cache, tokens[tokens.len() - 1]);
            let full = model.forward(&tokens);
            let last = full.row(full.rows() - 1);
            for j in 0..last.len() {
                assert!(
                    (step[j] - last[j]).abs() == 0.0,
                    "{name} logit {j}: {} vs {}",
                    step[j],
                    last[j]
                );
            }
        }
    }

    #[test]
    fn truncate_rolls_back_and_redecodes_bit_identically() {
        // The speculative-rollback primitive: decode T tokens, snapshot the
        // logits, truncate back, re-decode the same tokens — every logits
        // row must reproduce bitwise, for every stored-variant model.
        for (name, model) in [
            ("dense", tiny_model(61)),
            ("lowrank", lowrank_model(61)),
            ("factorized", factorized_model(61)),
            ("quant-dense", quantized(&tiny_model(61))),
        ] {
            let mut cache = model.new_cache();
            model.prefill(&mut cache, &[3, 1, 4, 1]);
            let keep = cache.len();
            let extra: [u16; 3] = [5, 9, 2];
            let first: Vec<Vec<f32>> =
                extra.iter().map(|&t| model.decode_step(&mut cache, t)).collect();
            assert_eq!(cache.len(), keep + extra.len());
            cache.truncate(keep);
            assert_eq!(cache.len(), keep);
            for (i, &t) in extra.iter().enumerate() {
                let again = model.decode_step(&mut cache, t);
                assert_eq!(again.len(), first[i].len(), "{name}");
                for j in 0..again.len() {
                    assert!(
                        (again[j] - first[i][j]).abs() == 0.0,
                        "{name}: step {i} logit {j} changed after rollback: {} vs {}",
                        again[j],
                        first[i][j]
                    );
                }
            }
            // truncate to 0 and re-prefill is also exact
            cache.truncate(0);
            let mut fresh = model.new_cache();
            let a = model.prefill(&mut cache, &[3, 1, 4, 1]);
            let b = model.prefill(&mut fresh, &[3, 1, 4, 1]);
            assert_same_mat(&a, &b, name);
        }
    }

    #[test]
    #[should_panic(expected = "cannot extend")]
    fn truncate_cannot_extend_the_cache() {
        let model = tiny_model(62);
        let mut cache = model.new_cache();
        model.prefill(&mut cache, &[1, 2]);
        cache.truncate(5);
    }

    #[test]
    fn multi_row_step_matches_sequential_steps_bitwise() {
        // The speculative verify kernel: one decode_step_multi over k tokens
        // must reproduce the k sequential decode_step logits rows bitwise —
        // for dense, low-rank, factorized, and all packed-quantized
        // variants, i.e. every `LinearWeight` (GEMM dispatch vs apply_row).
        for (name, model) in [
            ("dense", tiny_model(63)),
            ("lowrank", lowrank_model(63)),
            ("factorized", factorized_model(63)),
            ("quant-dense", quantized(&tiny_model(63))),
            ("quant-lowrank", quantized(&lowrank_model(63))),
            ("quant-factorized", quantized(&factorized_model(63))),
        ] {
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
            let batch: Vec<u16> = vec![9, 2, 6, 5];
            let mut seq_cache = model.new_cache();
            model.prefill(&mut seq_cache, &prompt);
            let seq_rows: Vec<Vec<f32>> =
                batch.iter().map(|&t| model.decode_step(&mut seq_cache, t)).collect();
            let mut multi_cache = model.new_cache();
            model.prefill(&mut multi_cache, &prompt);
            let multi = model.decode_step_multi(&mut multi_cache, &batch);
            assert_eq!(multi.shape(), (batch.len(), model.cfg.vocab), "{name}");
            assert_eq!(multi_cache.len(), seq_cache.len(), "{name}");
            for (t, row) in seq_rows.iter().enumerate() {
                for j in 0..row.len() {
                    assert!(
                        (multi[(t, j)] - row[j]).abs() == 0.0,
                        "{name}: row {t} logit {j}: {} vs {}",
                        multi[(t, j)],
                        row[j]
                    );
                }
            }
            // ...and the caches themselves are interchangeable afterwards
            let a = model.decode_step(&mut seq_cache, 7);
            let b = model.decode_step(&mut multi_cache, 7);
            for j in 0..a.len() {
                assert!((a[j] - b[j]).abs() == 0.0, "{name}: post-step logit {j}");
            }
        }
    }

    #[test]
    fn multi_row_step_single_token_equals_decode_step() {
        let model = quantized(&lowrank_model(64));
        let mut a = model.new_cache();
        let mut b = model.new_cache();
        model.prefill(&mut a, &[1, 2, 3]);
        model.prefill(&mut b, &[1, 2, 3]);
        let row = model.decode_step(&mut a, 9);
        let one = model.decode_step_multi(&mut b, &[9]);
        assert_eq!(one.shape(), (1, row.len()));
        for j in 0..row.len() {
            assert!((one[(0, j)] - row[j]).abs() == 0.0, "logit {j}");
        }
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn batched_step_matches_individual_steps_bitwise() {
        // The cross-session batched kernel: one decode_step_batch over B
        // sessions (each cache prefilled to a *different* length, so rows
        // sit at heterogeneous positions) must reproduce each session's
        // solo decode_step logits bitwise — for every `LinearWeight`
        // variant (GEMM dispatch vs apply_row), at batch sizes 1, 2, 8.
        for (name, model) in [
            ("dense", tiny_model(71)),
            ("lowrank", lowrank_model(71)),
            ("factorized", factorized_model(71)),
            ("quant-dense", quantized(&tiny_model(71))),
            ("quant-lowrank", quantized(&lowrank_model(71))),
            ("quant-factorized", quantized(&factorized_model(71))),
        ] {
            for bsize in [1usize, 2, 8] {
                let prompts: Vec<Vec<u16>> = (0..bsize)
                    .map(|i| {
                        (0..3 + (i * 7) % 5).map(|t| ((t * 11 + i * 13) % 64) as u16).collect()
                    })
                    .collect();
                let toks: Vec<u16> = (0..bsize).map(|i| ((i * 17 + 5) % 64) as u16).collect();
                let prefilled = |p: &[u16]| {
                    let mut c = model.new_cache();
                    model.prefill(&mut c, p);
                    c
                };
                // sequential twin: each session steps alone
                let mut seq: Vec<KvCache> = prompts.iter().map(|p| prefilled(p)).collect();
                let seq_rows: Vec<Vec<f32>> = seq
                    .iter_mut()
                    .zip(toks.iter())
                    .map(|(c, &t)| model.decode_step(c, t))
                    .collect();
                // batched: one forward for the whole group
                let mut bat: Vec<KvCache> = prompts.iter().map(|p| prefilled(p)).collect();
                let mut refs: Vec<&mut KvCache> = bat.iter_mut().collect();
                let logits = model.decode_step_batch(&mut refs, &toks);
                drop(refs);
                assert_eq!(logits.shape(), (bsize, model.cfg.vocab), "{name}/b{bsize}");
                for (b, row) in seq_rows.iter().enumerate() {
                    for j in 0..row.len() {
                        assert!(
                            (logits[(b, j)] - row[j]).abs() == 0.0,
                            "{name}/b{bsize}: row {b} logit {j}: {} vs {}",
                            logits[(b, j)],
                            row[j]
                        );
                    }
                }
                // ...and the caches themselves are interchangeable afterwards
                for (b, (sc, bc)) in seq.iter_mut().zip(bat.iter_mut()).enumerate() {
                    assert_eq!(sc.len(), bc.len(), "{name}/b{bsize}: row {b} position");
                    let a = model.decode_step(sc, 7);
                    let z = model.decode_step(bc, 7);
                    for j in 0..a.len() {
                        assert!(
                            (a[j] - z[j]).abs() == 0.0,
                            "{name}/b{bsize}: post-step row {b} logit {j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "empty batch")]
    fn batched_step_rejects_empty_batch() {
        let model = tiny_model(72);
        let mut refs: Vec<&mut KvCache> = Vec::new();
        model.decode_step_batch(&mut refs, &[]);
    }

    #[test]
    #[should_panic(expected = "full")]
    fn batched_step_rejects_full_cache() {
        let model = tiny_model(73);
        let mut a = model.new_cache_with(8);
        let mut b = model.new_cache_with(4);
        model.prefill(&mut a, &[1, 2, 3]);
        model.prefill(&mut b, &[1, 2, 3, 4]); // b is at capacity
        let mut refs = vec![&mut a, &mut b];
        model.decode_step_batch(&mut refs, &[5, 6]);
    }

    #[test]
    #[should_panic(expected = "caches for")]
    fn batched_step_rejects_mismatched_lengths() {
        let model = tiny_model(74);
        let mut a = model.new_cache();
        model.prefill(&mut a, &[1, 2]);
        let mut refs = vec![&mut a];
        model.decode_step_batch(&mut refs, &[5, 6]);
    }

    /// Split a model at stage boundary `k` the way a 2-stage pipeline
    /// does: the head keeps the embedding and stages `..k`, the tail keeps
    /// stages `k..` plus the final norm and LM head — the same partial
    /// shapes [`Model::load_stage_range`] builds from a sharded checkpoint.
    fn split_at(model: &Model, k: usize) -> (Model, Model) {
        let d = model.cfg.d_model;
        let head = Model {
            cfg: model.cfg.clone(),
            embed: model.embed.clone(),
            stages: model.stages[..k].to_vec(),
            final_norm: Vec::new(),
            lm_head: Mat::zeros(0, 0),
        };
        let tail = Model {
            cfg: model.cfg.clone(),
            embed: Mat::zeros(0, d),
            stages: model.stages[k..].to_vec(),
            final_norm: model.final_norm.clone(),
            lm_head: model.lm_head.clone(),
        };
        (head, tail)
    }

    #[test]
    fn hidden_split_matches_prefill_and_decode_step_bitwise() {
        // The pipeline-parity spine: chaining the head stage's hidden rows
        // into the tail stage must reproduce prefill and every sequential
        // decode step of the unsplit model bitwise — for all six
        // `LinearWeight` variants.
        for (name, model) in [
            ("dense", tiny_model(81)),
            ("lowrank", lowrank_model(81)),
            ("factorized", factorized_model(81)),
            ("quant-dense", quantized(&tiny_model(81))),
            ("quant-lowrank", quantized(&lowrank_model(81))),
            ("quant-factorized", quantized(&factorized_model(81))),
        ] {
            let (head, tail) = split_at(&model, 1);
            let prompt: Vec<u16> = vec![3, 1, 4, 1, 5];
            let mut full_cache = model.new_cache();
            let full_logits = model.prefill(&mut full_cache, &prompt);
            let full_last = full_logits.row(full_logits.rows() - 1);
            let mut hc = head.new_cache();
            let mut tc = tail.new_cache();
            let h = head.forward_hidden_cached(&mut hc, head.embed_tokens(&prompt));
            assert_eq!(h.shape(), (prompt.len(), model.cfg.d_model), "{name}");
            let th = tail.forward_hidden_cached(&mut tc, h);
            let last = tail.logits_from_hidden_row(th.row(th.rows() - 1));
            assert_eq!(last.len(), full_last.len(), "{name}");
            for j in 0..last.len() {
                assert!(
                    (last[j] - full_last[j]).abs() == 0.0,
                    "{name}: prefill logit {j}: {} vs {}",
                    last[j],
                    full_last[j]
                );
            }
            for &t in &[9u16, 2, 6] {
                let full_row = model.decode_step(&mut full_cache, t);
                let x: Vec<f32> = head.embed.row(t as usize).to_vec();
                let h = head.decode_hidden_row(&mut hc, &x);
                let h2 = tail.decode_hidden_row(&mut tc, &h);
                let row = tail.logits_from_hidden_row(&h2);
                for j in 0..row.len() {
                    assert!(
                        (row[j] - full_row[j]).abs() == 0.0,
                        "{name}: token {t} logit {j}: {} vs {}",
                        row[j],
                        full_row[j]
                    );
                }
            }
            assert_eq!(hc.len(), full_cache.len(), "{name}: head position");
            assert_eq!(tc.len(), full_cache.len(), "{name}: tail position");
        }
    }

    #[test]
    fn hidden_batch_matches_batched_step_bitwise() {
        // Pipeline × batching: one decode_hidden_batch per stage must
        // reproduce the single-host decode_step_batch logits bitwise for
        // heterogeneous cache positions, at batch sizes 1 and 3.
        for (name, model) in [
            ("dense", tiny_model(82)),
            ("factorized", factorized_model(82)),
            ("quant-lowrank", quantized(&lowrank_model(82))),
        ] {
            let (head, tail) = split_at(&model, 1);
            for bsize in [1usize, 3] {
                let prompts: Vec<Vec<u16>> = (0..bsize)
                    .map(|i| {
                        (0..3 + (i * 5) % 4).map(|t| ((t * 9 + i * 13) % 64) as u16).collect()
                    })
                    .collect();
                let toks: Vec<u16> = (0..bsize).map(|i| ((i * 17 + 5) % 64) as u16).collect();
                // single-host twin
                let mut full: Vec<KvCache> = prompts
                    .iter()
                    .map(|p| {
                        let mut c = model.new_cache();
                        model.prefill(&mut c, p);
                        c
                    })
                    .collect();
                let mut refs: Vec<&mut KvCache> = full.iter_mut().collect();
                let logits = model.decode_step_batch(&mut refs, &toks);
                drop(refs);
                // pipeline: prefill both stage caches, then one hidden
                // round per stage and the tail logits helper per row
                let mut hcs: Vec<KvCache> = Vec::new();
                let mut tcs: Vec<KvCache> = Vec::new();
                for p in &prompts {
                    let mut hc = head.new_cache();
                    let mut tc = tail.new_cache();
                    let h = head.forward_hidden_cached(&mut hc, head.embed_tokens(p));
                    tail.forward_hidden_cached(&mut tc, h);
                    hcs.push(hc);
                    tcs.push(tc);
                }
                let mut hrefs: Vec<&mut KvCache> = hcs.iter_mut().collect();
                let h = head.decode_hidden_batch(&mut hrefs, head.embed_tokens(&toks));
                drop(hrefs);
                let mut trefs: Vec<&mut KvCache> = tcs.iter_mut().collect();
                let th = tail.decode_hidden_batch(&mut trefs, h);
                drop(trefs);
                for b in 0..bsize {
                    let row = tail.logits_from_hidden_row(th.row(b));
                    for j in 0..row.len() {
                        assert!(
                            (row[j] - logits[(b, j)]).abs() == 0.0,
                            "{name}/b{bsize}: row {b} logit {j}: {} vs {}",
                            row[j],
                            logits[(b, j)]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn session_split_step_halves_compose_to_step() {
        // next_input / consume_logits must drive a session to exactly the
        // tokens step() produces, including the done transition.
        let model = tiny_model(75);
        let prompt: Vec<u16> = vec![4, 2, 7];
        let mut whole = DecodeSession::start(&model, &prompt, 6, SamplerCfg::greedy());
        let mut split = DecodeSession::start(&model, &prompt, 6, SamplerCfg::greedy());
        loop {
            let a = whole.step(&model);
            let b = match split.next_input() {
                None => None,
                Some(last) => {
                    let logits = model.decode_step(split.cache_mut(), last);
                    Some(split.consume_logits(&logits))
                }
            };
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(whole.tokens(), split.tokens());
        assert!(split.is_done() && split.next_input().is_none());
    }

    #[test]
    fn sampler_top_k_zero_means_no_filtering() {
        // top_k = 0 must keep the full vocabulary (not truncate the
        // candidate order to empty): at a high temperature over near-flat
        // logits, sampled tokens land outside any small top set, and no
        // draw panics.
        let mut logits = vec![0.0f32; 16];
        logits[3] = 0.05; // a slight favorite, far from dominating at T=50
        let mut s = Sampler::new(SamplerCfg { temperature: 50.0, top_k: 0, seed: 9 });
        let picks: Vec<u16> = (0..400).map(|_| s.pick(&logits)).collect();
        assert!(picks.iter().all(|&t| (t as usize) < logits.len()));
        let distinct: std::collections::BTreeSet<u16> = picks.iter().copied().collect();
        assert!(
            distinct.len() > 8,
            "top_k=0 at high temperature must sample broadly, saw {distinct:?}"
        );
    }

    #[test]
    fn session_stops_at_max_seq_like_full_path() {
        let model = tiny_model(26);
        let prompt: Vec<u16> = (0..60u16).collect(); // max_seq = 64
        let cached = model.greedy_decode(&prompt, 50);
        let full = model.greedy_decode_full(&prompt, 50);
        assert_eq!(cached, full);
        assert_eq!(cached.len(), 4); // stops when total reaches max_seq
    }

    #[test]
    fn generate_edge_cases() {
        let model = tiny_model(27);
        assert!(model.generate(&[], 5, SamplerCfg::greedy()).is_empty());
        assert!(model.generate(&[1, 2], 0, SamplerCfg::greedy()).is_empty());
    }

    #[test]
    fn interleaved_sessions_match_isolated_generation() {
        // Continuous batching steps sessions round-robin; interleaving must
        // not change any session's continuation.
        let model = tiny_model(28);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[9, 8], &[40, 41, 42, 43]];
        let isolated: Vec<Vec<u16>> =
            prompts.iter().map(|p| model.greedy_decode(p, 8)).collect();
        let mut sessions: Vec<DecodeSession> = prompts
            .iter()
            .map(|p| DecodeSession::start(&model, p, 8, SamplerCfg::greedy()))
            .collect();
        while sessions.iter().any(|s| !s.is_done()) {
            for s in sessions.iter_mut() {
                s.step(&model);
            }
        }
        for (s, iso) in sessions.iter().zip(isolated.iter()) {
            assert_eq!(s.generated(), &iso[..]);
        }
    }

    #[test]
    fn sampled_decode_is_seed_deterministic() {
        let model = tiny_model(29);
        let cfg = SamplerCfg { temperature: 0.8, top_k: 8, seed: 42 };
        let a = model.generate(&[5, 6, 7], 10, cfg);
        let b = model.generate(&[5, 6, 7], 10, cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        assert!(a.iter().all(|&t| (t as usize) < model.cfg.vocab));
        // a different seed is allowed to (and here does) diverge eventually
        let c = model.generate(&[5, 6, 7], 10, SamplerCfg { seed: 43, ..cfg });
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn sampler_top_k_restricts_support() {
        let logits = vec![0.0f32, 5.0, 4.0, -1.0, 4.5, 0.5];
        let mut s = Sampler::new(SamplerCfg { temperature: 1.0, top_k: 3, seed: 7 });
        for _ in 0..200 {
            let t = s.pick(&logits) as usize;
            assert!([1usize, 2, 4].contains(&t), "sampled {t} outside top-3");
        }
        // top_k = 1 degenerates to argmax
        let mut s1 = Sampler::new(SamplerCfg { temperature: 1.0, top_k: 1, seed: 7 });
        for _ in 0..20 {
            assert_eq!(s1.pick(&logits), 1);
        }
        // greedy config ignores the rng entirely
        let mut g = Sampler::new(SamplerCfg::greedy());
        assert_eq!(g.pick(&logits), argmax(&logits));
    }

    #[test]
    fn suffix_prefill_continues_a_session() {
        // Prefill in two chunks ≡ prefill in one (bit-identical last row).
        let model = tiny_model(30);
        let tokens: Vec<u16> = (0..14u16).map(|i| (i * 11) % 64).collect();
        let mut one = model.new_cache();
        let all = model.prefill(&mut one, &tokens);
        let mut two = model.new_cache();
        model.prefill(&mut two, &tokens[..6]);
        let rest = model.prefill(&mut two, &tokens[6..]);
        assert_eq!(two.len(), tokens.len());
        let last_one = all.row(all.rows() - 1);
        let last_two = rest.row(rest.rows() - 1);
        for j in 0..last_one.len() {
            assert!((last_one[j] - last_two[j]).abs() == 0.0, "col {j}");
        }
    }

    #[test]
    fn cache_accounts_linear_stages() {
        let mut model = tiny_model(31);
        let d = model.cfg.d_model;
        model.stages[1] = Stage::Linear(Mat::eye(d).scale(0.5));
        let prompt: Vec<u16> = vec![1, 2, 3, 4];
        let cached = model.greedy_decode(&prompt, 6);
        let full = model.greedy_decode_full(&prompt, 6);
        assert_eq!(cached, full);
    }
}
