//! Shard manifest for sharded CPT2 checkpoints.
//!
//! A sharded checkpoint is an **index** file (a CPT2 container with an
//! empty data region) plus N **shard** files, each a complete CPT2
//! container holding the sections for a contiguous stage range. The index
//! header carries a `"shards"` array:
//!
//! ```text
//! {"shards": [{"id": 0, "path": "m.shard0.cpt2", "lo": 0, "hi": 12,
//!              "crc": <crc32 of the shard file's header JSON bytes>}, ...],
//!  "stages": [... metadata for ALL stages ...], "sections": []}
//! ```
//!
//! Shard `0` additionally carries the `embed` section; the last shard
//! carries `lm_head` and `final_norm`. Paths are relative to the index
//! file's directory. The recorded `crc` covers only the shard's *header*
//! bytes and is verified when the shard is opened at **load** time — the
//! index-only open behind `compot info` never touches a shard file, let
//! alone a shard payload (section payloads keep their own lazy per-section
//! CRCs inside each shard).
//!
//! This module owns the manifest shape and its validation (contiguous,
//! gap-free, overlap-free coverage of `0..n_stages`); the section I/O that
//! writes and reads the containers lives in [`super::cpt2`].

use crate::util::json::Json;

/// One shard record from the index header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    pub id: usize,
    /// Path relative to the index file's directory.
    pub path: String,
    /// Stage range `lo..hi` (absolute stage indices, half-open).
    pub lo: usize,
    pub hi: usize,
    /// CRC32 of the shard file's header JSON bytes.
    pub crc: u32,
}

impl ShardEntry {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id.into())
            .set("path", self.path.as_str().into())
            .set("lo", self.lo.into())
            .set("hi", self.hi.into())
            .set("crc", (self.crc as usize).into());
        j
    }

    fn from_json(j: &Json) -> anyhow::Result<ShardEntry> {
        let id = j
            .get("id")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("shard record without an id"))?;
        let field = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("shard {id}: missing field '{k}'"))
        };
        let path = j
            .get("path")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("shard {id}: missing relative path"))?;
        anyhow::ensure!(!path.is_empty(), "shard {id}: empty path");
        // Shard paths resolve against the index directory; an absolute path
        // or a parent-escaping one in an untrusted header must not make the
        // loader read outside that directory.
        anyhow::ensure!(
            !path.starts_with('/') && !path.split('/').any(|c| c == ".."),
            "shard {id}: path '{path}' must be relative to the index directory"
        );
        Ok(ShardEntry {
            id,
            path: path.to_string(),
            lo: field("lo")?,
            hi: field("hi")?,
            crc: field("crc")? as u32,
        })
    }
}

/// The validated shard table of one index header: entries in id order,
/// covering `0..n_stages` contiguously with no gaps and no overlaps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    pub entries: Vec<ShardEntry>,
}

impl ShardManifest {
    /// Parse the `"shards"` array of an index header, if present.
    /// `n_stages` is the length of the same header's `stages` array — the
    /// coverage target the ranges are validated against.
    pub fn from_header(header: &Json, n_stages: usize) -> anyhow::Result<Option<ShardManifest>> {
        match header.get("shards").and_then(Json::as_arr) {
            None => Ok(None),
            Some(arr) => Self::parse(arr, n_stages).map(Some),
        }
    }

    /// Validate a raw manifest array: ids must be `0..len` in order, every
    /// range non-empty, and the ranges must tile `0..n_stages` exactly —
    /// a gap or an overlap is a structured error naming the shard.
    pub fn parse(arr: &[Json], n_stages: usize) -> anyhow::Result<ShardManifest> {
        anyhow::ensure!(!arr.is_empty(), "shard manifest is empty");
        let mut entries = Vec::with_capacity(arr.len());
        for rec in arr {
            entries.push(ShardEntry::from_json(rec)?);
        }
        let mut expect_lo = 0usize;
        for (i, e) in entries.iter().enumerate() {
            anyhow::ensure!(
                e.id == i,
                "shard manifest out of order: entry {i} has id {}",
                e.id
            );
            anyhow::ensure!(e.lo < e.hi, "shard {i}: empty stage range {}..{}", e.lo, e.hi);
            anyhow::ensure!(
                e.lo == expect_lo,
                "shard manifest does not tile the stages: shard {i} covers {}..{} but \
                 coverage so far ends at {expect_lo} ({})",
                e.lo,
                e.hi,
                if e.lo > expect_lo { "gap" } else { "overlap" }
            );
            expect_lo = e.hi;
        }
        anyhow::ensure!(
            expect_lo == n_stages,
            "shard manifest covers stages 0..{expect_lo} but the checkpoint has {n_stages}"
        );
        Ok(ShardManifest { entries })
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.entries.iter().map(ShardEntry::to_json).collect())
    }

    /// Total stage count the manifest covers.
    pub fn n_stages(&self) -> usize {
        self.entries.last().map(|e| e.hi).unwrap_or(0)
    }

    /// The shards whose stage range intersects `lo..hi`, in id order.
    pub fn entries_for(&self, lo: usize, hi: usize) -> Vec<&ShardEntry> {
        self.entries.iter().filter(|e| e.lo < hi && lo < e.hi).collect()
    }

    /// One line per shard — what `compot info` prints for a sharded index.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&format!(
                "shard {:>3} stages {:>3}..{:<3} {} (header crc {:#010x})\n",
                e.id, e.lo, e.hi, e.path, e.crc
            ));
        }
        out
    }
}

/// Split `n_stages` stages into `n_shards` contiguous ranges of (near-)
/// equal size: `ceil(n/k)` stages per shard, the last one possibly
/// shorter. `n_shards` must be in `1..=n_stages` — more shards than stages
/// would mean empty shard files.
pub fn split_ranges(n_stages: usize, n_shards: usize) -> anyhow::Result<Vec<(usize, usize)>> {
    anyhow::ensure!(n_shards >= 1, "cannot split a checkpoint into 0 shards");
    anyhow::ensure!(
        n_shards <= n_stages,
        "cannot split {n_stages} stages into {n_shards} shards (at most one shard per stage)"
    );
    let chunk = n_stages.div_ceil(n_shards);
    let mut ranges = Vec::with_capacity(n_shards);
    let mut lo = 0usize;
    while lo < n_stages {
        let hi = (lo + chunk).min(n_stages);
        ranges.push((lo, hi));
        lo = hi;
    }
    Ok(ranges)
}

/// Shard file name derived from the index file name:
/// `model.cpt2` → `model.shard3.cpt2`.
pub fn shard_file_name(index_file_name: &str, id: usize) -> String {
    match index_file_name.strip_suffix(".cpt2") {
        Some(stem) => format!("{stem}.shard{id}.cpt2"),
        None => format!("{index_file_name}.shard{id}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: usize, lo: usize, hi: usize) -> Json {
        ShardEntry { id, path: format!("m.shard{id}.cpt2"), lo, hi, crc: 7 }.to_json()
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let arr = vec![entry(0, 0, 3), entry(1, 3, 5)];
        let m = ShardManifest::parse(&arr, 5).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.n_stages(), 5);
        assert_eq!(m.entries[1].path, "m.shard1.cpt2");
        let back = ShardManifest::parse(m.to_json().as_arr().unwrap(), 5).unwrap();
        assert_eq!(m, back);
        assert!(m.summary().contains("m.shard0.cpt2"));
    }

    #[test]
    fn gaps_overlaps_and_bad_ids_are_structured_errors() {
        // gap between shard 0 and 1
        let err = ShardManifest::parse(&[entry(0, 0, 2), entry(1, 3, 5)], 5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("gap"), "{err}");
        // overlap
        let err = ShardManifest::parse(&[entry(0, 0, 3), entry(1, 2, 5)], 5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("overlap"), "{err}");
        // short coverage
        let err = ShardManifest::parse(&[entry(0, 0, 4)], 5).unwrap_err().to_string();
        assert!(err.contains("0..4"), "{err}");
        // out-of-order ids
        let err = ShardManifest::parse(&[entry(1, 0, 2), entry(0, 2, 5)], 5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of order"), "{err}");
        // empty range
        let err =
            ShardManifest::parse(&[entry(0, 0, 0)], 0).unwrap_err().to_string();
        assert!(err.contains("empty stage range"), "{err}");
        // empty manifest
        assert!(ShardManifest::parse(&[], 0).is_err());
    }

    #[test]
    fn escaping_paths_are_rejected() {
        let mut j = entry(0, 0, 2);
        j.set("path", "/etc/passwd".into());
        let err = ShardManifest::parse(&[j], 2).unwrap_err().to_string();
        assert!(err.contains("relative"), "{err}");
        let mut j = entry(0, 0, 2);
        j.set("path", "../outside.cpt2".into());
        let err = ShardManifest::parse(&[j], 2).unwrap_err().to_string();
        assert!(err.contains("relative"), "{err}");
    }

    #[test]
    fn entries_for_selects_intersecting_shards() {
        let arr = vec![entry(0, 0, 2), entry(1, 2, 4), entry(2, 4, 6)];
        let m = ShardManifest::parse(&arr, 6).unwrap();
        let ids = |lo, hi| -> Vec<usize> {
            m.entries_for(lo, hi).iter().map(|e| e.id).collect()
        };
        assert_eq!(ids(0, 6), vec![0, 1, 2]);
        assert_eq!(ids(0, 2), vec![0]);
        assert_eq!(ids(1, 3), vec![0, 1]);
        assert_eq!(ids(4, 6), vec![2]);
        assert_eq!(ids(3, 3), Vec::<usize>::new());
    }

    #[test]
    fn split_ranges_tiles_exactly() {
        assert_eq!(split_ranges(4, 2).unwrap(), vec![(0, 2), (2, 4)]);
        assert_eq!(split_ranges(5, 2).unwrap(), vec![(0, 3), (3, 5)]);
        assert_eq!(split_ranges(2, 2).unwrap(), vec![(0, 1), (1, 2)]);
        assert_eq!(split_ranges(7, 3).unwrap(), vec![(0, 3), (3, 6), (6, 7)]);
        assert!(split_ranges(4, 0).is_err());
        assert!(split_ranges(2, 3).is_err());
        // every split parses back as a valid manifest
        for (n, k) in [(4, 2), (5, 2), (7, 3), (12, 5)] {
            let arr: Vec<Json> = split_ranges(n, k)
                .unwrap()
                .iter()
                .enumerate()
                .map(|(id, &(lo, hi))| entry(id, lo, hi))
                .collect();
            ShardManifest::parse(&arr, n).unwrap();
        }
    }

    #[test]
    fn shard_file_names_derive_from_the_index() {
        assert_eq!(shard_file_name("model.cpt2", 0), "model.shard0.cpt2");
        assert_eq!(shard_file_name("m-t7.cpt2", 12), "m-t7.shard12.cpt2");
        assert_eq!(shard_file_name("weird.bin", 1), "weird.bin.shard1");
    }
}
