//! Encoder–decoder transformer (Whisper-like) and prefix-VLM — the
//! substrates for the audio (Table 9/17) and vision-language (Table 8/16)
//! transfer experiments.
//!
//! The encoder ingests continuous frames through an input projection and
//! runs bidirectional blocks; the decoder adds cross-attention between
//! self-attention and the MLP. Only *decoder* projections are compressed,
//! matching the paper's Whisper protocol. The VLM variant is a prefix-LM:
//! projected patches are prepended to the token embedding sequence of a
//! plain decoder-only [`Model`].

use super::config::{ModelConfig, ProjKind};
use super::transformer::{apply_rope, attention_head, head_slice, rmsnorm, Block, Capture, Model};
use super::weights::TensorFile;
use crate::compress::LinearWeight;
use crate::linalg::{gemm, Mat};
use crate::util::Rng;

/// Decoder block with cross-attention.
#[derive(Clone, Debug)]
pub struct CrossBlock {
    /// Self-attention + MLP weights (the [`Block`] layout).
    pub base: Block,
    pub cross_norm: Vec<f32>,
    pub cq: LinearWeight,
    pub ck: LinearWeight,
    pub cv: LinearWeight,
    pub co: LinearWeight,
}

#[derive(Clone, Debug)]
pub struct EncDecModel {
    pub cfg: ModelConfig,
    /// d_input × d projection of the continuous input frames.
    pub input_proj: Mat,
    pub enc_blocks: Vec<Block>,
    pub enc_norm: Vec<f32>,
    pub embed: Mat,
    pub dec_blocks: Vec<CrossBlock>,
    pub final_norm: Vec<f32>,
    pub lm_head: Mat,
    /// vocab × d_input synthetic codebook: the frame emission model shared
    /// with the build-time generator (see DESIGN.md §3 — stored in the
    /// weight file so training and evaluation share the distribution).
    pub codebook: Mat,
}

impl CrossBlock {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> CrossBlock {
        let d = cfg.d_model;
        let std = 0.6 / (d as f32).sqrt();
        let kv = cfg.n_kv_heads * cfg.head_dim();
        CrossBlock {
            base: Block::random(cfg, rng),
            cross_norm: vec![1.0; d],
            cq: LinearWeight::Dense(Mat::randn(rng, d, d, std)),
            ck: LinearWeight::Dense(Mat::randn(rng, d, kv, std)),
            cv: LinearWeight::Dense(Mat::randn(rng, d, kv, std)),
            co: LinearWeight::Dense(Mat::randn(rng, d, d, std)),
        }
    }

    /// Forward: causal self-attention, cross-attention over `enc`, MLP.
    pub fn forward(
        &self,
        x: &Mat,
        enc: &Mat,
        head_dim: usize,
        theta: f32,
        layer: usize,
        mut capture: Option<&mut Capture>,
    ) -> Mat {
        // Self-attention + first residual (reuse Block's attention path by
        // building a temporary block with identity MLP is messier than just
        // inlining — Block::forward fuses attn+mlp, so we do the three
        // sublayers explicitly here).
        let b = &self.base;
        let xn = rmsnorm(x, &b.attn_norm);
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::Q, &xn);
            c.record(layer, ProjKind::K, &xn);
            c.record(layer, ProjKind::V, &xn);
        }
        let mut q = b.q.apply(&xn);
        let mut k = b.k.apply(&xn);
        let v = b.v.apply(&xn);
        apply_rope(&mut q, head_dim, theta, 0);
        apply_rope(&mut k, head_dim, theta, 0);
        let q_per_kv = b.n_heads / b.n_kv_heads;
        let mut concat = Mat::zeros(x.rows(), b.n_heads * head_dim);
        for h in 0..b.n_heads {
            let oh = attention_head(
                &head_slice(&q, h, head_dim),
                &head_slice(&k, h / q_per_kv, head_dim),
                &head_slice(&v, h / q_per_kv, head_dim),
                true,
            );
            for t in 0..x.rows() {
                concat.row_mut(t)[h * head_dim..(h + 1) * head_dim].copy_from_slice(oh.row(t));
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::O, &concat);
        }
        let x = x.add(&b.o.apply(&concat));

        // Cross-attention (no RoPE: absolute alignment to encoder states).
        let xn = rmsnorm(&x, &self.cross_norm);
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::CrossQ, &xn);
        }
        let q = self.cq.apply(&xn);
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::CrossK, enc);
            c.record(layer, ProjKind::CrossV, enc);
        }
        let k = self.ck.apply(enc);
        let v = self.cv.apply(enc);
        let mut concat = Mat::zeros(x.rows(), b.n_heads * head_dim);
        for h in 0..b.n_heads {
            let oh = attention_head(
                &head_slice(&q, h, head_dim),
                &head_slice(&k, h / q_per_kv, head_dim),
                &head_slice(&v, h / q_per_kv, head_dim),
                false,
            );
            for t in 0..x.rows() {
                concat.row_mut(t)[h * head_dim..(h + 1) * head_dim].copy_from_slice(oh.row(t));
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::CrossO, &concat);
        }
        let x = x.add(&self.co.apply(&concat));

        // MLP.
        let xn = rmsnorm(&x, &b.mlp_norm);
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::Gate, &xn);
            c.record(layer, ProjKind::Up, &xn);
        }
        let g = b.gate.apply(&xn);
        let u = b.up.apply(&xn);
        let mut hmat = g;
        for i in 0..hmat.rows() {
            let hrow = hmat.row_mut(i);
            for (hv, uv) in hrow.iter_mut().zip(u.row(i).iter()) {
                *hv = (*hv / (1.0 + (-*hv).exp())) * uv;
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.record(layer, ProjKind::Down, &hmat);
        }
        x.add(&b.down.apply(&hmat))
    }
}

impl EncDecModel {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> EncDecModel {
        let enc = cfg.encoder.clone().expect("encdec config must have encoder");
        let d = cfg.d_model;
        let std = 0.6 / (d as f32).sqrt();
        EncDecModel {
            input_proj: Mat::randn(rng, enc.d_input, d, 1.0 / (enc.d_input as f32).sqrt()),
            enc_blocks: (0..enc.n_layers).map(|_| Block::random(cfg, rng)).collect(),
            enc_norm: vec![1.0; d],
            embed: Mat::randn(rng, cfg.vocab, d, 1.0),
            dec_blocks: (0..cfg.n_layers).map(|_| CrossBlock::random(cfg, rng)).collect(),
            final_norm: vec![1.0; d],
            lm_head: Mat::randn(rng, d, cfg.vocab, std),
            codebook: Mat::randn(rng, cfg.vocab, enc.d_input, 1.0),
            cfg: cfg.clone(),
        }
    }

    /// Encode continuous frames (T_enc × d_input) to hidden states.
    pub fn encode(&self, frames: &Mat) -> Mat {
        let mut x = gemm::matmul(frames, &self.input_proj);
        let hd = self.cfg.head_dim();
        for (i, b) in self.enc_blocks.iter().enumerate() {
            x = b.forward_with(&x, hd, self.cfg.rope_theta, false, i, None);
        }
        rmsnorm(&x, &self.enc_norm)
    }

    /// Decoder logits given encoder states and the (teacher-forced) token
    /// prefix; optionally captures decoder calibration stats.
    pub fn decode(&self, enc: &Mat, tokens: &[u16], mut capture: Option<&mut Capture>) -> Mat {
        let d = self.cfg.d_model;
        let mut x = Mat::zeros(tokens.len(), d);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        let hd = self.cfg.head_dim();
        for (i, b) in self.dec_blocks.iter().enumerate() {
            x = b.forward(&x, enc, hd, self.cfg.rope_theta, i, capture.as_deref_mut());
        }
        gemm::matmul(&rmsnorm(&x, &self.final_norm), &self.lm_head)
    }

    /// Greedy transcription starting from BOS (token 0), up to `max_len`.
    pub fn transcribe(&self, frames: &Mat, max_len: usize, eos: u16) -> Vec<u16> {
        let enc = self.encode(frames);
        let mut seq: Vec<u16> = vec![0];
        for _ in 0..max_len {
            let logits = self.decode(&enc, &seq, None);
            let last = logits.row(logits.rows() - 1);
            let mut best = 0usize;
            for (i, &v) in last.iter().enumerate() {
                if v > last[best] {
                    best = i;
                }
            }
            if best as u16 == eos {
                break;
            }
            seq.push(best as u16);
        }
        seq[1..].to_vec()
    }

    /// Decoder projections, the compressible set for the audio experiments.
    pub const DECODER_PROJS: [ProjKind; 11] = [
        ProjKind::Q,
        ProjKind::K,
        ProjKind::V,
        ProjKind::O,
        ProjKind::Gate,
        ProjKind::Up,
        ProjKind::Down,
        ProjKind::CrossQ,
        ProjKind::CrossK,
        ProjKind::CrossV,
        ProjKind::CrossO,
    ];

    pub fn dec_proj(&self, layer: usize, p: ProjKind) -> &LinearWeight {
        let b = &self.dec_blocks[layer];
        match p {
            ProjKind::CrossQ => &b.cq,
            ProjKind::CrossK => &b.ck,
            ProjKind::CrossV => &b.cv,
            ProjKind::CrossO => &b.co,
            other => b.base.proj(other),
        }
    }

    pub fn dec_proj_mut(&mut self, layer: usize, p: ProjKind) -> &mut LinearWeight {
        let b = &mut self.dec_blocks[layer];
        match p {
            ProjKind::CrossQ => &mut b.cq,
            ProjKind::CrossK => &mut b.ck,
            ProjKind::CrossV => &mut b.cv,
            ProjKind::CrossO => &mut b.co,
            other => b.base.proj_mut(other),
        }
    }

    // ---- serialization (shared format with python/compile/pretrain.py) ----

    pub fn to_tensor_file(&self) -> TensorFile {
        let mut tf = TensorFile::new(self.cfg.clone());
        tf.insert("input_proj", self.input_proj.clone());
        tf.insert("embed", self.embed.clone());
        tf.insert("lm_head", self.lm_head.clone());
        tf.insert("codebook", self.codebook.clone());
        tf.insert("enc_norm", Mat::from_vec(1, self.enc_norm.len(), self.enc_norm.clone()));
        tf.insert("final_norm", Mat::from_vec(1, self.final_norm.len(), self.final_norm.clone()));
        for (i, b) in self.enc_blocks.iter().enumerate() {
            tf.insert(&format!("enc.{i}.attn_norm"), Mat::from_vec(1, b.attn_norm.len(), b.attn_norm.clone()));
            tf.insert(&format!("enc.{i}.mlp_norm"), Mat::from_vec(1, b.mlp_norm.len(), b.mlp_norm.clone()));
            for p in ProjKind::DECODER_SET {
                tf.insert(&format!("enc.{i}.{}", p.group()), b.proj(p).to_dense());
            }
        }
        for (i, b) in self.dec_blocks.iter().enumerate() {
            tf.insert(&format!("dec.{i}.attn_norm"), Mat::from_vec(1, b.base.attn_norm.len(), b.base.attn_norm.clone()));
            tf.insert(&format!("dec.{i}.mlp_norm"), Mat::from_vec(1, b.base.mlp_norm.len(), b.base.mlp_norm.clone()));
            tf.insert(&format!("dec.{i}.cross_norm"), Mat::from_vec(1, b.cross_norm.len(), b.cross_norm.clone()));
            for p in Self::DECODER_PROJS {
                tf.insert(&format!("dec.{i}.{}", p.group()), self.dec_proj(i, p).to_dense());
            }
        }
        tf
    }

    pub fn from_tensor_file(tf: &TensorFile) -> anyhow::Result<EncDecModel> {
        let cfg = tf.config.clone();
        let enc_cfg = cfg.encoder.clone().ok_or_else(|| anyhow::anyhow!("not an encdec config"))?;
        let dense = |name: String| -> anyhow::Result<LinearWeight> {
            Ok(LinearWeight::Dense(tf.get(&name)?.clone()))
        };
        let mut enc_blocks = Vec::new();
        for i in 0..enc_cfg.n_layers {
            enc_blocks.push(Block {
                attn_norm: tf.get_vec(&format!("enc.{i}.attn_norm"))?,
                mlp_norm: tf.get_vec(&format!("enc.{i}.mlp_norm"))?,
                q: dense(format!("enc.{i}.q_proj"))?,
                k: dense(format!("enc.{i}.k_proj"))?,
                v: dense(format!("enc.{i}.v_proj"))?,
                o: dense(format!("enc.{i}.o_proj"))?,
                gate: dense(format!("enc.{i}.gate_proj"))?,
                up: dense(format!("enc.{i}.up_proj"))?,
                down: dense(format!("enc.{i}.down_proj"))?,
                n_heads: cfg.n_heads,
                n_kv_heads: cfg.n_kv_heads,
            });
        }
        let mut dec_blocks = Vec::new();
        for i in 0..cfg.n_layers {
            dec_blocks.push(CrossBlock {
                base: Block {
                    attn_norm: tf.get_vec(&format!("dec.{i}.attn_norm"))?,
                    mlp_norm: tf.get_vec(&format!("dec.{i}.mlp_norm"))?,
                    q: dense(format!("dec.{i}.q_proj"))?,
                    k: dense(format!("dec.{i}.k_proj"))?,
                    v: dense(format!("dec.{i}.v_proj"))?,
                    o: dense(format!("dec.{i}.o_proj"))?,
                    gate: dense(format!("dec.{i}.gate_proj"))?,
                    up: dense(format!("dec.{i}.up_proj"))?,
                    down: dense(format!("dec.{i}.down_proj"))?,
                    n_heads: cfg.n_heads,
                    n_kv_heads: cfg.n_kv_heads,
                },
                cross_norm: tf.get_vec(&format!("dec.{i}.cross_norm"))?,
                cq: dense(format!("dec.{i}.cross_q_proj"))?,
                ck: dense(format!("dec.{i}.cross_k_proj"))?,
                cv: dense(format!("dec.{i}.cross_v_proj"))?,
                co: dense(format!("dec.{i}.cross_o_proj"))?,
            });
        }
        Ok(EncDecModel {
            input_proj: tf.get("input_proj")?.clone(),
            embed: tf.get("embed")?.clone(),
            lm_head: tf.get("lm_head")?.clone(),
            codebook: tf.get("codebook")?.clone(),
            enc_norm: tf.get_vec("enc_norm")?,
            final_norm: tf.get_vec("final_norm")?,
            enc_blocks,
            dec_blocks,
            cfg,
        })
    }
}

/// Prefix-VLM: a decoder-only LM consuming projected patch embeddings as a
/// prefix before the caption tokens.
#[derive(Clone, Debug)]
pub struct VlmModel {
    pub lm: Model,
    /// d_input × d patch projector (part of the "vision module", kept
    /// uncompressed — the paper compresses the language module only).
    pub patch_proj: Mat,
    /// concept vocab × d_input patch codebook (synthetic vision generator).
    pub codebook: Mat,
}

impl VlmModel {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> VlmModel {
        let enc = cfg.encoder.clone().expect("vlm config needs encoder.d_input");
        VlmModel {
            lm: Model::random(cfg, rng),
            patch_proj: Mat::randn(rng, enc.d_input, cfg.d_model, 1.0 / (enc.d_input as f32).sqrt()),
            codebook: Mat::randn(rng, cfg.vocab, enc.d_input, 1.0),
        }
    }

    /// Logits over the caption positions, conditioning on the patch prefix.
    pub fn forward(&self, patches: &Mat, tokens: &[u16]) -> Mat {
        let prefix = gemm::matmul(patches, &self.patch_proj);
        let tok_emb = self.lm.embed_tokens(tokens);
        let p = prefix.rows();
        let mut x = Mat::zeros(p + tokens.len(), self.lm.cfg.d_model);
        for i in 0..p {
            x.row_mut(i).copy_from_slice(prefix.row(i));
        }
        for t in 0..tokens.len() {
            x.row_mut(p + t).copy_from_slice(tok_emb.row(t));
        }
        let hd = self.lm.cfg.head_dim();
        for (layer, stage) in self.lm.stages.iter().enumerate() {
            x = match stage {
                super::transformer::Stage::Block(b) => {
                    b.forward(&x, hd, self.lm.cfg.rope_theta, layer, None)
                }
                super::transformer::Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        let h = rmsnorm(&x, &self.lm.final_norm);
        // only caption positions
        gemm::matmul(&h.rows_range(p, p + tokens.len()), &self.lm.lm_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_encdec() -> (ModelConfig, EncDecModel) {
        let mut cfg = ModelConfig::test_tiny();
        cfg.encoder = Some(super::super::config::EncoderConfig { n_layers: 1, d_input: 8 });
        cfg.n_kv_heads = cfg.n_heads; // simple MHA for cross-attn tests
        let m = EncDecModel::random(&cfg, &mut Rng::new(1));
        (cfg, m)
    }

    #[test]
    fn encdec_forward_shapes() {
        let (_cfg, m) = tiny_encdec();
        let mut rng = Rng::new(2);
        let frames = Mat::randn(&mut rng, 10, 8, 1.0);
        let enc = m.encode(&frames);
        assert_eq!(enc.shape(), (10, 32));
        let logits = m.decode(&enc, &[0, 5, 9], None);
        assert_eq!(logits.shape(), (3, 64));
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decoder_is_causal_encoder_is_not() {
        let (_cfg, m) = tiny_encdec();
        let mut rng = Rng::new(3);
        let frames = Mat::randn(&mut rng, 8, 8, 1.0);
        let enc = m.encode(&frames);
        let la = m.decode(&enc, &[0, 1, 2, 3], None);
        let lb = m.decode(&enc, &[0, 1, 2, 9], None);
        for j in 0..64 {
            assert!((la[(1, j)] - lb[(1, j)]).abs() < 1e-4);
        }
        // encoder: perturbing a late frame changes early encoder outputs
        let mut frames2 = frames.clone();
        frames2[(7, 0)] += 10.0;
        let enc2 = m.encode(&frames2);
        assert!(enc.rel_err(&enc2) > 1e-6);
        let mut early_changed = false;
        for j in 0..32 {
            if (enc[(0, j)] - enc2[(0, j)]).abs() > 1e-6 {
                early_changed = true;
            }
        }
        assert!(early_changed, "encoder must be bidirectional");
    }

    #[test]
    fn cross_attention_uses_encoder_states() {
        let (_cfg, m) = tiny_encdec();
        let mut rng = Rng::new(4);
        let f1 = Mat::randn(&mut rng, 6, 8, 1.0);
        let f2 = Mat::randn(&mut rng, 6, 8, 1.0);
        let l1 = m.decode(&m.encode(&f1), &[0, 1, 2], None);
        let l2 = m.decode(&m.encode(&f2), &[0, 1, 2], None);
        assert!(l1.rel_err(&l2) > 1e-6, "decoder must condition on audio");
    }

    #[test]
    fn capture_includes_cross_projections() {
        let (_cfg, m) = tiny_encdec();
        let mut rng = Rng::new(5);
        let frames = Mat::randn(&mut rng, 6, 8, 1.0);
        let enc = m.encode(&frames);
        let mut cap = Capture::default();
        m.decode(&enc, &[0, 1, 2, 3], Some(&mut cap));
        // 2 dec layers × 11 projections
        assert_eq!(cap.stats.len(), 2 * 11);
        assert!(cap.stats.contains_key(&(0, ProjKind::CrossK)));
    }

    #[test]
    fn encdec_serialization_roundtrip() {
        let (_cfg, m) = tiny_encdec();
        let dir = std::env::temp_dir().join("compot_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("encdec.bin");
        m.to_tensor_file().save(&path).unwrap();
        let back = EncDecModel::from_tensor_file(&TensorFile::load(&path).unwrap()).unwrap();
        let mut rng = Rng::new(6);
        let frames = Mat::randn(&mut rng, 5, 8, 1.0);
        let a = m.decode(&m.encode(&frames), &[0, 2], None);
        let b = back.decode(&back.encode(&frames), &[0, 2], None);
        assert!(a.rel_err(&b) < 1e-6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn vlm_conditions_on_patches() {
        let mut cfg = ModelConfig::test_tiny();
        cfg.encoder = Some(super::super::config::EncoderConfig { n_layers: 0, d_input: 8 });
        let m = VlmModel::random(&cfg, &mut Rng::new(7));
        let mut rng = Rng::new(8);
        let p1 = Mat::randn(&mut rng, 4, 8, 1.0);
        let p2 = Mat::randn(&mut rng, 4, 8, 1.0);
        let l1 = m.forward(&p1, &[1, 2, 3]);
        let l2 = m.forward(&p2, &[1, 2, 3]);
        assert_eq!(l1.shape(), (3, 64));
        assert!(l1.rel_err(&l2) > 1e-6);
    }
}
