//! Model configurations. Presets are scaled-down analogues of the paper's
//! evaluation models (DESIGN.md §3 documents the substitution); shape
//! *heterogeneity* — square attention projections, GQA-narrow K/V, wide MLP
//! — is preserved because it is what drives the allocator.

use crate::util::json::Json;

/// Projection types of a decoder block (the compressible set — embeddings
/// and lm_head stay uncompressed, matching the paper's protocol).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProjKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
    /// Cross-attention projections (encoder–decoder models only).
    CrossQ,
    CrossK,
    CrossV,
    CrossO,
}

impl ProjKind {
    pub const DECODER_SET: [ProjKind; 7] = [
        ProjKind::Q,
        ProjKind::K,
        ProjKind::V,
        ProjKind::O,
        ProjKind::Gate,
        ProjKind::Up,
        ProjKind::Down,
    ];

    /// Group key used by the allocator / SVD-LLM V2 (matches HF naming).
    pub fn group(&self) -> &'static str {
        match self {
            ProjKind::Q => "q_proj",
            ProjKind::K => "k_proj",
            ProjKind::V => "v_proj",
            ProjKind::O => "o_proj",
            ProjKind::Gate => "gate_proj",
            ProjKind::Up => "up_proj",
            ProjKind::Down => "down_proj",
            ProjKind::CrossQ => "cross_q_proj",
            ProjKind::CrossK => "cross_k_proj",
            ProjKind::CrossV => "cross_v_proj",
            ProjKind::CrossO => "cross_o_proj",
        }
    }

    pub fn from_group(s: &str) -> Option<ProjKind> {
        Self::DECODER_SET
            .iter()
            .chain([ProjKind::CrossQ, ProjKind::CrossK, ProjKind::CrossV, ProjKind::CrossO].iter())
            .copied()
            .find(|p| p.group() == s)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    /// Encoder config for enc-dec models (None for decoder-only).
    pub encoder: Option<EncoderConfig>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EncoderConfig {
    pub n_layers: usize,
    /// Input feature dimension of the continuous frames.
    pub d_input: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Shapes of the compressible projections of one decoder block, in
    /// [`ProjKind::DECODER_SET`] order. Convention: W is (in, out), y = x·W.
    pub fn proj_shape(&self, p: ProjKind) -> (usize, usize) {
        let d = self.d_model;
        let kv = self.n_kv_heads * self.head_dim();
        match p {
            ProjKind::Q | ProjKind::CrossQ => (d, d),
            ProjKind::K | ProjKind::V | ProjKind::CrossK | ProjKind::CrossV => (d, kv),
            ProjKind::O | ProjKind::CrossO => (d, d),
            ProjKind::Gate | ProjKind::Up => (d, self.d_ff),
            ProjKind::Down => (self.d_ff, d),
        }
    }

    /// Total parameters in compressible projections (decoder blocks).
    pub fn compressible_params(&self) -> usize {
        self.n_layers
            * ProjKind::DECODER_SET
                .iter()
                .map(|&p| {
                    let (m, n) = self.proj_shape(p);
                    m * n
                })
                .sum::<usize>()
    }

    // ---- presets (paper model in parentheses; DESIGN.md §3) ----

    /// Tiny unit-test config.
    pub fn test_tiny() -> ModelConfig {
        ModelConfig {
            name: "test-tiny".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 64,
            max_seq: 64,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Llama 3.2-1B) — the ablation workhorse.
    pub fn llama_micro() -> ModelConfig {
        ModelConfig {
            name: "llama-micro".into(),
            vocab: 256,
            d_model: 96,
            n_layers: 3,
            n_heads: 6,
            n_kv_heads: 2,
            d_ff: 256,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Llama 2-7B — MHA, no GQA.)
    pub fn llama_mini() -> ModelConfig {
        ModelConfig {
            name: "llama-mini".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 8,
            d_ff: 344,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Llama 3-8B.)
    pub fn llama_small() -> ModelConfig {
        ModelConfig {
            name: "llama-small".into(),
            vocab: 256,
            d_model: 160,
            n_layers: 5,
            n_heads: 10,
            n_kv_heads: 5,
            d_ff: 432,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Qwen3-0.6B.)
    pub fn qwen_nano() -> ModelConfig {
        ModelConfig {
            name: "qwen-nano".into(),
            vocab: 256,
            d_model: 64,
            n_layers: 3,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Qwen3-8B.)
    pub fn qwen_micro() -> ModelConfig {
        ModelConfig {
            name: "qwen-micro".into(),
            vocab: 256,
            d_model: 144,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 4,
            d_ff: 400,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Llama-13B / 30B stand-in for the scale table.)
    pub fn llama_wide() -> ModelConfig {
        ModelConfig {
            name: "llama-wide".into(),
            vocab: 256,
            d_model: 192,
            n_layers: 6,
            n_heads: 12,
            n_kv_heads: 12,
            d_ff: 512,
            max_seq: 128,
            rope_theta: 10000.0,
            encoder: None,
        }
    }

    /// (Whisper-like) encoder–decoder for the audio table.
    pub fn encdec_micro() -> ModelConfig {
        ModelConfig {
            name: "encdec-micro".into(),
            vocab: 256,
            d_model: 96,
            n_layers: 3,
            n_heads: 6,
            n_kv_heads: 6,
            d_ff: 256,
            max_seq: 192,
            rope_theta: 10000.0,
            encoder: Some(EncoderConfig { n_layers: 2, d_input: 32 }),
        }
    }

    /// (Qwen3-VL-like) prefix-VLM: patches projected into the decoder.
    pub fn vlm_micro() -> ModelConfig {
        ModelConfig {
            name: "vlm-micro".into(),
            vocab: 256,
            d_model: 96,
            n_layers: 3,
            n_heads: 6,
            n_kv_heads: 3,
            d_ff: 256,
            max_seq: 160,
            rope_theta: 10000.0,
            encoder: Some(EncoderConfig { n_layers: 0, d_input: 32 }),
        }
    }

    pub fn preset(name: &str) -> Option<ModelConfig> {
        Some(match name {
            "test-tiny" => Self::test_tiny(),
            "llama-micro" => Self::llama_micro(),
            "llama-mini" => Self::llama_mini(),
            "llama-small" => Self::llama_small(),
            "llama-wide" => Self::llama_wide(),
            "qwen-nano" => Self::qwen_nano(),
            "qwen-micro" => Self::qwen_micro(),
            "encdec-micro" => Self::encdec_micro(),
            "vlm-micro" => Self::vlm_micro(),
            _ => return None,
        })
    }

    pub const PRESETS: [&'static str; 9] = [
        "test-tiny",
        "llama-micro",
        "llama-mini",
        "llama-small",
        "llama-wide",
        "qwen-nano",
        "qwen-micro",
        "encdec-micro",
        "vlm-micro",
    ];

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str().into())
            .set("vocab", self.vocab.into())
            .set("d_model", self.d_model.into())
            .set("n_layers", self.n_layers.into())
            .set("n_heads", self.n_heads.into())
            .set("n_kv_heads", self.n_kv_heads.into())
            .set("d_ff", self.d_ff.into())
            .set("max_seq", self.max_seq.into())
            .set("rope_theta", (self.rope_theta as f64).into());
        if let Some(enc) = &self.encoder {
            let mut e = Json::obj();
            e.set("n_layers", enc.n_layers.into()).set("d_input", enc.d_input.into());
            j.set("encoder", e);
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let get = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("config missing field {k}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            rope_theta: j
                .get("rope_theta")
                .and_then(Json::as_f64)
                .unwrap_or(10000.0) as f32,
            encoder: j.get("encoder").map(|e| {
                Ok::<_, anyhow::Error>(EncoderConfig {
                    n_layers: e
                        .get("n_layers")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("encoder.n_layers"))?,
                    d_input: e
                        .get("d_input")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("encoder.d_input"))?,
                })
            })
            .transpose()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for name in ModelConfig::PRESETS {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{name}");
            assert_eq!(c.n_heads % c.n_kv_heads, 0, "{name}");
            assert!(c.compressible_params() > 0);
        }
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn shapes_are_heterogeneous() {
        let c = ModelConfig::llama_micro();
        let (qm, qn) = c.proj_shape(ProjKind::Q);
        let (km, kn) = c.proj_shape(ProjKind::K);
        let (um, un) = c.proj_shape(ProjKind::Up);
        let (dm, dn) = c.proj_shape(ProjKind::Down);
        assert_eq!((qm, qn), (96, 96));
        assert_eq!((km, kn), (96, 32)); // GQA-narrow
        assert_eq!((um, un), (96, 256)); // wide MLP
        assert_eq!((dm, dn), (256, 96));
    }

    #[test]
    fn json_roundtrip() {
        for name in ["llama-micro", "encdec-micro"] {
            let c = ModelConfig::preset(name).unwrap();
            let j = c.to_json();
            let back = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(c, back);
        }
    }

    #[test]
    fn group_names_roundtrip() {
        for p in ProjKind::DECODER_SET {
            assert_eq!(ProjKind::from_group(p.group()), Some(p));
        }
    }
}
