//! Decoder-only transformer forward pass (RMSNorm · RoPE · GQA attention ·
//! SwiGLU MLP), generic over [`LinearWeight`] so compressed projections plug
//! straight in, with optional per-projection activation capture for
//! calibration (the coordinator's first pipeline stage).

use super::config::{ModelConfig, ProjKind};
use crate::compress::whitening::CalibStats;
use crate::compress::LinearWeight;
use crate::linalg::{gemm, Mat};
use crate::util::Rng;
use std::collections::BTreeMap;

/// One decoder block. Head counts live here (not only in the config) so
/// structured pruning can shrink individual blocks.
#[derive(Clone, Debug)]
pub struct Block {
    pub attn_norm: Vec<f32>,
    pub q: LinearWeight,
    pub k: LinearWeight,
    pub v: LinearWeight,
    pub o: LinearWeight,
    pub mlp_norm: Vec<f32>,
    pub gate: LinearWeight,
    pub up: LinearWeight,
    pub down: LinearWeight,
    pub n_heads: usize,
    pub n_kv_heads: usize,
}

/// A pipeline stage: a transformer block, or the linear map ReplaceMe leaves
/// behind after deleting a span of blocks.
#[derive(Clone, Debug)]
pub enum Stage {
    Block(Block),
    /// x ← x·T (residual-stream linear replacement).
    Linear(Mat),
}

#[derive(Clone, Debug)]
pub struct Model {
    pub cfg: ModelConfig,
    /// vocab × d token embedding.
    pub embed: Mat,
    pub stages: Vec<Stage>,
    pub final_norm: Vec<f32>,
    /// d × vocab output head (kept uncompressed, paper protocol).
    pub lm_head: Mat,
}

/// Calibration activation capture: per (stage index, projection).
#[derive(Default)]
pub struct Capture {
    pub stats: BTreeMap<(usize, ProjKind), CalibStats>,
}

impl Capture {
    pub fn record(&mut self, layer: usize, kind: ProjKind, x: &Mat) {
        self.stats
            .entry((layer, kind))
            .or_insert_with(|| CalibStats::new(x.cols()))
            .accumulate(x);
    }
}

pub fn rmsnorm(x: &Mat, gain: &[f32]) -> Mat {
    let mut out = x.clone();
    for i in 0..x.rows() {
        rmsnorm_row_inplace(out.row_mut(i), gain);
    }
    out
}

/// RMSNorm of a single activation row, in place — the per-token form the
/// incremental decode path runs (identical arithmetic to [`rmsnorm`]).
pub fn rmsnorm_row_inplace(row: &mut [f32], gain: &[f32]) {
    let d = row.len();
    assert_eq!(gain.len(), d);
    let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let inv = 1.0 / (ms + 1e-6).sqrt() as f32;
    for (v, g) in row.iter_mut().zip(gain.iter()) {
        *v *= inv * *g;
    }
}

/// Allocating convenience form of [`rmsnorm_row_inplace`].
pub fn rmsnorm_row(row: &[f32], gain: &[f32]) -> Vec<f32> {
    let mut out = row.to_vec();
    rmsnorm_row_inplace(&mut out, gain);
    out
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotary position embedding applied in place over heads of width
/// `head_dim`, positions offset by `pos0`.
pub fn apply_rope(x: &mut Mat, head_dim: usize, theta: f32, pos0: usize) {
    for t in 0..x.rows() {
        rope_row(x.row_mut(t), head_dim, theta, pos0 + t);
    }
}

/// RoPE for a single row at absolute position `pos` — the per-token form
/// the incremental decode path runs (identical arithmetic to
/// [`apply_rope`]).
pub fn rope_row(row: &mut [f32], head_dim: usize, theta: f32, pos: usize) {
    let width = row.len();
    assert_eq!(width % head_dim, 0);
    let half = head_dim / 2;
    let pos = pos as f32;
    for h in 0..width / head_dim {
        let base = h * head_dim;
        for i in 0..half {
            let freq = theta.powf(-2.0 * i as f32 / head_dim as f32);
            let (sin, cos) = (pos * freq).sin_cos();
            let a = row[base + i];
            let b = row[base + half + i];
            row[base + i] = a * cos - b * sin;
            row[base + half + i] = a * sin + b * cos;
        }
    }
}

/// Causal softmax-attention for one head: q, k, v are T×hd (k/v may be from
/// a shared KV head). `causal=false` gives bidirectional attention
/// (encoder use).
pub fn attention_head(q: &Mat, k: &Mat, v: &Mat, causal: bool) -> Mat {
    let t_q = q.rows();
    let t_k = k.rows();
    let hd = q.cols();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = gemm::matmul_nt(q, k); // T_q × T_k
    let mut out = Mat::zeros(t_q, hd);
    for i in 0..t_q {
        let row = scores.row_mut(i);
        let limit = if causal {
            // decoder self-attention assumes square q/k alignment
            i + 1 + t_k.saturating_sub(t_q)
        } else {
            t_k
        };
        let mut maxv = f32::NEG_INFINITY;
        for j in 0..limit {
            row[j] *= scale;
            maxv = maxv.max(row[j]);
        }
        let mut denom = 0.0f32;
        for j in 0..limit {
            row[j] = (row[j] - maxv).exp();
            denom += row[j];
        }
        let inv = 1.0 / denom.max(1e-20);
        let orow = out.row_mut(i);
        for j in 0..limit {
            let w = row[j] * inv;
            if w == 0.0 {
                continue;
            }
            for (oc, vc) in orow.iter_mut().zip(v.row(j).iter()) {
                *oc += w * vc;
            }
        }
    }
    out
}

/// Slice head `h` (width hd) out of a T×(H·hd) activation.
pub fn head_slice(x: &Mat, h: usize, hd: usize) -> Mat {
    x.cols_range(h * hd, (h + 1) * hd)
}

impl Block {
    /// Forward one block over x (T×d). `layer` + `capture` for calibration.
    pub fn forward(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        layer: usize,
        capture: Option<&mut Capture>,
    ) -> Mat {
        self.forward_with(x, head_dim, theta, true, layer, capture)
    }

    /// Forward with explicit attention causality (encoders pass false).
    pub fn forward_with(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        causal: bool,
        layer: usize,
        capture: Option<&mut Capture>,
    ) -> Mat {
        self.forward_core(x, head_dim, theta, causal, layer, capture, None)
    }

    /// The one batched block body. With `cache`, RoPE positions start at the
    /// cache offset, the block's post-RoPE K/V rows are appended, and
    /// attention runs over the cached prefix plus the new rows (the prefill
    /// path); without it, this is the stateless forward. Keeping a single
    /// body is what guarantees the cached and stateless paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn forward_core(
        &self,
        x: &Mat,
        head_dim: usize,
        theta: f32,
        causal: bool,
        layer: usize,
        capture: Option<&mut Capture>,
        cache: Option<(&mut crate::model::decode::LayerKv, usize)>,
    ) -> Mat {
        let mut cap = capture;
        // ---- attention ----
        let xn = rmsnorm(x, &self.attn_norm);
        if let Some(c) = cap.as_deref_mut() {
            c.record(layer, ProjKind::Q, &xn);
            c.record(layer, ProjKind::K, &xn);
            c.record(layer, ProjKind::V, &xn);
        }
        let pos0 = cache.as_ref().map_or(0, |(_, p)| *p);
        let mut q = self.q.apply(&xn);
        let mut k = self.k.apply(&xn);
        let v = self.v.apply(&xn);
        apply_rope(&mut q, head_dim, theta, pos0);
        apply_rope(&mut k, head_dim, theta, pos0);
        // Attention context: the new K/V rows alone, or (prefill) the cache
        // contents up to and including them. The cached rows 0..pos0+T are
        // bit-identical to what the stateless path would recompute.
        let (k_ctx, v_ctx) = match cache {
            Some((kv, p)) => {
                kv.append(p, &k, &v);
                let total = p + x.rows();
                (kv.k_rows(total), kv.v_rows(total))
            }
            None => (k, v),
        };
        let q_per_kv = self.n_heads / self.n_kv_heads;
        let mut concat = Mat::zeros(x.rows(), self.n_heads * head_dim);
        for h in 0..self.n_heads {
            let kvh = h / q_per_kv;
            let qh = head_slice(&q, h, head_dim);
            let kh = head_slice(&k_ctx, kvh, head_dim);
            let vh = head_slice(&v_ctx, kvh, head_dim);
            let oh = attention_head(&qh, &kh, &vh, causal);
            for t in 0..x.rows() {
                concat.row_mut(t)[h * head_dim..(h + 1) * head_dim].copy_from_slice(oh.row(t));
            }
        }
        if let Some(c) = cap.as_deref_mut() {
            c.record(layer, ProjKind::O, &concat);
        }
        let attn_out = self.o.apply(&concat);
        let x = x.add(&attn_out);

        // ---- MLP (SwiGLU) ----
        let xn2 = rmsnorm(&x, &self.mlp_norm);
        if let Some(c) = cap.as_deref_mut() {
            c.record(layer, ProjKind::Gate, &xn2);
            c.record(layer, ProjKind::Up, &xn2);
        }
        let g = self.gate.apply(&xn2);
        let u = self.up.apply(&xn2);
        let mut h = g;
        for i in 0..h.rows() {
            let hrow = h.row_mut(i);
            let urow = u.row(i);
            for (hv, uv) in hrow.iter_mut().zip(urow.iter()) {
                *hv = silu(*hv) * uv;
            }
        }
        if let Some(c) = cap.as_deref_mut() {
            c.record(layer, ProjKind::Down, &h);
        }
        let mlp_out = self.down.apply(&h);
        x.add(&mlp_out)
    }

    pub fn proj(&self, p: ProjKind) -> &LinearWeight {
        match p {
            ProjKind::Q => &self.q,
            ProjKind::K => &self.k,
            ProjKind::V => &self.v,
            ProjKind::O => &self.o,
            ProjKind::Gate => &self.gate,
            ProjKind::Up => &self.up,
            ProjKind::Down => &self.down,
            _ => panic!("decoder block has no {p:?}"),
        }
    }

    pub fn proj_mut(&mut self, p: ProjKind) -> &mut LinearWeight {
        match p {
            ProjKind::Q => &mut self.q,
            ProjKind::K => &mut self.k,
            ProjKind::V => &mut self.v,
            ProjKind::O => &mut self.o,
            ProjKind::Gate => &mut self.gate,
            ProjKind::Up => &mut self.up,
            ProjKind::Down => &mut self.down,
            _ => panic!("decoder block has no {p:?}"),
        }
    }

    /// Random block at the config's shapes.
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Block {
        let d = cfg.d_model;
        let std = 0.6 / (d as f32).sqrt();
        let mk = |p: ProjKind, rng: &mut Rng| {
            let (m, n) = cfg.proj_shape(p);
            LinearWeight::Dense(Mat::randn(rng, m, n, std))
        };
        Block {
            attn_norm: vec![1.0; d],
            q: mk(ProjKind::Q, rng),
            k: mk(ProjKind::K, rng),
            v: mk(ProjKind::V, rng),
            o: mk(ProjKind::O, rng),
            mlp_norm: vec![1.0; d],
            gate: mk(ProjKind::Gate, rng),
            up: mk(ProjKind::Up, rng),
            down: mk(ProjKind::Down, rng),
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
        }
    }
}

impl Model {
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Model {
        let std = 0.6 / (cfg.d_model as f32).sqrt();
        Model {
            embed: Mat::randn(rng, cfg.vocab, cfg.d_model, 1.0),
            stages: (0..cfg.n_layers).map(|_| Stage::Block(Block::random(cfg, rng))).collect(),
            final_norm: vec![1.0; cfg.d_model],
            lm_head: Mat::randn(rng, cfg.d_model, cfg.vocab, std),
            cfg: cfg.clone(),
        }
    }

    /// Embed a token sequence.
    pub fn embed_tokens(&self, tokens: &[u16]) -> Mat {
        let d = self.cfg.d_model;
        let mut x = Mat::zeros(tokens.len(), d);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.embed.row(tok as usize));
        }
        x
    }

    /// Hidden states after all stages (before the LM head).
    pub fn hidden_states(&self, tokens: &[u16], mut capture: Option<&mut Capture>) -> Mat {
        let mut x = self.embed_tokens(tokens);
        let hd = self.cfg.head_dim();
        for (layer, stage) in self.stages.iter().enumerate() {
            x = match stage {
                Stage::Block(b) => {
                    b.forward(&x, hd, self.cfg.rope_theta, layer, capture.as_deref_mut())
                }
                Stage::Linear(t) => gemm::matmul(&x, t),
            };
        }
        rmsnorm(&x, &self.final_norm)
    }

    /// Logits (T × vocab) for every position.
    pub fn forward(&self, tokens: &[u16]) -> Mat {
        gemm::matmul(&self.hidden_states(tokens, None), &self.lm_head)
    }

    /// Forward while accumulating calibration stats for every projection.
    pub fn forward_capture(&self, tokens: &[u16], capture: &mut Capture) -> Mat {
        gemm::matmul(&self.hidden_states(tokens, Some(capture)), &self.lm_head)
    }

    /// Greedy continuation of `prompt` by `max_new` tokens, via the
    /// KV-cached incremental runtime ([`crate::model::decode`]): one prefill
    /// over the prompt, then O(T) decode steps. Returns `[]` on an empty
    /// prompt.
    pub fn greedy_decode(&self, prompt: &[u16], max_new: usize) -> Vec<u16> {
        self.generate(prompt, max_new, crate::model::decode::SamplerCfg::greedy())
    }

    /// Reference greedy decode that recomputes the full O(T²) forward for
    /// every generated token. Kept for cached-vs-uncached parity tests and
    /// the decode benchmark; everything else should use
    /// [`greedy_decode`](Self::greedy_decode).
    pub fn greedy_decode_full(&self, prompt: &[u16], max_new: usize) -> Vec<u16> {
        let mut seq: Vec<u16> = prompt.to_vec();
        for _ in 0..max_new {
            let logits = self.forward(&seq);
            let last = logits.row(logits.rows() - 1);
            seq.push(crate::model::decode::argmax(last));
            if seq.len() >= self.cfg.max_seq {
                break;
            }
        }
        seq[prompt.len()..].to_vec()
    }

    /// Blocks only (skipping Linear stages), with original stage indices.
    pub fn blocks(&self) -> impl Iterator<Item = (usize, &Block)> {
        self.stages.iter().enumerate().filter_map(|(i, s)| match s {
            Stage::Block(b) => Some((i, b)),
            Stage::Linear(_) => None,
        })
    }

    /// Total parameter count (dense-equivalent for compressed layers uses
    /// their true stored parameter count).
    pub fn storage_bits(&self) -> u64 {
        let mut bits = 16 * (self.embed.rows() * self.embed.cols()
            + self.lm_head.rows() * self.lm_head.cols()
            + self.final_norm.len()) as u64;
        for stage in &self.stages {
            match stage {
                Stage::Block(b) => {
                    bits += 16 * (b.attn_norm.len() + b.mlp_norm.len()) as u64;
                    for p in ProjKind::DECODER_SET {
                        bits += b.proj(p).storage_bits();
                    }
                }
                Stage::Linear(t) => bits += 16 * (t.rows() * t.cols()) as u64,
            }
        }
        bits
    }

    /// Actual resident *heap* bytes of every weight buffer: embed, LM head,
    /// and norms at 4 B/f32, and each projection in its *stored*
    /// representation — packed-quantized projections count their real
    /// packed size (codes + f16 scales + sparse indices). Mapping-aware: a
    /// checkpoint-mapped buffer occupies shared file-backed pages, not
    /// process heap, so it counts toward
    /// [`mapped_weight_bytes`](Self::mapped_weight_bytes) instead. This is
    /// the memory-bandwidth quantity the `quant_decode` benchmark gates on,
    /// as opposed to the paper's [`storage_bits`](Self::storage_bits)
    /// accounting protocol.
    pub fn resident_weight_bytes(&self) -> usize {
        let mut bytes = self.embed.resident_bytes()
            + self.lm_head.resident_bytes()
            + 4 * self.final_norm.len();
        for stage in &self.stages {
            match stage {
                Stage::Block(b) => {
                    bytes += 4 * (b.attn_norm.len() + b.mlp_norm.len());
                    for p in ProjKind::DECODER_SET {
                        bytes += b.proj(p).resident_bytes();
                    }
                }
                Stage::Linear(t) => bytes += t.resident_bytes(),
            }
        }
        bytes
    }

    /// Packed-quantized projections replaced by their dequantized f32 forms
    /// (bit-identical values) — the fake-quant reference the packed decode
    /// path is parity-tested against.
    pub fn dequantize_projections(&self) -> Model {
        let mut out = self.clone();
        for stage in out.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let w = b.proj(p).dequantized();
                    *b.proj_mut(p) = w;
                }
            }
        }
        out
    }

    /// Every packed-quantized projection re-encoded in `layout` (see
    /// [`crate::linalg::QuantMat::with_layout`]) — stored values identical,
    /// only the physical code layout (and thus the unpack kernel serving
    /// decode) changes. The `quant_decode` benchmark uses this to measure
    /// the planar-vs-legacy unpack speedup on one model.
    pub fn with_quant_layout(&self, layout: crate::linalg::QuantLayout) -> Model {
        let mut out = self.clone();
        for stage in out.stages.iter_mut() {
            if let Stage::Block(b) = stage {
                for p in ProjKind::DECODER_SET {
                    let w = b.proj(p).with_quant_layout(layout);
                    *b.proj_mut(p) = w;
                }
            }
        }
        out
    }

    /// Storage bits of the compressible projections only (the quantity the
    /// model-level CR is defined over, matching the paper's protocol).
    pub fn projection_bits(&self) -> u64 {
        let mut bits = 0;
        for stage in &self.stages {
            match stage {
                Stage::Block(b) => {
                    for p in ProjKind::DECODER_SET {
                        bits += b.proj(p).storage_bits();
                    }
                }
                Stage::Linear(t) => bits += 16 * (t.rows() * t.cols()) as u64,
            }
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model(seed: u64) -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(seed))
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let tokens: Vec<u16> = vec![1, 5, 9, 13, 2];
        let logits = m.forward(&tokens);
        assert_eq!(logits.shape(), (5, 64));
        assert!(logits.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let m = tiny_model(2);
        let a: Vec<u16> = vec![1, 2, 3, 4, 5, 6];
        let mut b = a.clone();
        b[5] = 60;
        let la = m.forward(&a);
        let lb = m.forward(&b);
        for t in 0..5 {
            for j in 0..64 {
                assert!(
                    (la[(t, j)] - lb[(t, j)]).abs() < 1e-4,
                    "position {t} depends on future token"
                );
            }
        }
        // ...but the last position must differ (token 5 itself changed... the
        // *input* at position 5 changed so logits at 5 change).
        let mut differs = false;
        for j in 0..64 {
            if (la[(5, j)] - lb[(5, j)]).abs() > 1e-6 {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn rope_preserves_norm_and_relativity() {
        let mut rng = Rng::new(3);
        let mut x = Mat::randn(&mut rng, 6, 16, 1.0);
        let before: Vec<f64> = (0..6)
            .map(|t| x.row(t).iter().map(|&v| (v as f64).powi(2)).sum::<f64>())
            .collect();
        apply_rope(&mut x, 8, 10000.0, 0);
        for t in 0..6 {
            let after: f64 = x.row(t).iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((after - before[t]).abs() / before[t] < 1e-4);
        }
        // relative property: <rope(q,i), rope(k,j)> depends only on i-j
        let q = Mat::from_fn(1, 8, |_, j| (j as f32 * 0.3).sin());
        let k = Mat::from_fn(1, 8, |_, j| (j as f32 * 0.7).cos());
        let dot_at = |pi: usize, pj: usize| {
            let mut qq = q.clone();
            let mut kk = k.clone();
            apply_rope(&mut qq, 8, 100.0, pi);
            apply_rope(&mut kk, 8, 100.0, pj);
            crate::linalg::matrix::dot64(qq.row(0), kk.row(0))
        };
        assert!((dot_at(3, 1) - dot_at(7, 5)).abs() < 1e-4);
        assert!((dot_at(3, 1) - dot_at(4, 1)).abs() > 1e-6);
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        let mut rng = Rng::new(4);
        let q = Mat::randn(&mut rng, 5, 8, 1.0);
        let k = Mat::randn(&mut rng, 5, 8, 1.0);
        let v = Mat::from_fn(5, 8, |i, _| i as f32); // rows constant
        let out = attention_head(&q, &k, &v, true);
        // row 0 attends only to position 0 ⇒ exactly v[0]
        for j in 0..8 {
            assert!((out[(0, j)] - 0.0).abs() < 1e-6);
        }
        // each output in the convex hull of visible v rows
        for t in 0..5 {
            for j in 0..8 {
                assert!(out[(t, j)] >= -1e-5 && out[(t, j)] <= t as f32 + 1e-5);
            }
        }
    }

    #[test]
    fn capture_collects_all_projections() {
        let m = tiny_model(5);
        let mut cap = Capture::default();
        let tokens: Vec<u16> = (0..12u16).collect();
        m.forward_capture(&tokens, &mut cap);
        assert_eq!(cap.stats.len(), 2 * 7); // 2 layers × 7 projections
        for ((layer, kind), st) in &cap.stats {
            assert_eq!(st.count, 12, "layer {layer} {kind:?}");
            let expect_dim = match kind {
                ProjKind::Down => 64,
                _ => 32,
            };
            assert_eq!(st.dim(), expect_dim);
        }
    }

    #[test]
    fn compressed_projection_plugs_in() {
        use crate::compress::compot::Compot;
        use crate::compress::Compressor;
        let mut m = tiny_model(6);
        let tokens: Vec<u16> = (0..16u16).map(|i| i * 3 % 64).collect();
        let base = m.forward(&tokens);
        // capture calibration, compress one projection lightly
        let mut cap = Capture::default();
        m.forward_capture(&tokens, &mut cap);
        let stats = &cap.stats[&(0, ProjKind::Up)];
        let w = match m.stages[0] {
            Stage::Block(ref b) => b.up.to_dense(),
            _ => unreachable!(),
        };
        let mut rng = Rng::new(7);
        let layer = Compot::default().compress(&w, stats, 0.15, &mut rng).unwrap();
        if let Stage::Block(ref mut b) = m.stages[0] {
            b.up = layer.weight;
        }
        let out = m.forward(&tokens);
        // mild compression ⇒ close logits
        assert!(out.rel_err(&base) < 0.5, "rel err {}", out.rel_err(&base));
    }

    #[test]
    fn linear_stage_applies() {
        let mut m = tiny_model(8);
        let d = m.cfg.d_model;
        m.stages[1] = Stage::Linear(Mat::eye(d).scale(0.5));
        let tokens: Vec<u16> = vec![1, 2, 3];
        let out = m.forward(&tokens);
        assert!(out.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn greedy_decode_is_deterministic() {
        let m = tiny_model(9);
        let a = m.greedy_decode(&[1, 2, 3], 5);
        let b = m.greedy_decode(&[1, 2, 3], 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn storage_accounting_counts_all() {
        let m = tiny_model(10);
        let bits = m.storage_bits();
        // embed 64*32 + head 32*64 + norms... at least the projections:
        assert!(bits > 16 * m.cfg.compressible_params() as u64);
        assert_eq!(
            m.projection_bits(),
            16 * m.cfg.compressible_params() as u64
        );
    }
}
