//! Transformer model substrate.
//!
//! Pure-Rust forward passes over models whose projections may be dense or
//! compressed ([`crate::compress::LinearWeight`]), so every compression
//! method can be evaluated end-to-end without Python on the path. The
//! decoder-only LM ([`transformer`]) covers the language tables; the
//! encoder–decoder ([`encdec`]) covers the Whisper-like audio and VLM
//! transfer experiments. Generation runs through the KV-cached incremental
//! runtime ([`decode`]): prefill once, then O(T) compressed-native decode
//! steps per token.
//!
//! Weights are *trained at build time* by `python/compile/pretrain.py` (JAX,
//! `make artifacts`) and loaded from the binary format in [`weights`]; unit
//! tests use randomly initialized models.

pub mod config;
pub mod cpt2;
pub mod decode;
pub mod encdec;
pub mod shard;
pub mod transformer;
pub mod weights;

pub use config::{ModelConfig, ProjKind};
pub use cpt2::{CheckpointInfo, MappedCheckpoint};
pub use shard::{ShardEntry, ShardManifest};
pub use decode::{DecodeSession, KvCache, Sampler, SamplerCfg};
pub use transformer::{Block, Model};
