//! `CPT2` — the compressed-checkpoint format: every [`LinearWeight`]
//! variant serialized *natively*, so a compressed (and possibly packed-
//! quantized) model reloads in one pass with **zero recompression and zero
//! requantization**. The factorization is the deployable artifact
//! (CoSpaDi/ProcrustesGPT); this module makes it durable.
//!
//! Layout:
//! ```text
//! b"CPT2" | u32 header_len | header JSON (utf-8)
//!         | zero pad to ALIGN | section payloads (LE, each ALIGN-aligned)
//! ```
//!
//! The header carries `{"version", "config", "plan"?, "align", "sections",
//! "stages"}`. Each section record is `{"name", "dtype": "f32"|"u32"|"u16",
//! "len", "offset", "crc32"}` with `offset` in bytes from the start of the
//! (aligned) data region — so a loader can `read_exact`/`mmap` a section
//! straight into its resident buffer. Each stage entry tags its projections
//! with a variant (`dense`, `low_rank`, `factorized`, `quant_dense`,
//! `quant_low_rank`, `quant_factorized`), shapes, and bit widths; the
//! quantized variants reference raw u32 code-word and u16 f16-scale
//! sections that are byte-for-byte the in-memory [`QuantMat`] buffers.
//!
//! Every field read from disk is validated against the actual file size
//! before any allocation, every section payload is CRC32-checked, and every
//! reconstruction goes through the fallible `from_raw_parts` constructors —
//! a corrupt or adversarial checkpoint yields an error, never a panic or a
//! huge allocation.
//!
//! [`Model::load_checkpoint`] is the versioned entry point: it sniffs the
//! magic and accepts both the dense `CPT1` tensor format
//! ([`super::weights`]) and `CPT2`.

use super::config::ProjKind;
use super::transformer::{Block, Model, Stage};
use super::weights::TensorFile;
use crate::compress::sparse::{ColumnSparse, QuantColumnSparse};
use crate::compress::LinearWeight;
use crate::linalg::{Mat, QuantMat};
use crate::model::config::ModelConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Seek, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"CPT2";
pub const VERSION: usize = 2;
/// Section payload alignment (bytes) — sized for cache lines / mmap-friendly
/// direct reads into the resident buffers.
pub const ALIGN: usize = 64;

/// What a checkpoint said about itself — surfaced by `serve`'s info
/// response so operators can tell a cold-loaded artifact from an in-process
/// compression run.
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    /// `"cpt1"` or `"cpt2"`.
    pub format: &'static str,
    /// Compression-plan provenance recorded at save time (CPT2 only).
    pub plan: Option<String>,
}

/// Byte-at-a-time CRC32 lookup table, built at compile time. The table
/// version runs ~8× faster than the bitwise loop — checksumming must not
/// become the cold-load bottleneck this format exists to remove.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut b = 0;
        while b < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ 0xedb8_8320 } else { c >> 1 };
            b += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE, reflected) of a byte slice — in-tree, no crc crate in this
/// offline env.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

fn align_up(x: usize, a: usize) -> usize {
    x.div_ceil(a) * a
}

// ---------------------------------------------------------------------------
// Section writer.
// ---------------------------------------------------------------------------

struct PendingSection {
    name: String,
    dtype: &'static str,
    len: usize,
    bytes: Vec<u8>,
}

#[derive(Default)]
struct SectionWriter {
    sections: Vec<PendingSection>,
}

impl SectionWriter {
    fn add(&mut self, name: &str, dtype: &'static str, len: usize, bytes: Vec<u8>) {
        self.sections.push(PendingSection { name: name.to_string(), dtype, len, bytes });
    }

    fn add_f32(&mut self, name: &str, vals: &[f32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "f32", vals.len(), b);
    }

    fn add_u32(&mut self, name: &str, vals: &[u32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u32", vals.len(), b);
    }

    fn add_u16(&mut self, name: &str, vals: &[u16]) {
        let mut b = Vec::with_capacity(2 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u16", vals.len(), b);
    }

    /// Lay the sections out ALIGN-aligned; returns (section records, payload).
    fn finish(self) -> (Vec<Json>, Vec<u8>) {
        let mut records = Vec::with_capacity(self.sections.len());
        let mut payload: Vec<u8> = Vec::new();
        for s in self.sections {
            let offset = align_up(payload.len(), ALIGN);
            payload.resize(offset, 0);
            let mut rec = Json::obj();
            rec.set("name", s.name.as_str().into())
                .set("dtype", s.dtype.into())
                .set("len", s.len.into())
                .set("offset", offset.into())
                .set("crc32", (crc32(&s.bytes) as usize).into());
            records.push(rec);
            payload.extend_from_slice(&s.bytes);
        }
        (records, payload)
    }
}

// ---------------------------------------------------------------------------
// Section reader.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct SectionDesc {
    dtype_size: usize,
    len: usize,
    offset: usize,
}

struct SectionReader<'a> {
    data: &'a [u8],
    by_name: BTreeMap<String, (SectionDesc, &'static str)>,
}

impl<'a> SectionReader<'a> {
    fn new(header: &Json, data: &'a [u8]) -> anyhow::Result<SectionReader<'a>> {
        let mut by_name = BTreeMap::new();
        for rec in header
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'sections' array"))?
        {
            let name = rec
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("section record without a name"))?;
            let (dtype, size): (&'static str, usize) =
                match rec.get("dtype").and_then(Json::as_str) {
                    Some("f32") => ("f32", 4),
                    Some("u32") => ("u32", 4),
                    Some("u16") => ("u16", 2),
                    other => anyhow::bail!("section '{name}': unknown dtype {other:?}"),
                };
            let len = rec
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing len"))?;
            let offset = rec
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing offset"))?;
            let byte_len = len
                .checked_mul(size)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': length overflows"))?;
            let end = offset
                .checked_add(byte_len)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': offset overflows"))?;
            anyhow::ensure!(
                end <= data.len(),
                "section '{name}' ({len}×{size} B at offset {offset}) runs past the data \
                 region ({} B) — truncated or corrupt checkpoint",
                data.len()
            );
            let want_crc = rec
                .get("crc32")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing crc32"))?;
            let got = crc32(&data[offset..end]) as usize;
            anyhow::ensure!(
                got == want_crc,
                "section '{name}': crc mismatch (header {want_crc:#x}, payload {got:#x})"
            );
            by_name.insert(
                name.to_string(),
                (SectionDesc { dtype_size: size, len, offset }, dtype),
            );
        }
        Ok(SectionReader { data, by_name })
    }

    fn desc(&self, name: &str, dtype: &str, expect_len: usize) -> anyhow::Result<SectionDesc> {
        let (desc, have_dtype) = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing section '{name}'"))?;
        anyhow::ensure!(
            *have_dtype == dtype,
            "section '{name}': dtype {have_dtype}, expected {dtype}"
        );
        anyhow::ensure!(
            desc.len == expect_len,
            "section '{name}': {} elements on disk, header metadata implies {expect_len}",
            desc.len
        );
        Ok(*desc)
    }

    fn f32s(&self, name: &str, expect_len: usize) -> anyhow::Result<Vec<f32>> {
        let d = self.desc(name, "f32", expect_len)?;
        let raw = &self.data[d.offset..d.offset + d.len * d.dtype_size];
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u32s(&self, name: &str, expect_len: usize) -> anyhow::Result<Vec<u32>> {
        let d = self.desc(name, "u32", expect_len)?;
        let raw = &self.data[d.offset..d.offset + d.len * d.dtype_size];
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u16s(&self, name: &str, expect_len: usize) -> anyhow::Result<Vec<u16>> {
        let d = self.desc(name, "u16", expect_len)?;
        let raw = &self.data[d.offset..d.offset + d.len * d.dtype_size];
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
    }

    fn mat(&self, name: &str, rows: usize, cols: usize) -> anyhow::Result<Mat> {
        let len = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("section '{name}': {rows}x{cols} overflows"))?;
        Ok(Mat::from_vec(rows, cols, self.f32s(name, len)?))
    }

    /// `bits` is pre-validated by `meta_bits` (projection-named error);
    /// `QuantMat::from_raw_parts` re-checks it as the fallible constructor
    /// every path funnels through — no third check here.
    fn qmat(&self, base: &str, rows: usize, cols: usize, bits: u32) -> anyhow::Result<QuantMat> {
        let np = QuantMat::packed_len(rows, cols, bits)
            .ok_or_else(|| anyhow::anyhow!("'{base}': {rows}x{cols} overflows"))?;
        let ns = QuantMat::scales_len(rows, cols)
            .ok_or_else(|| anyhow::anyhow!("'{base}': {rows}x{cols} overflows"))?;
        let packed = self.u32s(&format!("{base}.codes"), np)?;
        let scales = self.u16s(&format!("{base}.scales"), ns)?;
        QuantMat::from_raw_parts(rows, cols, bits, packed, scales)
    }
}

// ---------------------------------------------------------------------------
// LinearWeight ⇄ sections.
// ---------------------------------------------------------------------------

fn write_qmat(sw: &mut SectionWriter, base: &str, q: &QuantMat) {
    sw.add_u32(&format!("{base}.codes"), q.packed_words());
    sw.add_u16(&format!("{base}.scales"), q.scale_bits());
}

/// Serialize one projection under `base`, returning its header metadata.
fn write_weight(sw: &mut SectionWriter, base: &str, w: &LinearWeight) -> Json {
    let mut meta = Json::obj();
    match w {
        LinearWeight::Dense(m) => {
            meta.set("variant", "dense".into())
                .set("rows", m.rows().into())
                .set("cols", m.cols().into());
            sw.add_f32(&format!("{base}.w"), m.data());
        }
        LinearWeight::LowRank { b, c } => {
            meta.set("variant", "low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into());
            sw.add_f32(&format!("{base}.b"), b.data());
            sw.add_f32(&format!("{base}.c"), c.data());
        }
        LinearWeight::Factorized { a, s } => {
            meta.set("variant", "factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into());
            sw.add_f32(&format!("{base}.a"), a.data());
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            sw.add_f32(&format!("{base}.s.val"), s.values());
        }
        LinearWeight::QuantDense(q) => {
            meta.set("variant", "quant_dense".into())
                .set("rows", q.rows().into())
                .set("cols", q.cols().into())
                .set("bits", (q.bits() as usize).into());
            write_qmat(sw, &format!("{base}.w"), q);
        }
        LinearWeight::QuantLowRank { b, c } => {
            meta.set("variant", "quant_low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into())
                .set("bits_b", (b.bits() as usize).into())
                .set("bits_c", (c.bits() as usize).into());
            write_qmat(sw, &format!("{base}.b"), b);
            write_qmat(sw, &format!("{base}.c"), c);
        }
        LinearWeight::QuantFactorized { a, s } => {
            let v = s.values_qmat();
            meta.set("variant", "quant_factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into())
                .set("bits_a", (a.bits() as usize).into())
                .set("bits_val", (v.bits() as usize).into());
            write_qmat(sw, &format!("{base}.a"), a);
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            write_qmat(sw, &format!("{base}.s.val"), v);
        }
    }
    meta
}

fn meta_usize(meta: &Json, base: &str, key: &str) -> anyhow::Result<usize> {
    meta.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing field '{key}'"))
}

fn meta_bits(meta: &Json, base: &str, key: &str) -> anyhow::Result<u32> {
    let b = meta_usize(meta, base, key)?;
    anyhow::ensure!(
        (2..=8).contains(&b),
        "projection '{base}': {key}={b} outside the packable 2..=8 range"
    );
    Ok(b as u32)
}

/// Reconstruct one projection from its header metadata + sections.
fn read_weight(sr: &SectionReader, base: &str, meta: &Json) -> anyhow::Result<LinearWeight> {
    let variant = meta
        .get("variant")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing variant tag"))?;
    match variant {
        "dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            Ok(LinearWeight::Dense(sr.mat(&format!("{base}.w"), rows, cols)?))
        }
        "low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::LowRank {
                b: sr.mat(&format!("{base}.b"), m, r)?,
                c: sr.mat(&format!("{base}.c"), r, n)?,
            })
        }
        "factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.u32s(&format!("{base}.s.idx"), ns)?;
            let val = sr.f32s(&format!("{base}.s.val"), ns)?;
            Ok(LinearWeight::Factorized {
                a: sr.mat(&format!("{base}.a"), m, k)?,
                s: ColumnSparse::from_raw_parts(k, n, s, idx, val)?,
            })
        }
        "quant_dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            let bits = meta_bits(meta, base, "bits")?;
            Ok(LinearWeight::QuantDense(sr.qmat(&format!("{base}.w"), rows, cols, bits)?))
        }
        "quant_low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::QuantLowRank {
                b: sr.qmat(&format!("{base}.b"), m, r, meta_bits(meta, base, "bits_b")?)?,
                c: sr.qmat(&format!("{base}.c"), r, n, meta_bits(meta, base, "bits_c")?)?,
            })
        }
        "quant_factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.u32s(&format!("{base}.s.idx"), ns)?;
            let val = sr.qmat(&format!("{base}.s.val"), n, s, meta_bits(meta, base, "bits_val")?)?;
            Ok(LinearWeight::QuantFactorized {
                a: sr.qmat(&format!("{base}.a"), m, k, meta_bits(meta, base, "bits_a")?)?,
                s: QuantColumnSparse::from_raw_parts(k, idx, val)?,
            })
        }
        other => anyhow::bail!("projection '{base}': unknown variant tag '{other}'"),
    }
}

/// Structural contract the forward pass will index into: a CRC-valid
/// checkpoint whose per-tensor shapes are internally consistent could still
/// describe a block the attention/MLP code would panic on. Head widths are
/// per-block (structured pruning shrinks them) but must agree with the
/// config's global head_dim; the MLP hidden width is free (channel pruning)
/// but gate/up/down must agree with each other.
fn validate_block_shapes(i: usize, b: &Block, d: usize, head_dim: usize) -> anyhow::Result<()> {
    let check = |name: &str, got: (usize, usize), want: (usize, usize)| -> anyhow::Result<()> {
        anyhow::ensure!(
            got == want,
            "stage {i}: {name} shape {}x{} does not match the structural contract {}x{}",
            got.0,
            got.1,
            want.0,
            want.1
        );
        Ok(())
    };
    // Head counts come from the header: checked arithmetic, like every
    // other untrusted multiplication in this module.
    let qw = b
        .n_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_heads·head_dim overflows"))?;
    let kvw = b
        .n_kv_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_kv_heads·head_dim overflows"))?;
    check("q_proj", (b.q.in_dim(), b.q.out_dim()), (d, qw))?;
    check("k_proj", (b.k.in_dim(), b.k.out_dim()), (d, kvw))?;
    check("v_proj", (b.v.in_dim(), b.v.out_dim()), (d, kvw))?;
    check("o_proj", (b.o.in_dim(), b.o.out_dim()), (qw, d))?;
    let ff = b.gate.out_dim();
    check("gate_proj", (b.gate.in_dim(), ff), (d, ff))?;
    check("up_proj", (b.up.in_dim(), b.up.out_dim()), (d, ff))?;
    check("down_proj", (b.down.in_dim(), b.down.out_dim()), (ff, d))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Model save / load.
// ---------------------------------------------------------------------------

impl Model {
    /// Serialize this model — compressed or not — as a CPT2 checkpoint.
    /// Every projection is stored in its *native* representation (packed
    /// quantized buffers included), so reloading never re-runs compression
    /// or requantization. `plan` records the compression-plan provenance in
    /// the header.
    pub fn save_compressed(&self, path: &Path, plan: Option<&str>) -> anyhow::Result<()> {
        let mut sw = SectionWriter::default();
        sw.add_f32("embed", self.embed.data());
        sw.add_f32("lm_head", self.lm_head.data());
        sw.add_f32("final_norm", &self.final_norm);
        let mut stages = Vec::with_capacity(self.stages.len());
        for (i, stage) in self.stages.iter().enumerate() {
            let mut sj = Json::obj();
            match stage {
                Stage::Block(b) => {
                    sj.set("kind", "block".into())
                        .set("n_heads", b.n_heads.into())
                        .set("n_kv_heads", b.n_kv_heads.into());
                    sw.add_f32(&format!("stages.{i}.attn_norm"), &b.attn_norm);
                    sw.add_f32(&format!("stages.{i}.mlp_norm"), &b.mlp_norm);
                    let mut projs = Json::obj();
                    for p in ProjKind::DECODER_SET {
                        let base = format!("stages.{i}.{}", p.group());
                        projs.set(p.group(), write_weight(&mut sw, &base, b.proj(p)));
                    }
                    sj.set("projections", projs);
                }
                Stage::Linear(t) => {
                    sj.set("kind", "linear".into())
                        .set("rows", t.rows().into())
                        .set("cols", t.cols().into());
                    sw.add_f32(&format!("stages.{i}.linear"), t.data());
                }
            }
            stages.push(sj);
        }
        let (records, payload) = sw.finish();
        let mut header = Json::obj();
        header
            .set("version", VERSION.into())
            .set("config", self.cfg.to_json())
            .set("align", ALIGN.into())
            .set("sections", Json::Arr(records))
            .set("stages", Json::Arr(stages));
        if let Some(p) = plan {
            header.set("plan", p.into());
        }
        let header_bytes = header.to_string().into_bytes();
        let data_start = align_up(8 + header_bytes.len(), ALIGN);

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
        f.write_all(&header_bytes)?;
        f.write_all(&vec![0u8; data_start - 8 - header_bytes.len()])?;
        f.write_all(&payload)?;
        // Flush explicitly: the drop-time flush swallows errors, and a
        // silently truncated checkpoint (disk full) must not report Ok.
        f.flush()?;
        Ok(())
    }

    /// Load a CPT2 checkpoint. Returns the model plus what the checkpoint
    /// recorded about its origin. No compression stage runs; packed
    /// quantized buffers are read back verbatim.
    pub fn load_compressed(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?} (not a CPT2 checkpoint)");
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as u64;
        // Validate the header length against the actual file size *before*
        // allocating — a corrupt length must not drive a huge allocation.
        anyhow::ensure!(
            8 + hlen <= file_len,
            "header length {hlen} exceeds file size {file_len} — truncated checkpoint"
        );
        let mut hbytes = vec![0u8; hlen as usize];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)
            .map_err(|e| anyhow::anyhow!("bad checkpoint header json: {e}"))?;
        let version = header.get("version").and_then(Json::as_usize).unwrap_or(0);
        anyhow::ensure!(
            version == VERSION,
            "unsupported CPT2 version {version} (this build reads version {VERSION})"
        );
        let cfg = ModelConfig::from_json(
            header.get("config").ok_or_else(|| anyhow::anyhow!("checkpoint has no config"))?,
        )?;
        // head_dim() divides by n_heads — reject a config that would panic.
        anyhow::ensure!(
            cfg.n_heads >= 1 && cfg.d_model >= 1 && cfg.d_model % cfg.n_heads == 0,
            "checkpoint config has invalid head geometry (d_model {}, n_heads {})",
            cfg.d_model,
            cfg.n_heads
        );
        let plan = header.get("plan").and_then(Json::as_str).map(String::from);

        let data_start = align_up(8 + hlen as usize, ALIGN) as u64;
        anyhow::ensure!(data_start <= file_len, "truncated checkpoint (no data region)");
        // Seek past the alignment pad, then pull the data region. The region
        // is bounded by the real file size, so section bounds checked
        // against `data.len()` are checked against reality.
        f.seek(std::io::SeekFrom::Start(data_start))?;
        let mut data = Vec::with_capacity((file_len - data_start) as usize);
        f.read_to_end(&mut data)?;
        let sr = SectionReader::new(&header, &data)?;

        let d = cfg.d_model;
        let embed = sr.mat("embed", cfg.vocab, d)?;
        let lm_head = sr.mat("lm_head", d, cfg.vocab)?;
        let final_norm = sr.f32s("final_norm", d)?;
        let mut stages = Vec::new();
        for (i, sj) in header
            .get("stages")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'stages' array"))?
            .iter()
            .enumerate()
        {
            match sj.get("kind").and_then(Json::as_str) {
                Some("block") => {
                    let n_heads = sj
                        .get("n_heads")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_heads"))?;
                    let n_kv_heads = sj
                        .get("n_kv_heads")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_kv_heads"))?;
                    anyhow::ensure!(
                        n_kv_heads >= 1 && n_heads >= n_kv_heads && n_heads % n_kv_heads == 0,
                        "stage {i}: invalid head counts {n_heads}/{n_kv_heads}"
                    );
                    let projs = sj
                        .get("projections")
                        .ok_or_else(|| anyhow::anyhow!("stage {i}: missing projections"))?;
                    let get = |p: ProjKind| -> anyhow::Result<LinearWeight> {
                        let base = format!("stages.{i}.{}", p.group());
                        let meta = projs.get(p.group()).ok_or_else(|| {
                            anyhow::anyhow!("stage {i}: missing projection '{}'", p.group())
                        })?;
                        read_weight(&sr, &base, meta)
                    };
                    let block = Block {
                        attn_norm: sr.f32s(&format!("stages.{i}.attn_norm"), d)?,
                        q: get(ProjKind::Q)?,
                        k: get(ProjKind::K)?,
                        v: get(ProjKind::V)?,
                        o: get(ProjKind::O)?,
                        mlp_norm: sr.f32s(&format!("stages.{i}.mlp_norm"), d)?,
                        gate: get(ProjKind::Gate)?,
                        up: get(ProjKind::Up)?,
                        down: get(ProjKind::Down)?,
                        n_heads,
                        n_kv_heads,
                    };
                    validate_block_shapes(i, &block, d, cfg.head_dim())?;
                    stages.push(Stage::Block(block));
                }
                Some("linear") => {
                    let rows = sj
                        .get("rows")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("stage {i}: missing rows"))?;
                    let cols = sj
                        .get("cols")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("stage {i}: missing cols"))?;
                    anyhow::ensure!(
                        rows == d && cols == d,
                        "stage {i}: linear shape {rows}x{cols} does not preserve the \
                         d={d} residual stream"
                    );
                    stages.push(Stage::Linear(sr.mat(&format!("stages.{i}.linear"), rows, cols)?));
                }
                other => anyhow::bail!("stage {i}: unknown stage kind {other:?}"),
            }
        }
        let model = Model { cfg, embed, stages, final_norm, lm_head };
        Ok((model, CheckpointInfo { format: "cpt2", plan }))
    }

    /// Versioned checkpoint entry point: sniffs the magic and loads either
    /// the dense `CPT1` tensor format or a `CPT2` compressed checkpoint.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        drop(f);
        if &magic == MAGIC {
            Self::load_compressed(path)
        } else if &magic == super::weights::MAGIC {
            let model = Self::from_tensor_file(&TensorFile::load(path)?)?;
            Ok((model, CheckpointInfo { format: "cpt1", plan: None }))
        } else {
            anyhow::bail!(
                "{path:?}: unknown checkpoint magic {magic:?} (expected CPT1 or CPT2)"
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::StageConfig;
    use crate::coordinator::plan::CompressionPlan;
    use crate::data::SynthLang;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("compot_cpt2_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny() -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(11))
    }

    fn compressed(spec: &str) -> Model {
        let model = tiny();
        let lang = SynthLang::wiki(model.cfg.vocab);
        let calib = lang.gen_batch(6, 48, &mut Rng::new(12));
        let plan = CompressionPlan::parse(spec, &StageConfig::new(0.25, false)).unwrap();
        plan.run(&model, &calib).unwrap().0
    }

    fn assert_identical(a: &Model, b: &Model) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.resident_weight_bytes(), b.resident_weight_bytes());
        assert_eq!(a.storage_bits(), b.storage_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        // bit-identical buffers, variant included
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind changed across the round trip"),
            }
        }
        let prompt = [1u16, 2, 3, 4];
        assert_eq!(a.greedy_decode(&prompt, 8), b.greedy_decode(&prompt, 8));
    }

    #[test]
    fn dense_model_roundtrip() {
        let m = tiny();
        let path = tmp("dense.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, info) = Model::load_compressed(&path).unwrap();
        assert_eq!(info.format, "cpt2");
        assert!(info.plan.is_none());
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn every_compressed_variant_roundtrips_bit_identically() {
        // One plan per LinearWeight variant the pipeline can emit:
        // LowRank, Factorized, QuantDense, QuantLowRank, QuantFactorized.
        for (spec, name) in [
            ("svd-llm@0.2", "lowrank"),
            ("compot@0.25", "factorized"),
            ("rtn4", "quant_dense"),
            ("svd-llm@0.2+rtn4", "quant_lowrank"),
            ("compot@0.25+gptq4", "quant_factorized"),
        ] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            let (back, info) = Model::load_checkpoint(&path).unwrap();
            assert_eq!(info.plan.as_deref(), Some(spec), "{spec}");
            assert_identical(&m, &back);
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn linear_stage_roundtrips() {
        let mut m = tiny();
        let d = m.cfg.d_model;
        m.stages[1] = Stage::Linear(Mat::randn(&mut Rng::new(13), d, d, 0.2));
        let path = tmp("linear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, _) = Model::load_compressed(&path).unwrap();
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cpt1_loads_through_the_versioned_entry_point() {
        let m = tiny();
        let path = tmp("old.cpt1");
        m.save(&path).unwrap();
        let (back, info) = Model::load_checkpoint(&path).unwrap();
        assert_eq!(info.format, "cpt1");
        let prompt = [3u16, 1, 4];
        assert_eq!(m.greedy_decode(&prompt, 6), back.greedy_decode(&prompt, 6));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_is_an_error() {
        let path = tmp("junk.cpt2");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00rest of the junk").unwrap();
        assert!(Model::load_compressed(&path).is_err());
        let err = Model::load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("unknown checkpoint magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_header_and_sections_are_errors() {
        let m = tiny();
        let path = tmp("trunc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let full = std::fs::read(&path).unwrap();

        // header length field claims more bytes than the file has
        let mut huge = full.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &huge).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // file cut inside the header
        std::fs::write(&path, &full[..64]).unwrap();
        assert!(Model::load_compressed(&path).is_err());

        // file cut inside the section payloads: bounds check, no panic
        std::fs::write(&path, &full[..full.len() - 97]).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("runs past the data region") || err.contains("crc mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let m = compressed("rtn4");
        let path = tmp("crc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff; // flip a bit in the last section's payload
        std::fs::write(&path, &bytes).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    fn mangle_header(path: &Path, from: &str, to: &str) {
        // Same-length textual header edits keep offsets valid so the
        // specific validator under test is the one that fires.
        assert_eq!(from.len(), to.len());
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = String::from_utf8(bytes[8..8 + hlen].to_vec()).unwrap();
        assert!(header.contains(from), "header does not contain '{from}'");
        let patched = header.replacen(from, to, 1);
        let mut out = bytes[..8].to_vec();
        out.extend_from_slice(patched.as_bytes());
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(path, &out).unwrap();
    }

    #[test]
    fn unknown_variant_tag_is_an_error() {
        let m = compressed("rtn4");
        let path = tmp("variant.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"quant_dense\"", "\"quant_blorp\"");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unknown variant tag"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bits_outside_packable_range_are_errors() {
        let m = compressed("rtn4");
        let path = tmp("bits.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"bits\":4", "\"bits\":9");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("2..=8"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_section_length_mismatch_is_an_error() {
        let m = tiny();
        let path = tmp("mismatch.cpt2");
        m.save_compressed(&path, None).unwrap();
        // final_norm has d_model = 32 elements; claim 64 → the recorded CRC
        // no longer matches the (bounds-checked, never-trusted) enlarged
        // range, or the range runs past the data region.
        mangle_header(
            &path,
            "\"len\":32,\"name\":\"final_norm\"",
            "\"len\":64,\"name\":\"final_norm\"",
        );
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("final_norm"),
            "mismatch must be caught on the named section: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn structurally_inconsistent_shapes_are_rejected() {
        // Per-tensor shapes can be internally consistent (sections + CRCs
        // valid) while describing a block the forward pass would panic on:
        // the loader must reject it, never defer the panic to serve time.
        let mut m = tiny();
        let d = m.cfg.d_model;
        if let Stage::Block(b) = &mut m.stages[0] {
            // 24 ≠ n_heads · head_dim for test-tiny
            b.q = LinearWeight::Dense(Mat::zeros(d, 24));
        }
        let path = tmp("badshape.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("structural contract"), "{err}");
        std::fs::remove_file(&path).ok();

        // A linear stage that changes the residual width is rejected too.
        let mut m = tiny();
        m.stages[1] = Stage::Linear(Mat::zeros(d, d + 1));
        let path = tmp("badlinear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("residual stream"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn future_version_is_rejected() {
        let m = tiny();
        let path = tmp("version.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"version\":2", "\"version\":7");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported CPT2 version"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }
}
