//! `CPT2` — the compressed-checkpoint format: every [`LinearWeight`]
//! variant serialized *natively*, so a compressed (and possibly packed-
//! quantized) model reloads in one pass with **zero recompression and zero
//! requantization**. The factorization is the deployable artifact
//! (CoSpaDi/ProcrustesGPT); this module makes it durable.
//!
//! Layout:
//! ```text
//! b"CPT2" | u32 header_len | header JSON (utf-8)
//!         | zero pad to ALIGN | section payloads (LE, each ALIGN-aligned)
//! ```
//!
//! The header carries `{"version", "config", "plan"?, "align", "sections",
//! "stages"}`. Each section record is `{"name", "dtype": "f32"|"u32"|"u16",
//! "len", "offset", "crc32"}` with `offset` in bytes from the start of the
//! (aligned) data region — so a loader can `read_exact`/`mmap` a section
//! straight into its resident buffer. Each stage entry tags its projections
//! with a variant (`dense`, `low_rank`, `factorized`, `quant_dense`,
//! `quant_low_rank`, `quant_factorized`), shapes, and bit widths; the
//! quantized variants reference raw u32 code-word and u16 f16-scale
//! sections that are byte-for-byte the in-memory [`QuantMat`] buffers.
//! Each packed tensor additionally carries a physical-layout tag
//! (`layout` / `layout_b` / `layout_c` / `layout_a` / `layout_val`:
//! `"row_seq"` or `"planar"`). The tag is **absent** in checkpoints written
//! before the code-planar storage rework, and an absent tag means the
//! legacy row-sequential stream — old checkpoints keep loading through the
//! legacy unpack path with zero conversion, while new saves record the
//! layout the buffers are actually in (`compot info` prints it).
//!
//! Every field read from disk is validated against the actual file size
//! before any allocation, every section payload is CRC32-checked (lazily,
//! per section, as each buffer is materialized), and every reconstruction
//! goes through the fallible `from_raw_parts` constructors — a corrupt or
//! adversarial checkpoint yields an error, never a panic or a huge
//! allocation.
//!
//! Two load paths share one stage-walking body: the copying loader
//! ([`Model::load_compressed`], owned buffers) and the zero-copy loader
//! ([`MappedCheckpoint`] / [`Model::load_compressed_mmap`]), which maps
//! the file once and hands every weight a [`WeightBuf`] view into the
//! 64-B-aligned section payloads — no decode, no copy, page cache shared
//! across serve workers.
//!
//! [`Model::load_checkpoint`] is the versioned entry point: it sniffs the
//! magic and accepts both the dense `CPT1` tensor format
//! ([`super::weights`]) and `CPT2`.

use super::config::ProjKind;
use super::shard::{self, ShardEntry, ShardManifest};
use super::transformer::{Block, Model, Stage};
use super::weights::TensorFile;
use crate::compress::sparse::{ColumnSparse, QuantColumnSparse};
use crate::compress::LinearWeight;
use crate::linalg::buf::{Advice, Mapping, Pod, WeightBuf};
use crate::linalg::qmat::{supported_group, GROUP};
use crate::linalg::{Mat, QuantLayout, QuantMat};
use crate::model::config::ModelConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Seek, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

pub const MAGIC: &[u8; 4] = b"CPT2";
pub const VERSION: usize = 2;
/// Section payload alignment (bytes) — sized for cache lines and for the
/// zero-copy loader: every section's absolute file offset is a multiple of
/// ALIGN, so a page-aligned mapping yields views aligned for f32/u32/u16.
pub const ALIGN: usize = 64;

/// What a checkpoint said about itself — surfaced by `serve`'s info
/// response so operators can tell a cold-loaded artifact from an in-process
/// compression run.
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    /// `"cpt1"` or `"cpt2"`.
    pub format: &'static str,
    /// Compression-plan provenance recorded at save time (CPT2 only).
    pub plan: Option<String>,
    /// Where the weight buffers live: `"owned"` (copied into heap
    /// allocations), `"mmap"` (zero-copy views into a shared file
    /// mapping), or `"mmap-fallback"` (an mmap load on a host/filesystem
    /// without mmap support — views into one private aligned heap read, so
    /// no page sharing across workers).
    pub source: &'static str,
}

/// Byte-at-a-time CRC32 lookup table, built at compile time. The table
/// version runs ~8× faster than the bitwise loop — checksumming must not
/// become the cold-load bottleneck this format exists to remove.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut b = 0;
        while b < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ 0xedb8_8320 } else { c >> 1 };
            b += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE, reflected) of a byte slice — in-tree, no crc crate in this
/// offline env.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

fn align_up(x: usize, a: usize) -> usize {
    x.div_ceil(a) * a
}

// ---------------------------------------------------------------------------
// Section writer.
// ---------------------------------------------------------------------------

struct PendingSection {
    name: String,
    dtype: &'static str,
    len: usize,
    bytes: Vec<u8>,
}

#[derive(Default)]
struct SectionWriter {
    sections: Vec<PendingSection>,
}

impl SectionWriter {
    fn add(&mut self, name: &str, dtype: &'static str, len: usize, bytes: Vec<u8>) {
        self.sections.push(PendingSection { name: name.to_string(), dtype, len, bytes });
    }

    fn add_f32(&mut self, name: &str, vals: &[f32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "f32", vals.len(), b);
    }

    fn add_u32(&mut self, name: &str, vals: &[u32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u32", vals.len(), b);
    }

    fn add_u16(&mut self, name: &str, vals: &[u16]) {
        let mut b = Vec::with_capacity(2 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u16", vals.len(), b);
    }

    /// Lay the sections out ALIGN-aligned; returns (section records, payload).
    fn finish(self) -> (Vec<Json>, Vec<u8>) {
        let mut records = Vec::with_capacity(self.sections.len());
        let mut payload: Vec<u8> = Vec::new();
        for s in self.sections {
            let offset = align_up(payload.len(), ALIGN);
            payload.resize(offset, 0);
            let mut rec = Json::obj();
            rec.set("name", s.name.as_str().into())
                .set("dtype", s.dtype.into())
                .set("len", s.len.into())
                .set("offset", offset.into())
                .set("crc32", (crc32(&s.bytes) as usize).into());
            records.push(rec);
            payload.extend_from_slice(&s.bytes);
        }
        (records, payload)
    }
}

// ---------------------------------------------------------------------------
// Section reader — one record table, two payload sources.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct SectionDesc {
    dtype_size: usize,
    len: usize,
    offset: usize,
    crc32: u32,
}

/// Where section bytes come from: the copying loader's in-memory data
/// region, or a shared file [`Mapping`] whose data region starts at `start`
/// (zero-copy — accessors hand out [`WeightBuf`] views into it).
enum Payload {
    Copied(Vec<u8>),
    Mapped { map: Arc<Mapping>, start: usize },
}

struct SectionReader {
    payload: Payload,
    by_name: BTreeMap<String, (SectionDesc, &'static str)>,
}

impl SectionReader {
    /// Parse and bounds-check the section table against the real data-region
    /// size. CRCs are **not** checked here — each section is checksummed
    /// lazily, the first (and only) time an accessor materializes it. That
    /// keeps header-only opens ([`MappedCheckpoint::open`], `compot info`)
    /// free of any payload I/O.
    fn new(header: &Json, payload: Payload) -> anyhow::Result<SectionReader> {
        let region_len = match &payload {
            Payload::Copied(data) => data.len(),
            Payload::Mapped { map, start } => map.len().saturating_sub(*start),
        };
        let mut by_name = BTreeMap::new();
        for rec in header
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'sections' array"))?
        {
            let name = rec
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("section record without a name"))?;
            let (dtype, size): (&'static str, usize) =
                match rec.get("dtype").and_then(Json::as_str) {
                    Some("f32") => ("f32", 4),
                    Some("u32") => ("u32", 4),
                    Some("u16") => ("u16", 2),
                    other => anyhow::bail!("section '{name}': unknown dtype {other:?}"),
                };
            let len = rec
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing len"))?;
            let offset = rec
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing offset"))?;
            let byte_len = len
                .checked_mul(size)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': length overflows"))?;
            let end = offset
                .checked_add(byte_len)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': offset overflows"))?;
            anyhow::ensure!(
                end <= region_len,
                "section '{name}' ({len}×{size} B at offset {offset}) runs past the data \
                 region ({region_len} B) — truncated or corrupt checkpoint"
            );
            let want_crc = rec
                .get("crc32")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing crc32"))?;
            by_name.insert(
                name.to_string(),
                (SectionDesc { dtype_size: size, len, offset, crc32: want_crc as u32 }, dtype),
            );
        }
        Ok(SectionReader { payload, by_name })
    }

    fn desc(&self, name: &str, dtype: &str, expect_len: usize) -> anyhow::Result<SectionDesc> {
        let (desc, have_dtype) = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing section '{name}'"))?;
        anyhow::ensure!(
            *have_dtype == dtype,
            "section '{name}': dtype {have_dtype}, expected {dtype}"
        );
        anyhow::ensure!(
            desc.len == expect_len,
            "section '{name}': {} elements on disk, header metadata implies {expect_len}",
            desc.len
        );
        Ok(*desc)
    }

    fn region(&self) -> &[u8] {
        match &self.payload {
            Payload::Copied(data) => data,
            Payload::Mapped { map, start } => &map.bytes()[*start..],
        }
    }

    /// Materialize one section as a [`WeightBuf`]: CRC-check its bytes
    /// (lazy — this is the first time anything reads the payload), then
    /// either decode into an owned vector (copy source) or hand out an
    /// aligned zero-copy view (mapped source).
    fn buf<T: Pod>(&self, name: &str, expect_len: usize) -> anyhow::Result<WeightBuf<T>> {
        let d = self.desc(name, T::DTYPE, expect_len)?;
        // Build the view first so a misaligned offset reports as the
        // structural error it is, not as the checksum mismatch the shifted
        // bytes would also produce.
        let buf = match &self.payload {
            Payload::Copied(_) => None,
            Payload::Mapped { map, start } => Some(
                WeightBuf::view(map, start + d.offset, d.len)
                    .map_err(|e| anyhow::anyhow!("section '{name}': {e}"))?,
            ),
        };
        // The CRC pass streams the section's pages front-to-back exactly
        // once — tell the kernel so readahead runs ahead of the checksum
        // loop, then drop back to normal (decode-time access is random).
        if let Payload::Mapped { map, start } = &self.payload {
            map.advise(start + d.offset, d.len * d.dtype_size, Advice::Sequential);
        }
        let raw = &self.region()[d.offset..d.offset + d.len * d.dtype_size];
        let got = crc32(raw);
        if let Payload::Mapped { map, start } = &self.payload {
            map.advise(start + d.offset, d.len * d.dtype_size, Advice::Normal);
        }
        anyhow::ensure!(
            got == d.crc32,
            "section '{name}': crc mismatch (header {:#x}, payload {got:#x})",
            d.crc32
        );
        match buf {
            Some(view) => Ok(view),
            None => Ok(raw
                .chunks_exact(std::mem::size_of::<T>())
                .map(T::from_le_bytes)
                .collect::<Vec<T>>()
                .into()),
        }
    }

    /// Small vectors (norm gains) always materialize owned — they are a few
    /// hundred bytes and the forward pass stores them as `Vec<f32>`.
    fn vec_f32(&self, name: &str, expect_len: usize) -> anyhow::Result<Vec<f32>> {
        Ok(self.buf::<f32>(name, expect_len)?.into_vec())
    }

    fn mat(&self, name: &str, rows: usize, cols: usize) -> anyhow::Result<Mat> {
        let len = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("section '{name}': {rows}x{cols} overflows"))?;
        Mat::from_buf(rows, cols, self.buf::<f32>(name, len)?)
    }

    /// `bits`/`group`/`layout` are pre-validated by
    /// `meta_bits`/`meta_group`/`meta_layout` (projection-named errors);
    /// `QuantMat::from_raw_parts` re-checks them as the fallible constructor
    /// every path funnels through. The layout decides the expected code-word
    /// count — a header that tags a planar tensor but ships a legacy-sized
    /// section (or vice versa) fails the length check by name.
    fn qmat(
        &self,
        base: &str,
        rows: usize,
        cols: usize,
        bits: u32,
        group: usize,
        layout: QuantLayout,
    ) -> anyhow::Result<QuantMat> {
        let np = QuantMat::packed_len_layout(rows, cols, bits, group, layout).ok_or_else(|| {
            anyhow::anyhow!("'{base}': invalid packed geometry {rows}x{cols} @{bits}b g{group}")
        })?;
        let ns = QuantMat::scales_len_grouped(rows, cols, group)
            .ok_or_else(|| anyhow::anyhow!("'{base}': {rows}x{cols} overflows"))?;
        let packed = self.buf::<u32>(&format!("{base}.codes"), np)?;
        let scales = self.buf::<u16>(&format!("{base}.scales"), ns)?;
        QuantMat::from_raw_parts(rows, cols, bits, group, layout, packed, scales)
    }
}

// ---------------------------------------------------------------------------
// LinearWeight ⇄ sections.
// ---------------------------------------------------------------------------

fn write_qmat(sw: &mut SectionWriter, base: &str, q: &QuantMat) {
    sw.add_u32(&format!("{base}.codes"), q.packed_words());
    sw.add_u16(&format!("{base}.scales"), q.scale_bits());
}

/// Serialize one projection under `base`, returning its header metadata.
fn write_weight(sw: &mut SectionWriter, base: &str, w: &LinearWeight) -> Json {
    let mut meta = Json::obj();
    match w {
        LinearWeight::Dense(m) => {
            meta.set("variant", "dense".into())
                .set("rows", m.rows().into())
                .set("cols", m.cols().into());
            sw.add_f32(&format!("{base}.w"), m.data());
        }
        LinearWeight::LowRank { b, c } => {
            meta.set("variant", "low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into());
            sw.add_f32(&format!("{base}.b"), b.data());
            sw.add_f32(&format!("{base}.c"), c.data());
        }
        LinearWeight::Factorized { a, s } => {
            meta.set("variant", "factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into());
            sw.add_f32(&format!("{base}.a"), a.data());
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            sw.add_f32(&format!("{base}.s.val"), s.values());
        }
        LinearWeight::QuantDense(q) => {
            meta.set("variant", "quant_dense".into())
                .set("rows", q.rows().into())
                .set("cols", q.cols().into())
                .set("bits", (q.bits() as usize).into())
                .set("group", q.group().into())
                .set("layout", q.layout().as_str().into());
            write_qmat(sw, &format!("{base}.w"), q);
        }
        LinearWeight::QuantLowRank { b, c } => {
            meta.set("variant", "quant_low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into())
                .set("bits_b", (b.bits() as usize).into())
                .set("bits_c", (c.bits() as usize).into())
                .set("group_b", b.group().into())
                .set("group_c", c.group().into())
                .set("layout_b", b.layout().as_str().into())
                .set("layout_c", c.layout().as_str().into());
            write_qmat(sw, &format!("{base}.b"), b);
            write_qmat(sw, &format!("{base}.c"), c);
        }
        LinearWeight::QuantFactorized { a, s } => {
            let v = s.values_qmat();
            meta.set("variant", "quant_factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into())
                .set("bits_a", (a.bits() as usize).into())
                .set("bits_val", (v.bits() as usize).into())
                .set("group_a", a.group().into())
                .set("group_val", v.group().into())
                .set("layout_a", a.layout().as_str().into())
                .set("layout_val", v.layout().as_str().into());
            write_qmat(sw, &format!("{base}.a"), a);
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            write_qmat(sw, &format!("{base}.s.val"), v);
        }
    }
    meta
}

fn meta_usize(meta: &Json, base: &str, key: &str) -> anyhow::Result<usize> {
    meta.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing field '{key}'"))
}

fn meta_bits(meta: &Json, base: &str, key: &str) -> anyhow::Result<u32> {
    let b = meta_usize(meta, base, key)?;
    anyhow::ensure!(
        (2..=8).contains(&b),
        "projection '{base}': {key}={b} outside the packable 2..=8 range"
    );
    Ok(b as u32)
}

/// Quantization group size for one packed tensor. Absent (pre-group-sweep
/// checkpoints) defaults to [`GROUP`]; present values are validated here so
/// the error names the projection.
fn meta_group(meta: &Json, base: &str, key: &str) -> anyhow::Result<usize> {
    let g = match meta.get(key) {
        None => return Ok(GROUP),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("projection '{base}': bad field '{key}'"))?,
    };
    anyhow::ensure!(
        supported_group(g),
        "projection '{base}': {key}={g} is not a supported quantization group size"
    );
    Ok(g)
}

/// Physical code layout for one packed tensor. Absent (checkpoints written
/// before the code-planar storage rework) means the legacy row-sequential
/// stream; present values are validated here so the error names the
/// projection.
fn meta_layout(meta: &Json, base: &str, key: &str) -> anyhow::Result<QuantLayout> {
    match meta.get(key) {
        None => Ok(QuantLayout::RowSeq),
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': bad field '{key}'"))?;
            QuantLayout::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "projection '{base}': {key}='{s}' is not a known quantized layout"
                )
            })
        }
    }
}

/// Reconstruct one projection from its header metadata + sections.
fn read_weight(sr: &SectionReader, base: &str, meta: &Json) -> anyhow::Result<LinearWeight> {
    let variant = meta
        .get("variant")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing variant tag"))?;
    match variant {
        "dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            Ok(LinearWeight::Dense(sr.mat(&format!("{base}.w"), rows, cols)?))
        }
        "low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::LowRank {
                b: sr.mat(&format!("{base}.b"), m, r)?,
                c: sr.mat(&format!("{base}.c"), r, n)?,
            })
        }
        "factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.buf::<u32>(&format!("{base}.s.idx"), ns)?;
            let val = sr.buf::<f32>(&format!("{base}.s.val"), ns)?;
            Ok(LinearWeight::Factorized {
                a: sr.mat(&format!("{base}.a"), m, k)?,
                s: ColumnSparse::from_raw_parts(k, n, s, idx, val)?,
            })
        }
        "quant_dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            let bits = meta_bits(meta, base, "bits")?;
            let group = meta_group(meta, base, "group")?;
            let layout = meta_layout(meta, base, "layout")?;
            Ok(LinearWeight::QuantDense(sr.qmat(
                &format!("{base}.w"),
                rows,
                cols,
                bits,
                group,
                layout,
            )?))
        }
        "quant_low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::QuantLowRank {
                b: sr.qmat(
                    &format!("{base}.b"),
                    m,
                    r,
                    meta_bits(meta, base, "bits_b")?,
                    meta_group(meta, base, "group_b")?,
                    meta_layout(meta, base, "layout_b")?,
                )?,
                c: sr.qmat(
                    &format!("{base}.c"),
                    r,
                    n,
                    meta_bits(meta, base, "bits_c")?,
                    meta_group(meta, base, "group_c")?,
                    meta_layout(meta, base, "layout_c")?,
                )?,
            })
        }
        "quant_factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.buf::<u32>(&format!("{base}.s.idx"), ns)?;
            let val = sr.qmat(
                &format!("{base}.s.val"),
                n,
                s,
                meta_bits(meta, base, "bits_val")?,
                meta_group(meta, base, "group_val")?,
                meta_layout(meta, base, "layout_val")?,
            )?;
            Ok(LinearWeight::QuantFactorized {
                a: sr.qmat(
                    &format!("{base}.a"),
                    m,
                    k,
                    meta_bits(meta, base, "bits_a")?,
                    meta_group(meta, base, "group_a")?,
                    meta_layout(meta, base, "layout_a")?,
                )?,
                s: QuantColumnSparse::from_raw_parts(k, idx, val)?,
            })
        }
        other => anyhow::bail!("projection '{base}': unknown variant tag '{other}'"),
    }
}

/// Structural contract the forward pass will index into: a CRC-valid
/// checkpoint whose per-tensor shapes are internally consistent could still
/// describe a block the attention/MLP code would panic on. Head widths are
/// per-block (structured pruning shrinks them) but must agree with the
/// config's global head_dim; the MLP hidden width is free (channel pruning)
/// but gate/up/down must agree with each other.
fn validate_block_shapes(i: usize, b: &Block, d: usize, head_dim: usize) -> anyhow::Result<()> {
    let check = |name: &str, got: (usize, usize), want: (usize, usize)| -> anyhow::Result<()> {
        anyhow::ensure!(
            got == want,
            "stage {i}: {name} shape {}x{} does not match the structural contract {}x{}",
            got.0,
            got.1,
            want.0,
            want.1
        );
        Ok(())
    };
    // Head counts come from the header: checked arithmetic, like every
    // other untrusted multiplication in this module.
    let qw = b
        .n_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_heads·head_dim overflows"))?;
    let kvw = b
        .n_kv_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_kv_heads·head_dim overflows"))?;
    check("q_proj", (b.q.in_dim(), b.q.out_dim()), (d, qw))?;
    check("k_proj", (b.k.in_dim(), b.k.out_dim()), (d, kvw))?;
    check("v_proj", (b.v.in_dim(), b.v.out_dim()), (d, kvw))?;
    check("o_proj", (b.o.in_dim(), b.o.out_dim()), (qw, d))?;
    let ff = b.gate.out_dim();
    check("gate_proj", (b.gate.in_dim(), ff), (d, ff))?;
    check("up_proj", (b.up.in_dim(), b.up.out_dim()), (d, ff))?;
    check("down_proj", (b.down.in_dim(), b.down.out_dim()), (ff, d))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Model save / load.
// ---------------------------------------------------------------------------

impl Model {
    /// Serialize this model — compressed or not — as a CPT2 checkpoint.
    /// Every projection is stored in its *native* representation (packed
    /// quantized buffers included), so reloading never re-runs compression
    /// or requantization. `plan` records the compression-plan provenance in
    /// the header.
    pub fn save_compressed(&self, path: &Path, plan: Option<&str>) -> anyhow::Result<()> {
        let mut sw = SectionWriter::default();
        sw.add_f32("embed", self.embed.data());
        sw.add_f32("lm_head", self.lm_head.data());
        sw.add_f32("final_norm", &self.final_norm);
        let mut stages = Vec::with_capacity(self.stages.len());
        for (i, stage) in self.stages.iter().enumerate() {
            stages.push(write_stage_sections(&mut sw, i, stage));
        }
        let (records, payload) = sw.finish();
        let mut header = base_header(&self.cfg, plan);
        header.set("sections", Json::Arr(records)).set("stages", Json::Arr(stages));
        write_container(path, &header, &payload)?;
        Ok(())
    }

    /// Serialize this model as a **sharded** CPT2 checkpoint: `n_shards`
    /// shard files beside `path`, each a complete CPT2 container holding a
    /// contiguous stage range (shard 0 additionally carries `embed`, the
    /// last shard `lm_head` + `final_norm`), plus the **index** file at
    /// `path` — a CPT2 container with an empty data region whose header
    /// records the full stage metadata and the shard manifest
    /// (`{id, relative path, stage range, header crc}` per shard). A
    /// pipeline stage later pages in only its shards via
    /// [`MappedCheckpoint::load_stage_range`], while `compot info` on the
    /// index stays header-only and never opens a shard file.
    pub fn save_compressed_sharded(
        &self,
        path: &Path,
        plan: Option<&str>,
        n_shards: usize,
    ) -> anyhow::Result<()> {
        let ranges = shard::split_ranges(self.stages.len(), n_shards)?;
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| anyhow::anyhow!("sharded save needs a utf-8 file name: {path:?}"))?;
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        let last = ranges.len() - 1;
        let mut entries = Vec::with_capacity(ranges.len());
        let mut all_stages = Vec::with_capacity(self.stages.len());
        for (id, &(lo, hi)) in ranges.iter().enumerate() {
            let mut sw = SectionWriter::default();
            if id == 0 {
                sw.add_f32("embed", self.embed.data());
            }
            if id == last {
                sw.add_f32("lm_head", self.lm_head.data());
                sw.add_f32("final_norm", &self.final_norm);
            }
            // Section names keep their *absolute* stage indices, so a
            // shard's sections are exactly the subset the single-file save
            // would have written for those stages.
            let mut metas = Vec::with_capacity(hi - lo);
            for i in lo..hi {
                metas.push(write_stage_sections(&mut sw, i, &self.stages[i]));
            }
            let (records, payload) = sw.finish();
            let mut header = base_header(&self.cfg, plan);
            let mut marker = Json::obj();
            marker.set("id", id.into()).set("lo", lo.into()).set("hi", hi.into());
            header
                .set("shard", marker)
                .set("sections", Json::Arr(records))
                .set("stages", Json::Arr(metas.clone()));
            let rel = shard::shard_file_name(file_name, id);
            let crc = write_container(&dir.join(&rel), &header, &payload)?;
            entries.push(ShardEntry { id, path: rel, lo, hi, crc });
            all_stages.extend(metas);
        }
        let manifest = ShardManifest { entries };
        let mut header = base_header(&self.cfg, plan);
        header
            .set("shards", manifest.to_json())
            .set("sections", Json::Arr(Vec::new()))
            .set("stages", Json::Arr(all_stages));
        write_container(path, &header, &[])?;
        Ok(())
    }

    /// Load a CPT2 checkpoint through the **copying** path: every section
    /// is decoded into freshly allocated owned buffers. Returns the model
    /// plus what the checkpoint recorded about its origin. No compression
    /// stage runs; packed quantized buffers are read back verbatim.
    pub fn load_compressed(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let (header, data_start, file_len) = read_header(&mut f, path)?;
        let (cfg, plan) = validate_header(&header)?;
        let n = stage_count(&header);
        if let Some(manifest) = ShardManifest::from_header(&header, n)? {
            // Sharded index: the real sections live in the shard files.
            drop(f);
            let dir = path.parent().unwrap_or_else(|| Path::new("."));
            return read_model_sharded(dir, &cfg, &header, &manifest, &(0..n), plan, false);
        }
        // Seek past the alignment pad, then pull the data region. The region
        // is bounded by the real file size, so section bounds checked
        // against its length are checked against reality.
        f.seek(std::io::SeekFrom::Start(data_start))?;
        let mut data = Vec::with_capacity((file_len - data_start) as usize);
        f.read_to_end(&mut data)?;
        let sr = SectionReader::new(&header, Payload::Copied(data))?;
        let model = read_model(cfg, &header, &sr)?;
        Ok((model, CheckpointInfo { format: "cpt2", plan, source: "owned" }))
    }

    /// Load only the stages in `range` as a **partial** model — the storage
    /// half of pipeline serving. On a sharded checkpoint, only the shards
    /// intersecting the range are opened (a stage process never pages
    /// another stage's weights); on a monolithic checkpoint the same subset
    /// of sections is materialized from the single file. `embed` is loaded
    /// only when `range` starts at stage 0, `lm_head`/`final_norm` only
    /// when it ends at the last stage; a partial model must run through the
    /// hidden-state entry points, not token-level decode.
    pub fn load_stage_range(
        path: &Path,
        range: std::ops::Range<usize>,
        mmap: bool,
    ) -> anyhow::Result<(Model, CheckpointInfo)> {
        if mmap {
            return MappedCheckpoint::open(path)?.load_stage_range(range);
        }
        let mut f = std::fs::File::open(path)?;
        let (header, data_start, file_len) = read_header(&mut f, path)?;
        let (cfg, plan) = validate_header(&header)?;
        let n = stage_count(&header);
        if let Some(manifest) = ShardManifest::from_header(&header, n)? {
            drop(f);
            let dir = path.parent().unwrap_or_else(|| Path::new("."));
            return read_model_sharded(dir, &cfg, &header, &manifest, &range, plan, false);
        }
        f.seek(std::io::SeekFrom::Start(data_start))?;
        let mut data = Vec::with_capacity((file_len - data_start) as usize);
        f.read_to_end(&mut data)?;
        let sr = SectionReader::new(&header, Payload::Copied(data))?;
        let model = read_model_range(&cfg, &header, &sr, &range)?;
        Ok((model, CheckpointInfo { format: "cpt2", plan, source: "owned" }))
    }

    /// Load a CPT2 checkpoint through the **zero-copy** path: open and
    /// validate the header once, map the file, and point every weight
    /// buffer straight into the mapping (CRCs checked lazily per section).
    /// Equivalent to [`MappedCheckpoint::open`] + `load_model`.
    pub fn load_compressed_mmap(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        MappedCheckpoint::open(path)?.load_model()
    }

    /// Versioned checkpoint entry point: sniffs the magic and loads either
    /// the dense `CPT1` tensor format or a `CPT2` compressed checkpoint.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        Self::load_checkpoint_with(path, false)
    }

    /// [`load_checkpoint`](Self::load_checkpoint) with an explicit storage
    /// mode: `mmap = true` loads CPT2 weights as zero-copy views into a
    /// shared file mapping (the serve `--mmap` flag). CPT1 files carry
    /// unaligned dense tensors and do not support mapping.
    pub fn load_checkpoint_with(
        path: &Path,
        mmap: bool,
    ) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        drop(f);
        if &magic == MAGIC {
            if mmap {
                Self::load_compressed_mmap(path)
            } else {
                Self::load_compressed(path)
            }
        } else if &magic == super::weights::MAGIC {
            anyhow::ensure!(
                !mmap,
                "{path:?} is a CPT1 checkpoint; --mmap needs the aligned CPT2 format \
                 (re-save with --save-compressed)"
            );
            let model = Self::from_tensor_file(&TensorFile::load(path)?)?;
            Ok((model, CheckpointInfo { format: "cpt1", plan: None, source: "owned" }))
        } else {
            anyhow::bail!(
                "{path:?}: unknown checkpoint magic {magic:?} (expected CPT1 or CPT2)"
            )
        }
    }

    /// Total bytes the model's weight buffers borrow from checkpoint
    /// mappings (0 for an owned model) — the complement of
    /// [`resident_weight_bytes`](Model::resident_weight_bytes).
    pub fn mapped_weight_bytes(&self) -> usize {
        let mut bytes = self.embed.mapped_bytes() + self.lm_head.mapped_bytes();
        for stage in &self.stages {
            match stage {
                Stage::Block(b) => {
                    for p in ProjKind::DECODER_SET {
                        bytes += b.proj(p).mapped_bytes();
                    }
                }
                Stage::Linear(t) => bytes += t.mapped_bytes(),
            }
        }
        bytes
    }

    /// Whether any weight buffer is a zero-copy view into a checkpoint
    /// mapping.
    pub fn weights_mapped(&self) -> bool {
        self.mapped_weight_bytes() > 0
    }
}

/// Serialize one stage's sections (under absolute stage index `i`) and
/// return its header metadata — shared verbatim by the single-file and the
/// sharded save so a shard's sections cannot drift from the monolith's.
fn write_stage_sections(sw: &mut SectionWriter, i: usize, stage: &Stage) -> Json {
    let mut sj = Json::obj();
    match stage {
        Stage::Block(b) => {
            sj.set("kind", "block".into())
                .set("n_heads", b.n_heads.into())
                .set("n_kv_heads", b.n_kv_heads.into());
            sw.add_f32(&format!("stages.{i}.attn_norm"), &b.attn_norm);
            sw.add_f32(&format!("stages.{i}.mlp_norm"), &b.mlp_norm);
            let mut projs = Json::obj();
            for p in ProjKind::DECODER_SET {
                let base = format!("stages.{i}.{}", p.group());
                projs.set(p.group(), write_weight(sw, &base, b.proj(p)));
            }
            sj.set("projections", projs);
        }
        Stage::Linear(t) => {
            sj.set("kind", "linear".into())
                .set("rows", t.rows().into())
                .set("cols", t.cols().into());
            sw.add_f32(&format!("stages.{i}.linear"), t.data());
        }
    }
    sj
}

/// Header fields common to every container this module writes (single-file
/// checkpoints, shard files, and the sharded index).
fn base_header(cfg: &ModelConfig, plan: Option<&str>) -> Json {
    let mut header = Json::obj();
    header
        .set("version", VERSION.into())
        .set("config", cfg.to_json())
        .set("align", ALIGN.into());
    if let Some(p) = plan {
        header.set("plan", p.into());
    }
    header
}

/// Write one CPT2 container (`MAGIC | header | pad | payload`) and return
/// the CRC32 of the header JSON bytes — what the sharded index records per
/// shard so a replaced or corrupted shard header is caught at load time.
fn write_container(path: &Path, header: &Json, payload: &[u8]) -> anyhow::Result<u32> {
    let header_bytes = header.to_string().into_bytes();
    let crc = crc32(&header_bytes);
    let data_start = align_up(8 + header_bytes.len(), ALIGN);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
    f.write_all(&header_bytes)?;
    f.write_all(&vec![0u8; data_start - 8 - header_bytes.len()])?;
    f.write_all(payload)?;
    // Flush explicitly: the drop-time flush swallows errors, and a
    // silently truncated checkpoint (disk full) must not report Ok.
    f.flush()?;
    Ok(crc)
}

/// Read and bound the `CPT2` preamble: magic, header JSON, aligned
/// data-region start. Touches only the header bytes — the payload stays
/// unread (and, for mapped opens, unpaged).
fn read_header(f: &mut std::fs::File, path: &Path) -> anyhow::Result<(Json, u64, u64)> {
    let (header, _, data_start, file_len) = read_header_raw(f, path)?;
    Ok((header, data_start, file_len))
}

/// [`read_header`] plus the raw header JSON bytes — the sharded loader
/// checksums them against the CRC the index manifest recorded per shard.
fn read_header_raw(
    f: &mut std::fs::File,
    path: &Path,
) -> anyhow::Result<(Json, Vec<u8>, u64, u64)> {
    let file_len = f.metadata()?.len();
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?} (not a CPT2 checkpoint)");
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as u64;
    // Validate the header length against the actual file size *before*
    // allocating — a corrupt length must not drive a huge allocation.
    anyhow::ensure!(
        8 + hlen <= file_len,
        "header length {hlen} exceeds file size {file_len} — truncated checkpoint"
    );
    let mut hbytes = vec![0u8; hlen as usize];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("bad checkpoint header json: {e}"))?;
    let data_start = align_up(8 + hlen as usize, ALIGN) as u64;
    anyhow::ensure!(data_start <= file_len, "truncated checkpoint (no data region)");
    Ok((header, hbytes, data_start, file_len))
}

/// Number of stages the header describes — also the coverage target a
/// shard manifest is validated against.
fn stage_count(header: &Json) -> usize {
    header.get("stages").and_then(Json::as_arr).map(<[Json]>::len).unwrap_or(0)
}

fn check_stage_range(range: &std::ops::Range<usize>, n: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        range.start < range.end,
        "empty stage range {}..{}",
        range.start,
        range.end
    );
    anyhow::ensure!(
        range.end <= n,
        "stage range {}..{} is outside the checkpoint's {n} stages",
        range.start,
        range.end
    );
    Ok(())
}

/// Version/config/geometry checks shared by both load paths.
fn validate_header(header: &Json) -> anyhow::Result<(ModelConfig, Option<String>)> {
    let version = header.get("version").and_then(Json::as_usize).unwrap_or(0);
    anyhow::ensure!(
        version == VERSION,
        "unsupported CPT2 version {version} (this build reads version {VERSION})"
    );
    let cfg = ModelConfig::from_json(
        header.get("config").ok_or_else(|| anyhow::anyhow!("checkpoint has no config"))?,
    )?;
    // head_dim() divides by n_heads — reject a config that would panic.
    anyhow::ensure!(
        cfg.n_heads >= 1 && cfg.d_model >= 1 && cfg.d_model % cfg.n_heads == 0,
        "checkpoint config has invalid head geometry (d_model {}, n_heads {})",
        cfg.d_model,
        cfg.n_heads
    );
    let plan = header.get("plan").and_then(Json::as_str).map(String::from);
    Ok((cfg, plan))
}

/// Construct the model from a validated header plus a section reader —
/// the one stage-walking body both the copying and the zero-copy loader
/// run, so the two paths cannot drift.
fn read_model(cfg: ModelConfig, header: &Json, sr: &SectionReader) -> anyhow::Result<Model> {
    let d = cfg.d_model;
    let embed = sr.mat("embed", cfg.vocab, d)?;
    let lm_head = sr.mat("lm_head", d, cfg.vocab)?;
    let final_norm = sr.vec_f32("final_norm", d)?;
    let mut stages = Vec::new();
    for (i, sj) in header
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'stages' array"))?
        .iter()
        .enumerate()
    {
        stages.push(read_stage(i, sj, sr, d, cfg.head_dim())?);
    }
    Ok(Model { cfg, embed, stages, final_norm, lm_head })
}

/// Reconstruct one stage from its metadata + sections. `i` is the
/// *absolute* stage index — it names the sections (`stages.{i}.*`) and the
/// errors, whether the sections live in a monolithic checkpoint or in the
/// shard that owns stage `i`.
fn read_stage(
    i: usize,
    sj: &Json,
    sr: &SectionReader,
    d: usize,
    head_dim: usize,
) -> anyhow::Result<Stage> {
    match sj.get("kind").and_then(Json::as_str) {
        Some("block") => {
            let n_heads = sj
                .get("n_heads")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_heads"))?;
            let n_kv_heads = sj
                .get("n_kv_heads")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_kv_heads"))?;
            anyhow::ensure!(
                n_kv_heads >= 1 && n_heads >= n_kv_heads && n_heads % n_kv_heads == 0,
                "stage {i}: invalid head counts {n_heads}/{n_kv_heads}"
            );
            let projs = sj
                .get("projections")
                .ok_or_else(|| anyhow::anyhow!("stage {i}: missing projections"))?;
            let get = |p: ProjKind| -> anyhow::Result<LinearWeight> {
                let base = format!("stages.{i}.{}", p.group());
                let meta = projs.get(p.group()).ok_or_else(|| {
                    anyhow::anyhow!("stage {i}: missing projection '{}'", p.group())
                })?;
                read_weight(sr, &base, meta)
            };
            let block = Block {
                attn_norm: sr.vec_f32(&format!("stages.{i}.attn_norm"), d)?,
                q: get(ProjKind::Q)?,
                k: get(ProjKind::K)?,
                v: get(ProjKind::V)?,
                o: get(ProjKind::O)?,
                mlp_norm: sr.vec_f32(&format!("stages.{i}.mlp_norm"), d)?,
                gate: get(ProjKind::Gate)?,
                up: get(ProjKind::Up)?,
                down: get(ProjKind::Down)?,
                n_heads,
                n_kv_heads,
            };
            validate_block_shapes(i, &block, d, head_dim)?;
            Ok(Stage::Block(block))
        }
        Some("linear") => {
            let rows = sj
                .get("rows")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("stage {i}: missing rows"))?;
            let cols = sj
                .get("cols")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("stage {i}: missing cols"))?;
            anyhow::ensure!(
                rows == d && cols == d,
                "stage {i}: linear shape {rows}x{cols} does not preserve the \
                 d={d} residual stream"
            );
            Ok(Stage::Linear(sr.mat(&format!("stages.{i}.linear"), rows, cols)?))
        }
        other => anyhow::bail!("stage {i}: unknown stage kind {other:?}"),
    }
}

/// Build a (possibly partial) model for `range` out of one monolithic
/// section reader. Stages outside the range are skipped entirely; `embed`
/// is read only when the range starts at stage 0 (the pipeline head embeds
/// tokens), `lm_head`/`final_norm` only when it ends at the last stage (the
/// pipeline tail samples). The absent ends are empty buffers — partial
/// models run only through the hidden-state entry points
/// ([`Model::forward_hidden_cached`] and friends), never through
/// token-level decode.
fn read_model_range(
    cfg: &ModelConfig,
    header: &Json,
    sr: &SectionReader,
    range: &std::ops::Range<usize>,
) -> anyhow::Result<Model> {
    let stages_meta = header
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'stages' array"))?;
    check_stage_range(range, stages_meta.len())?;
    let d = cfg.d_model;
    let embed =
        if range.start == 0 { sr.mat("embed", cfg.vocab, d)? } else { Mat::zeros(0, d) };
    let (lm_head, final_norm) = if range.end == stages_meta.len() {
        (sr.mat("lm_head", d, cfg.vocab)?, sr.vec_f32("final_norm", d)?)
    } else {
        (Mat::zeros(0, 0), Vec::new())
    };
    let mut stages = Vec::with_capacity(range.len());
    for i in range.clone() {
        stages.push(read_stage(i, &stages_meta[i], sr, d, cfg.head_dim())?);
    }
    Ok(Model { cfg: cfg.clone(), embed, stages, final_norm, lm_head })
}

/// Open one shard file for loading: verify its header CRC against the
/// manifest, its config against the index, and its recorded stage range
/// against the manifest entry, then hand back a section reader over its
/// payload (`mmap = false` copies the data region; `true` maps it). The
/// bool reports whether the mapping is a true mmap.
fn open_shard_reader(
    dir: &Path,
    cfg: &ModelConfig,
    e: &ShardEntry,
    mmap: bool,
) -> anyhow::Result<(SectionReader, bool)> {
    let path = dir.join(&e.path);
    let mut f = std::fs::File::open(&path).map_err(|err| {
        anyhow::anyhow!("shard {}: cannot open {path:?}: {err}", e.id)
    })?;
    let (header, hbytes, data_start, file_len) = read_header_raw(&mut f, &path)?;
    let got = crc32(&hbytes);
    anyhow::ensure!(
        got == e.crc,
        "shard {}: header crc mismatch (manifest {:#010x}, file {got:#010x}) — \
         shard replaced or corrupted",
        e.id,
        e.crc
    );
    let (shard_cfg, _) = validate_header(&header)?;
    anyhow::ensure!(
        shard_cfg == *cfg,
        "shard {}: config '{}' does not match the index config '{}'",
        e.id,
        shard_cfg.name,
        cfg.name
    );
    let marker = header
        .get("shard")
        .ok_or_else(|| anyhow::anyhow!("shard {}: {path:?} is not a shard file", e.id))?;
    let field = |k: &str| marker.get(k).and_then(Json::as_usize);
    anyhow::ensure!(
        field("id") == Some(e.id) && field("lo") == Some(e.lo) && field("hi") == Some(e.hi),
        "shard {}: file records id {:?} stages {:?}..{:?}, manifest says {}..{}",
        e.id,
        field("id"),
        field("lo"),
        field("hi"),
        e.lo,
        e.hi
    );
    let (payload, is_mmap) = if mmap {
        let map = Mapping::open(&path)?;
        anyhow::ensure!(
            data_start as usize <= map.len(),
            "shard {}: truncated while opening (data region past mapped {} B)",
            e.id,
            map.len()
        );
        let is_mmap = map.is_mmap();
        (Payload::Mapped { map, start: data_start as usize }, is_mmap)
    } else {
        f.seek(std::io::SeekFrom::Start(data_start))?;
        let mut data = Vec::with_capacity((file_len - data_start) as usize);
        f.read_to_end(&mut data)?;
        (Payload::Copied(data), false)
    };
    Ok((SectionReader::new(&header, payload)?, is_mmap))
}

/// Assemble a (possibly partial) model for `range` from the shards that
/// intersect it. Shards outside the range are never opened — a stage-range
/// process touches only its own files — and every opened shard is verified
/// (header CRC, config, recorded range) before any section materializes.
fn read_model_sharded(
    dir: &Path,
    cfg: &ModelConfig,
    index_header: &Json,
    manifest: &ShardManifest,
    range: &std::ops::Range<usize>,
    plan: Option<String>,
    mmap: bool,
) -> anyhow::Result<(Model, CheckpointInfo)> {
    let stages_meta = index_header
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("sharded index has no 'stages' array"))?;
    let n = stages_meta.len();
    check_stage_range(range, n)?;
    let d = cfg.d_model;
    let last_id = manifest.entries.len() - 1;
    let mut embed = None;
    let mut lm_head = None;
    let mut final_norm = None;
    let mut stages = Vec::with_capacity(range.len());
    let mut all_mmap = true;
    for e in manifest.entries_for(range.start, range.end) {
        let (sr, is_mmap) = open_shard_reader(dir, cfg, e, mmap)?;
        all_mmap &= is_mmap;
        if e.id == 0 && range.start == 0 {
            embed = Some(sr.mat("embed", cfg.vocab, d)?);
        }
        if e.id == last_id && range.end == n {
            lm_head = Some(sr.mat("lm_head", d, cfg.vocab)?);
            final_norm = Some(sr.vec_f32("final_norm", d)?);
        }
        for i in e.lo.max(range.start)..e.hi.min(range.end) {
            stages.push(read_stage(i, &stages_meta[i], &sr, d, cfg.head_dim())?);
        }
    }
    let model = Model {
        cfg: cfg.clone(),
        embed: embed.unwrap_or_else(|| Mat::zeros(0, d)),
        stages,
        final_norm: final_norm.unwrap_or_default(),
        lm_head: lm_head.unwrap_or_else(|| Mat::zeros(0, 0)),
    };
    let source = if !mmap {
        "owned"
    } else if all_mmap {
        "mmap"
    } else {
        "mmap-fallback"
    };
    Ok((model, CheckpointInfo { format: "cpt2", plan, source }))
}

// ---------------------------------------------------------------------------
// MappedCheckpoint: open/validate once, serve zero-copy models.
// ---------------------------------------------------------------------------

/// A CPT2 checkpoint opened for zero-copy serving: the file is mapped once,
/// the header is parsed and validated once, and
/// [`load_model`](MappedCheckpoint::load_model) builds a [`Model`] whose
/// weight buffers point straight into the mapping. Section CRCs are checked
/// lazily — a corrupt payload surfaces as an error from `load_model`, while
/// `open` itself touches only header bytes (this is also what makes the
/// `compot info <ckpt>` fast path free).
pub struct MappedCheckpoint {
    map: Arc<Mapping>,
    header: Json,
    data_start: usize,
    cfg: ModelConfig,
    plan: Option<String>,
    /// Parsed shard manifest when this is a sharded **index** file. The
    /// shard files themselves are *not* opened here — their mappings are
    /// created (and their header CRCs verified) only when a load asks for
    /// stages they hold, so `open` + `compot info` stay index-only.
    shards: Option<ShardManifest>,
    /// Directory shard paths resolve against (the index file's parent).
    dir: PathBuf,
}

impl MappedCheckpoint {
    /// Map the file and validate the header (magic, version, config
    /// geometry, data-region bounds; for a sharded index, also the
    /// manifest's gap/overlap-free stage coverage). No section payload is
    /// read or CRC-checked here, and no shard file is touched.
    pub fn open(path: &Path) -> anyhow::Result<MappedCheckpoint> {
        let mut f = std::fs::File::open(path)?;
        let (header, data_start, _) = read_header(&mut f, path)?;
        drop(f);
        let (cfg, plan) = validate_header(&header)?;
        let shards = ShardManifest::from_header(&header, stage_count(&header))?;
        let map = Mapping::open(path)?;
        // The mapping is taken after the header read; guard against the file
        // shrinking in between (the section table is bounds-checked against
        // the mapping again in SectionReader::new).
        anyhow::ensure!(
            data_start as usize <= map.len(),
            "checkpoint truncated while opening (data region past mapped {} B)",
            map.len()
        );
        let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();
        Ok(MappedCheckpoint {
            map,
            header,
            data_start: data_start as usize,
            cfg,
            plan,
            shards,
            dir,
        })
    }

    /// Model config recorded in the header.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Compression-plan provenance recorded at save time.
    pub fn plan(&self) -> Option<&str> {
        self.plan.as_deref()
    }

    /// The raw parsed header (config, stages, sections) — what the
    /// `compot info` fast path formats without loading any payload.
    pub fn header(&self) -> &Json {
        &self.header
    }

    /// Whether the backing store is a true `mmap` (page-cache shared)
    /// rather than the aligned heap-read fallback.
    pub fn is_mmap(&self) -> bool {
        self.map.is_mmap()
    }

    /// The shard manifest, when this checkpoint is a sharded index.
    pub fn manifest(&self) -> Option<&ShardManifest> {
        self.shards.as_ref()
    }

    /// Whether this checkpoint is a sharded index rather than a monolith.
    pub fn is_sharded(&self) -> bool {
        self.shards.is_some()
    }

    /// Load only the stages in `range` as a partial model (see
    /// [`Model::load_stage_range`]). On a sharded index, only the
    /// intersecting shards are mapped.
    pub fn load_stage_range(
        &self,
        range: std::ops::Range<usize>,
    ) -> anyhow::Result<(Model, CheckpointInfo)> {
        if let Some(manifest) = &self.shards {
            return read_model_sharded(
                &self.dir,
                &self.cfg,
                &self.header,
                manifest,
                &range,
                self.plan.clone(),
                true,
            );
        }
        let sr = SectionReader::new(
            &self.header,
            Payload::Mapped { map: self.map.clone(), start: self.data_start },
        )?;
        let model = read_model_range(&self.cfg, &self.header, &sr, &range)?;
        let source = if self.map.is_mmap() { "mmap" } else { "mmap-fallback" };
        Ok((model, CheckpointInfo { format: "cpt2", plan: self.plan.clone(), source }))
    }

    /// Construct the model with every weight buffer pointing into the
    /// mapping. Each section's CRC is verified (lazily, here) before its
    /// view is handed out; reconstruction goes through the same fallible
    /// constructors as the copying loader. On a sharded index, every shard
    /// is mapped and the full model assembled across them.
    pub fn load_model(&self) -> anyhow::Result<(Model, CheckpointInfo)> {
        if let Some(manifest) = &self.shards {
            let n = stage_count(&self.header);
            return read_model_sharded(
                &self.dir,
                &self.cfg,
                &self.header,
                manifest,
                &(0..n),
                self.plan.clone(),
                true,
            );
        }
        let sr = SectionReader::new(
            &self.header,
            Payload::Mapped { map: self.map.clone(), start: self.data_start },
        )?;
        // Every request a serve worker handles starts in the embedding
        // table and ends in the LM head — prefault those sections now so
        // the first request doesn't eat their page-fault latency.
        for name in ["embed", "lm_head"] {
            if let Some((d, _)) = sr.by_name.get(name) {
                self.map.advise(
                    self.data_start + d.offset,
                    d.len * d.dtype_size,
                    Advice::WillNeed,
                );
            }
        }
        let model = read_model(self.cfg.clone(), &self.header, &sr)?;
        // Report the fallback honestly: an operator sizing N serve workers
        // must know whether the model is page-cache-shared or a private
        // heap copy per process.
        let source = if self.map.is_mmap() { "mmap" } else { "mmap-fallback" };
        Ok((model, CheckpointInfo { format: "cpt2", plan: self.plan.clone(), source }))
    }
}

/// One-line-per-stage summary of a CPT2 header — variant tags, shapes, and
/// bit widths straight from the JSON, no section payload touched. The
/// `compot info <checkpoint>` fast path prints this.
pub fn header_summary(header: &Json) -> String {
    let mut out = String::new();
    let cfg_name = header
        .get("config")
        .and_then(|c| c.get("name"))
        .and_then(Json::as_str)
        .unwrap_or("?");
    out.push_str(&format!(
        "config: {cfg_name} | version {} | plan {}\n",
        header.get("version").and_then(Json::as_usize).unwrap_or(0),
        header.get("plan").and_then(Json::as_str).unwrap_or("none recorded"),
    ));
    // Sharded index: print the manifest. Still strictly header-derived —
    // no shard file is opened, no payload byte is read.
    if let Some(arr) = header.get("shards").and_then(Json::as_arr) {
        out.push_str(&format!("sharded index: {} shards\n", arr.len()));
        match ShardManifest::parse(arr, stage_count(header)) {
            Ok(m) => out.push_str(&m.summary()),
            Err(e) => out.push_str(&format!("(invalid shard manifest: {e})\n")),
        }
    }
    let Some(stages) = header.get("stages").and_then(Json::as_arr) else {
        out.push_str("(no stages array)\n");
        return out;
    };
    for (i, sj) in stages.iter().enumerate() {
        match sj.get("kind").and_then(Json::as_str) {
            Some("block") => {
                out.push_str(&format!(
                    "stage {i:>3} block ({}h/{}kv):",
                    sj.get("n_heads").and_then(Json::as_usize).unwrap_or(0),
                    sj.get("n_kv_heads").and_then(Json::as_usize).unwrap_or(0)
                ));
                if let Some(projs) = sj.get("projections") {
                    for p in ProjKind::DECODER_SET {
                        let Some(meta) = projs.get(p.group()) else { continue };
                        let variant = meta.get("variant").and_then(Json::as_str).unwrap_or("?");
                        let dim = |k: &str| meta.get(k).and_then(Json::as_usize);
                        let shape = match variant {
                            "dense" | "quant_dense" => format!(
                                "{}x{}",
                                dim("rows").unwrap_or(0),
                                dim("cols").unwrap_or(0)
                            ),
                            "low_rank" | "quant_low_rank" => format!(
                                "{}x{}x{}",
                                dim("m").unwrap_or(0),
                                dim("r").unwrap_or(0),
                                dim("n").unwrap_or(0)
                            ),
                            _ => format!(
                                "{}x{}x{} s{}",
                                dim("m").unwrap_or(0),
                                dim("k").unwrap_or(0),
                                dim("n").unwrap_or(0),
                                dim("s").unwrap_or(0)
                            ),
                        };
                        let mut bits = String::new();
                        for key in ["bits", "bits_b", "bits_c", "bits_a", "bits_val"] {
                            if let Some(b) = dim(key) {
                                if !bits.is_empty() {
                                    bits.push('/');
                                }
                                bits.push_str(&b.to_string());
                            }
                        }
                        let group = ["group", "group_b", "group_a"]
                            .iter()
                            .find_map(|k| dim(k))
                            .map(|g| format!(" g{g}"))
                            .unwrap_or_default();
                        let layout = ["layout", "layout_b", "layout_a"]
                            .iter()
                            .find_map(|k| meta.get(k).and_then(Json::as_str))
                            .map(|l| format!(" {l}"))
                            .unwrap_or_default();
                        if bits.is_empty() {
                            out.push_str(&format!(" {}={variant}[{shape}]", p.group()));
                        } else {
                            out.push_str(&format!(
                                " {}={variant}[{shape} @{bits}b{group}{layout}]",
                                p.group()
                            ));
                        }
                    }
                }
                out.push('\n');
            }
            Some("linear") => {
                out.push_str(&format!(
                    "stage {i:>3} linear {}x{}\n",
                    sj.get("rows").and_then(Json::as_usize).unwrap_or(0),
                    sj.get("cols").and_then(Json::as_usize).unwrap_or(0)
                ));
            }
            other => out.push_str(&format!("stage {i:>3} unknown kind {other:?}\n")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::StageConfig;
    use crate::coordinator::plan::CompressionPlan;
    use crate::data::SynthLang;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("compot_cpt2_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny() -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(11))
    }

    fn compressed(spec: &str) -> Model {
        let model = tiny();
        let lang = SynthLang::wiki(model.cfg.vocab);
        let calib = lang.gen_batch(6, 48, &mut Rng::new(12));
        let plan = CompressionPlan::parse(spec, &StageConfig::new(0.25, false)).unwrap();
        plan.run(&model, &calib).unwrap().0
    }

    fn assert_identical(a: &Model, b: &Model) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.resident_weight_bytes(), b.resident_weight_bytes());
        assert_eq!(a.storage_bits(), b.storage_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        // bit-identical buffers, variant included
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind changed across the round trip"),
            }
        }
        let prompt = [1u16, 2, 3, 4];
        assert_eq!(a.greedy_decode(&prompt, 8), b.greedy_decode(&prompt, 8));
    }

    #[test]
    fn dense_model_roundtrip() {
        let m = tiny();
        let path = tmp("dense.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, info) = Model::load_compressed(&path).unwrap();
        assert_eq!(info.format, "cpt2");
        assert!(info.plan.is_none());
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn every_compressed_variant_roundtrips_bit_identically() {
        // One plan per LinearWeight variant the pipeline can emit:
        // LowRank, Factorized, QuantDense, QuantLowRank, QuantFactorized.
        for (spec, name) in [
            ("svd-llm@0.2", "lowrank"),
            ("compot@0.25", "factorized"),
            ("rtn4", "quant_dense"),
            ("svd-llm@0.2+rtn4", "quant_lowrank"),
            ("compot@0.25+gptq4", "quant_factorized"),
        ] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            let (back, info) = Model::load_checkpoint(&path).unwrap();
            assert_eq!(info.plan.as_deref(), Some(spec), "{spec}");
            assert_identical(&m, &back);
            std::fs::remove_file(&path).ok();
        }
    }

    /// Bit-identity without the resident-bytes check — a mapped model keeps
    /// its weights in the file mapping, so residency *should* differ.
    fn assert_same_weights(a: &Model, b: &Model) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.storage_bits(), b.storage_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind changed across the round trip"),
            }
        }
        let prompt = [1u16, 2, 3, 4];
        assert_eq!(a.greedy_decode(&prompt, 8), b.greedy_decode(&prompt, 8));
    }

    #[test]
    fn mmap_load_is_bit_identical_across_all_variants() {
        // The tentpole acceptance matrix: for every LinearWeight variant,
        // the zero-copy loader reproduces the copying loader bit for bit
        // (WeightBuf equality is content equality across owned/mapped) and
        // decodes token-identically, while keeping the big buffers in the
        // mapping instead of on the heap.
        for (spec, name) in [
            ("svd-llm@0.2", "m_lowrank"),
            ("compot@0.25", "m_factorized"),
            ("rtn4", "m_quant_dense"),
            ("svd-llm@0.2+rtn4", "m_quant_lowrank"),
            ("compot@0.25+gptq4", "m_quant_factorized"),
        ] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            let (owned, oinfo) = Model::load_compressed(&path).unwrap();
            let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
            assert_eq!(oinfo.source, "owned", "{spec}");
            assert!(minfo.source.starts_with("mmap"), "{spec}: {}", minfo.source);
            assert_eq!(minfo.plan.as_deref(), Some(spec), "{spec}");
            assert_same_weights(&m, &owned);
            assert_same_weights(&owned, &mapped);
            // mapping-aware accounting. On a true mmap the mapped model's
            // projections live in shared file-backed pages, not the heap;
            // on the heap-read fallback ("mmap-fallback") they are private
            // memory and must be reported as resident. Either way the two
            // numbers add up to the owned footprint.
            assert!(!owned.weights_mapped(), "{spec}");
            if minfo.source == "mmap" {
                assert!(mapped.weights_mapped(), "{spec}");
                assert!(mapped.mapped_weight_bytes() > 0, "{spec}");
                assert!(
                    mapped.resident_weight_bytes() < owned.resident_weight_bytes(),
                    "{spec}: mapped model should keep weight bytes off the heap"
                );
            } else {
                assert_eq!(mapped.mapped_weight_bytes(), 0, "{spec}");
            }
            assert_eq!(
                mapped.resident_weight_bytes() + mapped.mapped_weight_bytes(),
                owned.resident_weight_bytes(),
                "{spec}: resident + mapped must add up to the owned footprint"
            );
            std::fs::remove_file(&path).ok();
        }
        // the dense (uncompressed) variant round-trips through the zero-copy
        // loader too
        let m = tiny();
        let path = tmp("m_dense.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
        assert_same_weights(&m, &mapped);
        assert!(minfo.source.starts_with("mmap"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapped_open_defers_crc_to_load() {
        // Lazy per-section CRC: a corrupt payload does not stop the
        // header-only open (that is the `compot info` fast path), but the
        // first load that touches the section must fail its checksum.
        let m = compressed("rtn4");
        let path = tmp("lazycrc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let ck = MappedCheckpoint::open(&path).expect("open is header-only, must succeed");
        let err = ck.load_model().unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_section_offset_is_a_structural_error() {
        // A header claiming a non-ALIGN-multiple offset would hand out a
        // misaligned f32 view — the mmap path must reject it as such (not
        // panic, not reinterpret).
        let m = tiny();
        let path = tmp("misaligned.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"name\":\"embed\",\"offset\":0", "\"name\":\"embed\",\"offset\":2");
        let err = Model::load_compressed_mmap(&path).unwrap_err().to_string();
        assert!(err.contains("misaligned"), "{err}");
        // the copying loader flags the same corruption as a checksum error
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_mapping_is_an_error() {
        let m = compressed("rtn4");
        let path = tmp("mtrunc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 97]).unwrap();
        let err = Model::load_compressed_mmap(&path).unwrap_err().to_string();
        assert!(
            err.contains("runs past the data region") || err.contains("crc mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cpt1_rejects_mmap_cleanly() {
        let m = tiny();
        let path = tmp("old_mmap.cpt1");
        m.save(&path).unwrap();
        let err = Model::load_checkpoint_with(&path, true).unwrap_err().to_string();
        assert!(err.contains("CPT1"), "{err}");
        // without --mmap the CPT1 path still loads
        assert!(Model::load_checkpoint_with(&path, false).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn group_size_roundtrips_through_the_header() {
        // Non-default quantization groups must survive save → load on both
        // paths: the header records each packed tensor's group, the loader
        // reconstructs the exact layout, decode stays token-identical.
        for (spec, want_group) in
            [("rtn4,group_size=64", 64usize), ("compot@0.25+gptq4,group_size=256", 256)]
        {
            let m = compressed(spec);
            let path = tmp(&format!("group{want_group}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            for mmap in [false, true] {
                let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
                assert_same_weights(&m, &back);
                let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
                match &b.q {
                    LinearWeight::QuantDense(q) => assert_eq!(q.group(), want_group, "{spec}"),
                    LinearWeight::QuantFactorized { a, s } => {
                        assert_eq!(a.group(), want_group, "{spec}");
                        assert_eq!(s.values_qmat().group(), want_group, "{spec}");
                    }
                    other => panic!("{spec}: unexpected variant {other:?}"),
                }
            }
            std::fs::remove_file(&path).ok();
        }
        // an unsupported group size in the header is an error, not a panic
        let m = compressed("rtn4");
        let path = tmp("badgroup.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"group\":128", "\"group\":100");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("group"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quant_layout_roundtrips_and_legacy_headers_default_to_row_seq() {
        // Default quantization now packs planar; the header records the tag
        // and both load paths rebuild the exact layout.
        let m = compressed("rtn4");
        let path = tmp("layout.cpt2");
        m.save_compressed(&path, Some("rtn4")).unwrap();
        for mmap in [false, true] {
            let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
            assert_same_weights(&m, &back);
            let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
            let LinearWeight::QuantDense(q) = &b.q else { panic!("not quant_dense") };
            assert_eq!(q.layout(), QuantLayout::Planar, "mmap={mmap}");
        }
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert!(header_summary(ck.header()).contains("planar"));
        drop(ck);
        // an unknown layout tag is an error, not a panic or a misread
        mangle_header(&path, "\"layout\":\"planar\"", "\"layout\":\"flanar\"");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("layout"), "{err}");
        std::fs::remove_file(&path).ok();

        // A header without any layout key (every pre-planar checkpoint) must
        // load as row-sequential. Simulate one by saving a row-seq model and
        // renaming its tag so the loader sees no "layout" field at all.
        let legacy = m.with_quant_layout(QuantLayout::RowSeq);
        let path = tmp("layout_legacy.cpt2");
        legacy.save_compressed(&path, Some("rtn4")).unwrap();
        mangle_header(&path, "\"layout\":\"row_seq\"", "\"laYout\":\"row_seq\"");
        for mmap in [false, true] {
            let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
            assert_same_weights(&legacy, &back);
            let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
            let LinearWeight::QuantDense(q) = &b.q else { panic!("not quant_dense") };
            assert_eq!(q.layout(), QuantLayout::RowSeq, "mmap={mmap}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_summary_reads_no_payload() {
        let m = compressed("compot@0.25+gptq4");
        let path = tmp("summary.cpt2");
        m.save_compressed(&path, Some("compot@0.25+gptq4")).unwrap();
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert_eq!(ck.plan(), Some("compot@0.25+gptq4"));
        assert_eq!(ck.config().name, "test-tiny");
        let summary = header_summary(ck.header());
        assert!(summary.contains("quant_factorized"), "{summary}");
        assert!(summary.contains("test-tiny"), "{summary}");
        assert!(summary.contains("g128"), "{summary}");
        // the fast path works even when every payload byte is corrupt
        let mut bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let data_start = (8 + hlen).div_ceil(ALIGN) * ALIGN;
        for b in bytes[data_start..].iter_mut() {
            *b = 0xaa;
        }
        std::fs::write(&path, &bytes).unwrap();
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert!(header_summary(ck.header()).contains("quant_factorized"));
        assert!(ck.load_model().is_err(), "corrupt payload must still fail the real load");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn linear_stage_roundtrips() {
        let mut m = tiny();
        let d = m.cfg.d_model;
        m.stages[1] = Stage::Linear(Mat::randn(&mut Rng::new(13), d, d, 0.2));
        let path = tmp("linear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, _) = Model::load_compressed(&path).unwrap();
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cpt1_loads_through_the_versioned_entry_point() {
        let m = tiny();
        let path = tmp("old.cpt1");
        m.save(&path).unwrap();
        let (back, info) = Model::load_checkpoint(&path).unwrap();
        assert_eq!(info.format, "cpt1");
        let prompt = [3u16, 1, 4];
        assert_eq!(m.greedy_decode(&prompt, 6), back.greedy_decode(&prompt, 6));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_is_an_error() {
        let path = tmp("junk.cpt2");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00rest of the junk").unwrap();
        assert!(Model::load_compressed(&path).is_err());
        let err = Model::load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("unknown checkpoint magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_header_and_sections_are_errors() {
        let m = tiny();
        let path = tmp("trunc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let full = std::fs::read(&path).unwrap();

        // header length field claims more bytes than the file has
        let mut huge = full.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &huge).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // file cut inside the header
        std::fs::write(&path, &full[..64]).unwrap();
        assert!(Model::load_compressed(&path).is_err());

        // file cut inside the section payloads: bounds check, no panic
        std::fs::write(&path, &full[..full.len() - 97]).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("runs past the data region") || err.contains("crc mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let m = compressed("rtn4");
        let path = tmp("crc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff; // flip a bit in the last section's payload
        std::fs::write(&path, &bytes).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    fn mangle_header(path: &Path, from: &str, to: &str) {
        // Same-length textual header edits keep offsets valid so the
        // specific validator under test is the one that fires.
        assert_eq!(from.len(), to.len());
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = String::from_utf8(bytes[8..8 + hlen].to_vec()).unwrap();
        assert!(header.contains(from), "header does not contain '{from}'");
        let patched = header.replacen(from, to, 1);
        let mut out = bytes[..8].to_vec();
        out.extend_from_slice(patched.as_bytes());
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(path, &out).unwrap();
    }

    #[test]
    fn unknown_variant_tag_is_an_error() {
        let m = compressed("rtn4");
        let path = tmp("variant.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"quant_dense\"", "\"quant_blorp\"");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unknown variant tag"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bits_outside_packable_range_are_errors() {
        let m = compressed("rtn4");
        let path = tmp("bits.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"bits\":4", "\"bits\":9");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("2..=8"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_section_length_mismatch_is_an_error() {
        let m = tiny();
        let path = tmp("mismatch.cpt2");
        m.save_compressed(&path, None).unwrap();
        // final_norm has d_model = 32 elements; claim 64 → the recorded CRC
        // no longer matches the (bounds-checked, never-trusted) enlarged
        // range, or the range runs past the data region.
        mangle_header(
            &path,
            "\"len\":32,\"name\":\"final_norm\"",
            "\"len\":64,\"name\":\"final_norm\"",
        );
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("final_norm"),
            "mismatch must be caught on the named section: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn structurally_inconsistent_shapes_are_rejected() {
        // Per-tensor shapes can be internally consistent (sections + CRCs
        // valid) while describing a block the forward pass would panic on:
        // the loader must reject it, never defer the panic to serve time.
        let mut m = tiny();
        let d = m.cfg.d_model;
        if let Stage::Block(b) = &mut m.stages[0] {
            // 24 ≠ n_heads · head_dim for test-tiny
            b.q = LinearWeight::Dense(Mat::zeros(d, 24));
        }
        let path = tmp("badshape.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("structural contract"), "{err}");
        std::fs::remove_file(&path).ok();

        // A linear stage that changes the residual width is rejected too.
        let mut m = tiny();
        m.stages[1] = Stage::Linear(Mat::zeros(d, d + 1));
        let path = tmp("badlinear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("residual stream"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn future_version_is_rejected() {
        let m = tiny();
        let path = tmp("version.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"version\":2", "\"version\":7");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported CPT2 version"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }

    // -----------------------------------------------------------------------
    // Sharded checkpoints.
    // -----------------------------------------------------------------------

    fn assert_stages_eq(a: &[Stage], b: &[Stage]) {
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(b.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind mismatch"),
            }
        }
    }

    /// Overwrite every byte of a container's data region, leaving the
    /// header intact.
    fn corrupt_payload(path: &Path) {
        let mut bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let data_start = (8 + hlen).div_ceil(ALIGN) * ALIGN;
        assert!(data_start < bytes.len(), "no payload to corrupt in {path:?}");
        for b in bytes[data_start..].iter_mut() {
            *b = 0xaa;
        }
        std::fs::write(path, &bytes).unwrap();
    }

    fn rm_sharded(name: &str) {
        for f in
            [format!("{name}.cpt2"), format!("{name}.shard0.cpt2"), format!("{name}.shard1.cpt2")]
        {
            std::fs::remove_file(tmp(&f)).ok();
        }
    }

    #[test]
    fn sharded_save_roundtrips_bit_identically() {
        for (spec, name) in [("rtn4", "sh_quant"), ("compot@0.25+gptq4", "sh_qfact")] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed_sharded(&path, Some(spec), 2).unwrap();
            assert!(tmp(&format!("{name}.shard0.cpt2")).exists());
            assert!(tmp(&format!("{name}.shard1.cpt2")).exists());
            // owned full load across shards is bit-identical (residency
            // included: every buffer is copied, exactly like the monolith)
            let (owned, oinfo) = Model::load_compressed(&path).unwrap();
            assert_eq!(oinfo.source, "owned", "{spec}");
            assert_eq!(oinfo.plan.as_deref(), Some(spec), "{spec}");
            assert_identical(&m, &owned);
            // mapped full load: one mapping per shard, same weights
            let ck = MappedCheckpoint::open(&path).unwrap();
            assert!(ck.is_sharded());
            assert_eq!(ck.manifest().unwrap().entries.len(), 2);
            let (mapped, minfo) = ck.load_model().unwrap();
            assert!(minfo.source.starts_with("mmap"), "{spec}: {}", minfo.source);
            assert_same_weights(&owned, &mapped);
            rm_sharded(name);
        }
        // the dense (uncompressed) model shards too
        let m = tiny();
        let path = tmp("sh_dense.cpt2");
        m.save_compressed_sharded(&path, None, 2).unwrap();
        let (back, _) = Model::load_compressed(&path).unwrap();
        assert_identical(&m, &back);
        rm_sharded("sh_dense");
    }

    #[test]
    fn load_stage_range_builds_partial_models() {
        let m = compressed("rtn4");
        let path = tmp("sh_range.cpt2");
        m.save_compressed_sharded(&path, Some("rtn4"), 2).unwrap();
        for mmap in [false, true] {
            // head partial: embed + its stages, no LM head
            let (head, _) = Model::load_stage_range(&path, 0..1, mmap).unwrap();
            assert_eq!(head.stages.len(), 1, "mmap={mmap}");
            assert_eq!(head.embed, m.embed, "mmap={mmap}");
            assert!(head.final_norm.is_empty(), "mmap={mmap}");
            assert_eq!(head.lm_head.rows(), 0, "mmap={mmap}");
            assert_stages_eq(&head.stages, &m.stages[0..1]);
            // tail partial: its stages + final_norm/lm_head, no embed
            let (tail, _) = Model::load_stage_range(&path, 1..2, mmap).unwrap();
            assert_eq!(tail.embed.rows(), 0, "mmap={mmap}");
            assert_eq!(tail.final_norm, m.final_norm, "mmap={mmap}");
            assert_eq!(tail.lm_head, m.lm_head, "mmap={mmap}");
            assert_stages_eq(&tail.stages, &m.stages[1..2]);
            // the full range through the partial API is the whole model
            let (full, _) = Model::load_stage_range(&path, 0..2, mmap).unwrap();
            assert_same_weights(&m, &full);
        }
        rm_sharded("sh_range");
        // the same partial API works on a monolithic checkpoint
        let mono = tmp("sh_range_mono.cpt2");
        m.save_compressed(&mono, Some("rtn4")).unwrap();
        for mmap in [false, true] {
            let (head, _) = Model::load_stage_range(&mono, 0..1, mmap).unwrap();
            assert_eq!(head.embed, m.embed, "mmap={mmap}");
            assert!(head.final_norm.is_empty(), "mmap={mmap}");
            assert_stages_eq(&head.stages, &m.stages[0..1]);
        }
        std::fs::remove_file(&mono).ok();
    }

    #[test]
    fn sharded_index_open_and_info_never_touch_a_shard_payload() {
        // The sharded counterpart of `mapped_open_defers_crc_to_load`:
        // corrupting a NON-head shard's entire payload must not disturb the
        // index-only open or the header summary (the `compot info` fast
        // path opens no shard file at all), must leave the head range
        // loadable, and must fail exactly the loads that touch the shard.
        let m = compressed("rtn4");
        let path = tmp("sh_lazy.cpt2");
        m.save_compressed_sharded(&path, Some("rtn4"), 2).unwrap();
        corrupt_payload(&tmp("sh_lazy.shard1.cpt2"));
        let ck = MappedCheckpoint::open(&path).expect("index open never reads a shard");
        let summary = header_summary(ck.header());
        assert!(summary.contains("sharded index: 2 shards"), "{summary}");
        assert!(summary.contains("sh_lazy.shard1.cpt2"), "{summary}");
        assert!(summary.contains("quant_dense"), "{summary}");
        // the intact head shard still serves its stage range, owned + mmap
        assert!(Model::load_stage_range(&path, 0..1, false).is_ok());
        assert!(Model::load_stage_range(&path, 0..1, true).is_ok());
        // anything touching the corrupt shard fails its lazy section CRC
        let err = ck.load_model().unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        rm_sharded("sh_lazy");
    }

    #[test]
    fn shard_loader_error_paths_are_structured() {
        let m = compressed("rtn4");

        // more shards than stages is a save-time error
        let err = m
            .save_compressed_sharded(&tmp("sh_err_n.cpt2"), None, 3)
            .unwrap_err()
            .to_string();
        assert!(err.contains("at most one shard per stage"), "{err}");
        assert!(m.save_compressed_sharded(&tmp("sh_err_n.cpt2"), None, 0).is_err());

        // missing shard file: structured error naming the shard, and the
        // range that avoids it still loads
        let path = tmp("sh_err_miss.cpt2");
        m.save_compressed_sharded(&path, None, 2).unwrap();
        std::fs::remove_file(tmp("sh_err_miss.shard1.cpt2")).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("shard 1") && err.contains("cannot open"), "{err}");
        assert!(Model::load_stage_range(&path, 0..1, false).is_ok());
        rm_sharded("sh_err_miss");

        // overlapping ranges in the manifest fire at open, header-only
        let path = tmp("sh_err_lap.cpt2");
        m.save_compressed_sharded(&path, None, 2).unwrap();
        mangle_header(&path, "\"hi\":1,\"id\":0", "\"hi\":2,\"id\":0");
        let err = MappedCheckpoint::open(&path).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
        rm_sharded("sh_err_lap");

        // coverage shortfall (gap against the stage count) fires the same way
        let path = tmp("sh_err_gap.cpt2");
        m.save_compressed_sharded(&path, None, 1).unwrap();
        mangle_header(&path, "\"hi\":2,\"id\":0", "\"hi\":1,\"id\":0");
        let err = MappedCheckpoint::open(&path).unwrap_err().to_string();
        assert!(err.contains("covers stages"), "{err}");
        std::fs::remove_file(tmp("sh_err_gap.shard0.cpt2")).ok();
        std::fs::remove_file(&path).ok();

        // stage ranges outside the checkpoint are rejected before any I/O
        let path = tmp("sh_err_range.cpt2");
        m.save_compressed_sharded(&path, None, 2).unwrap();
        let ck = MappedCheckpoint::open(&path).unwrap();
        let err = ck.load_stage_range(0..5).unwrap_err().to_string();
        assert!(err.contains("outside the checkpoint's 2 stages"), "{err}");
        let err = ck.load_stage_range(1..1).unwrap_err().to_string();
        assert!(err.contains("empty stage range"), "{err}");

        // a tampered shard header (still valid JSON) fails the manifest's
        // header CRC, while the untouched shard keeps serving its range
        mangle_header(&tmp("sh_err_range.shard1.cpt2"), "\"align\":64", "\"align\":65");
        let err = ck.load_stage_range(1..2).unwrap_err().to_string();
        assert!(err.contains("header crc mismatch"), "{err}");
        assert!(ck.load_stage_range(0..1).is_ok());
        rm_sharded("sh_err_range");
    }
}
