//! `CPT2` — the compressed-checkpoint format: every [`LinearWeight`]
//! variant serialized *natively*, so a compressed (and possibly packed-
//! quantized) model reloads in one pass with **zero recompression and zero
//! requantization**. The factorization is the deployable artifact
//! (CoSpaDi/ProcrustesGPT); this module makes it durable.
//!
//! Layout:
//! ```text
//! b"CPT2" | u32 header_len | header JSON (utf-8)
//!         | zero pad to ALIGN | section payloads (LE, each ALIGN-aligned)
//! ```
//!
//! The header carries `{"version", "config", "plan"?, "align", "sections",
//! "stages"}`. Each section record is `{"name", "dtype": "f32"|"u32"|"u16",
//! "len", "offset", "crc32"}` with `offset` in bytes from the start of the
//! (aligned) data region — so a loader can `read_exact`/`mmap` a section
//! straight into its resident buffer. Each stage entry tags its projections
//! with a variant (`dense`, `low_rank`, `factorized`, `quant_dense`,
//! `quant_low_rank`, `quant_factorized`), shapes, and bit widths; the
//! quantized variants reference raw u32 code-word and u16 f16-scale
//! sections that are byte-for-byte the in-memory [`QuantMat`] buffers.
//! Each packed tensor additionally carries a physical-layout tag
//! (`layout` / `layout_b` / `layout_c` / `layout_a` / `layout_val`:
//! `"row_seq"` or `"planar"`). The tag is **absent** in checkpoints written
//! before the code-planar storage rework, and an absent tag means the
//! legacy row-sequential stream — old checkpoints keep loading through the
//! legacy unpack path with zero conversion, while new saves record the
//! layout the buffers are actually in (`compot info` prints it).
//!
//! Every field read from disk is validated against the actual file size
//! before any allocation, every section payload is CRC32-checked (lazily,
//! per section, as each buffer is materialized), and every reconstruction
//! goes through the fallible `from_raw_parts` constructors — a corrupt or
//! adversarial checkpoint yields an error, never a panic or a huge
//! allocation.
//!
//! Two load paths share one stage-walking body: the copying loader
//! ([`Model::load_compressed`], owned buffers) and the zero-copy loader
//! ([`MappedCheckpoint`] / [`Model::load_compressed_mmap`]), which maps
//! the file once and hands every weight a [`WeightBuf`] view into the
//! 64-B-aligned section payloads — no decode, no copy, page cache shared
//! across serve workers.
//!
//! [`Model::load_checkpoint`] is the versioned entry point: it sniffs the
//! magic and accepts both the dense `CPT1` tensor format
//! ([`super::weights`]) and `CPT2`.

use super::config::ProjKind;
use super::transformer::{Block, Model, Stage};
use super::weights::TensorFile;
use crate::compress::sparse::{ColumnSparse, QuantColumnSparse};
use crate::compress::LinearWeight;
use crate::linalg::buf::{Advice, Mapping, Pod, WeightBuf};
use crate::linalg::qmat::{supported_group, GROUP};
use crate::linalg::{Mat, QuantLayout, QuantMat};
use crate::model::config::ModelConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Seek, Write};
use std::path::Path;
use std::sync::Arc;

pub const MAGIC: &[u8; 4] = b"CPT2";
pub const VERSION: usize = 2;
/// Section payload alignment (bytes) — sized for cache lines and for the
/// zero-copy loader: every section's absolute file offset is a multiple of
/// ALIGN, so a page-aligned mapping yields views aligned for f32/u32/u16.
pub const ALIGN: usize = 64;

/// What a checkpoint said about itself — surfaced by `serve`'s info
/// response so operators can tell a cold-loaded artifact from an in-process
/// compression run.
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    /// `"cpt1"` or `"cpt2"`.
    pub format: &'static str,
    /// Compression-plan provenance recorded at save time (CPT2 only).
    pub plan: Option<String>,
    /// Where the weight buffers live: `"owned"` (copied into heap
    /// allocations), `"mmap"` (zero-copy views into a shared file
    /// mapping), or `"mmap-fallback"` (an mmap load on a host/filesystem
    /// without mmap support — views into one private aligned heap read, so
    /// no page sharing across workers).
    pub source: &'static str,
}

/// Byte-at-a-time CRC32 lookup table, built at compile time. The table
/// version runs ~8× faster than the bitwise loop — checksumming must not
/// become the cold-load bottleneck this format exists to remove.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut b = 0;
        while b < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ 0xedb8_8320 } else { c >> 1 };
            b += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 (IEEE, reflected) of a byte slice — in-tree, no crc crate in this
/// offline env.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLE[((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

fn align_up(x: usize, a: usize) -> usize {
    x.div_ceil(a) * a
}

// ---------------------------------------------------------------------------
// Section writer.
// ---------------------------------------------------------------------------

struct PendingSection {
    name: String,
    dtype: &'static str,
    len: usize,
    bytes: Vec<u8>,
}

#[derive(Default)]
struct SectionWriter {
    sections: Vec<PendingSection>,
}

impl SectionWriter {
    fn add(&mut self, name: &str, dtype: &'static str, len: usize, bytes: Vec<u8>) {
        self.sections.push(PendingSection { name: name.to_string(), dtype, len, bytes });
    }

    fn add_f32(&mut self, name: &str, vals: &[f32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "f32", vals.len(), b);
    }

    fn add_u32(&mut self, name: &str, vals: &[u32]) {
        let mut b = Vec::with_capacity(4 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u32", vals.len(), b);
    }

    fn add_u16(&mut self, name: &str, vals: &[u16]) {
        let mut b = Vec::with_capacity(2 * vals.len());
        for v in vals {
            b.extend_from_slice(&v.to_le_bytes());
        }
        self.add(name, "u16", vals.len(), b);
    }

    /// Lay the sections out ALIGN-aligned; returns (section records, payload).
    fn finish(self) -> (Vec<Json>, Vec<u8>) {
        let mut records = Vec::with_capacity(self.sections.len());
        let mut payload: Vec<u8> = Vec::new();
        for s in self.sections {
            let offset = align_up(payload.len(), ALIGN);
            payload.resize(offset, 0);
            let mut rec = Json::obj();
            rec.set("name", s.name.as_str().into())
                .set("dtype", s.dtype.into())
                .set("len", s.len.into())
                .set("offset", offset.into())
                .set("crc32", (crc32(&s.bytes) as usize).into());
            records.push(rec);
            payload.extend_from_slice(&s.bytes);
        }
        (records, payload)
    }
}

// ---------------------------------------------------------------------------
// Section reader — one record table, two payload sources.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct SectionDesc {
    dtype_size: usize,
    len: usize,
    offset: usize,
    crc32: u32,
}

/// Where section bytes come from: the copying loader's in-memory data
/// region, or a shared file [`Mapping`] whose data region starts at `start`
/// (zero-copy — accessors hand out [`WeightBuf`] views into it).
enum Payload {
    Copied(Vec<u8>),
    Mapped { map: Arc<Mapping>, start: usize },
}

struct SectionReader {
    payload: Payload,
    by_name: BTreeMap<String, (SectionDesc, &'static str)>,
}

impl SectionReader {
    /// Parse and bounds-check the section table against the real data-region
    /// size. CRCs are **not** checked here — each section is checksummed
    /// lazily, the first (and only) time an accessor materializes it. That
    /// keeps header-only opens ([`MappedCheckpoint::open`], `compot info`)
    /// free of any payload I/O.
    fn new(header: &Json, payload: Payload) -> anyhow::Result<SectionReader> {
        let region_len = match &payload {
            Payload::Copied(data) => data.len(),
            Payload::Mapped { map, start } => map.len().saturating_sub(*start),
        };
        let mut by_name = BTreeMap::new();
        for rec in header
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'sections' array"))?
        {
            let name = rec
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("section record without a name"))?;
            let (dtype, size): (&'static str, usize) =
                match rec.get("dtype").and_then(Json::as_str) {
                    Some("f32") => ("f32", 4),
                    Some("u32") => ("u32", 4),
                    Some("u16") => ("u16", 2),
                    other => anyhow::bail!("section '{name}': unknown dtype {other:?}"),
                };
            let len = rec
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing len"))?;
            let offset = rec
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing offset"))?;
            let byte_len = len
                .checked_mul(size)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': length overflows"))?;
            let end = offset
                .checked_add(byte_len)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': offset overflows"))?;
            anyhow::ensure!(
                end <= region_len,
                "section '{name}' ({len}×{size} B at offset {offset}) runs past the data \
                 region ({region_len} B) — truncated or corrupt checkpoint"
            );
            let want_crc = rec
                .get("crc32")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}': missing crc32"))?;
            by_name.insert(
                name.to_string(),
                (SectionDesc { dtype_size: size, len, offset, crc32: want_crc as u32 }, dtype),
            );
        }
        Ok(SectionReader { payload, by_name })
    }

    fn desc(&self, name: &str, dtype: &str, expect_len: usize) -> anyhow::Result<SectionDesc> {
        let (desc, have_dtype) = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing section '{name}'"))?;
        anyhow::ensure!(
            *have_dtype == dtype,
            "section '{name}': dtype {have_dtype}, expected {dtype}"
        );
        anyhow::ensure!(
            desc.len == expect_len,
            "section '{name}': {} elements on disk, header metadata implies {expect_len}",
            desc.len
        );
        Ok(*desc)
    }

    fn region(&self) -> &[u8] {
        match &self.payload {
            Payload::Copied(data) => data,
            Payload::Mapped { map, start } => &map.bytes()[*start..],
        }
    }

    /// Materialize one section as a [`WeightBuf`]: CRC-check its bytes
    /// (lazy — this is the first time anything reads the payload), then
    /// either decode into an owned vector (copy source) or hand out an
    /// aligned zero-copy view (mapped source).
    fn buf<T: Pod>(&self, name: &str, expect_len: usize) -> anyhow::Result<WeightBuf<T>> {
        let d = self.desc(name, T::DTYPE, expect_len)?;
        // Build the view first so a misaligned offset reports as the
        // structural error it is, not as the checksum mismatch the shifted
        // bytes would also produce.
        let buf = match &self.payload {
            Payload::Copied(_) => None,
            Payload::Mapped { map, start } => Some(
                WeightBuf::view(map, start + d.offset, d.len)
                    .map_err(|e| anyhow::anyhow!("section '{name}': {e}"))?,
            ),
        };
        // The CRC pass streams the section's pages front-to-back exactly
        // once — tell the kernel so readahead runs ahead of the checksum
        // loop, then drop back to normal (decode-time access is random).
        if let Payload::Mapped { map, start } = &self.payload {
            map.advise(start + d.offset, d.len * d.dtype_size, Advice::Sequential);
        }
        let raw = &self.region()[d.offset..d.offset + d.len * d.dtype_size];
        let got = crc32(raw);
        if let Payload::Mapped { map, start } = &self.payload {
            map.advise(start + d.offset, d.len * d.dtype_size, Advice::Normal);
        }
        anyhow::ensure!(
            got == d.crc32,
            "section '{name}': crc mismatch (header {:#x}, payload {got:#x})",
            d.crc32
        );
        match buf {
            Some(view) => Ok(view),
            None => Ok(raw
                .chunks_exact(std::mem::size_of::<T>())
                .map(T::from_le_bytes)
                .collect::<Vec<T>>()
                .into()),
        }
    }

    /// Small vectors (norm gains) always materialize owned — they are a few
    /// hundred bytes and the forward pass stores them as `Vec<f32>`.
    fn vec_f32(&self, name: &str, expect_len: usize) -> anyhow::Result<Vec<f32>> {
        Ok(self.buf::<f32>(name, expect_len)?.into_vec())
    }

    fn mat(&self, name: &str, rows: usize, cols: usize) -> anyhow::Result<Mat> {
        let len = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("section '{name}': {rows}x{cols} overflows"))?;
        Mat::from_buf(rows, cols, self.buf::<f32>(name, len)?)
    }

    /// `bits`/`group`/`layout` are pre-validated by
    /// `meta_bits`/`meta_group`/`meta_layout` (projection-named errors);
    /// `QuantMat::from_raw_parts` re-checks them as the fallible constructor
    /// every path funnels through. The layout decides the expected code-word
    /// count — a header that tags a planar tensor but ships a legacy-sized
    /// section (or vice versa) fails the length check by name.
    fn qmat(
        &self,
        base: &str,
        rows: usize,
        cols: usize,
        bits: u32,
        group: usize,
        layout: QuantLayout,
    ) -> anyhow::Result<QuantMat> {
        let np = QuantMat::packed_len_layout(rows, cols, bits, group, layout).ok_or_else(|| {
            anyhow::anyhow!("'{base}': invalid packed geometry {rows}x{cols} @{bits}b g{group}")
        })?;
        let ns = QuantMat::scales_len_grouped(rows, cols, group)
            .ok_or_else(|| anyhow::anyhow!("'{base}': {rows}x{cols} overflows"))?;
        let packed = self.buf::<u32>(&format!("{base}.codes"), np)?;
        let scales = self.buf::<u16>(&format!("{base}.scales"), ns)?;
        QuantMat::from_raw_parts(rows, cols, bits, group, layout, packed, scales)
    }
}

// ---------------------------------------------------------------------------
// LinearWeight ⇄ sections.
// ---------------------------------------------------------------------------

fn write_qmat(sw: &mut SectionWriter, base: &str, q: &QuantMat) {
    sw.add_u32(&format!("{base}.codes"), q.packed_words());
    sw.add_u16(&format!("{base}.scales"), q.scale_bits());
}

/// Serialize one projection under `base`, returning its header metadata.
fn write_weight(sw: &mut SectionWriter, base: &str, w: &LinearWeight) -> Json {
    let mut meta = Json::obj();
    match w {
        LinearWeight::Dense(m) => {
            meta.set("variant", "dense".into())
                .set("rows", m.rows().into())
                .set("cols", m.cols().into());
            sw.add_f32(&format!("{base}.w"), m.data());
        }
        LinearWeight::LowRank { b, c } => {
            meta.set("variant", "low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into());
            sw.add_f32(&format!("{base}.b"), b.data());
            sw.add_f32(&format!("{base}.c"), c.data());
        }
        LinearWeight::Factorized { a, s } => {
            meta.set("variant", "factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into());
            sw.add_f32(&format!("{base}.a"), a.data());
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            sw.add_f32(&format!("{base}.s.val"), s.values());
        }
        LinearWeight::QuantDense(q) => {
            meta.set("variant", "quant_dense".into())
                .set("rows", q.rows().into())
                .set("cols", q.cols().into())
                .set("bits", (q.bits() as usize).into())
                .set("group", q.group().into())
                .set("layout", q.layout().as_str().into());
            write_qmat(sw, &format!("{base}.w"), q);
        }
        LinearWeight::QuantLowRank { b, c } => {
            meta.set("variant", "quant_low_rank".into())
                .set("m", b.rows().into())
                .set("r", b.cols().into())
                .set("n", c.cols().into())
                .set("bits_b", (b.bits() as usize).into())
                .set("bits_c", (c.bits() as usize).into())
                .set("group_b", b.group().into())
                .set("group_c", c.group().into())
                .set("layout_b", b.layout().as_str().into())
                .set("layout_c", c.layout().as_str().into());
            write_qmat(sw, &format!("{base}.b"), b);
            write_qmat(sw, &format!("{base}.c"), c);
        }
        LinearWeight::QuantFactorized { a, s } => {
            let v = s.values_qmat();
            meta.set("variant", "quant_factorized".into())
                .set("m", a.rows().into())
                .set("k", a.cols().into())
                .set("n", s.n().into())
                .set("s", s.s().into())
                .set("bits_a", (a.bits() as usize).into())
                .set("bits_val", (v.bits() as usize).into())
                .set("group_a", a.group().into())
                .set("group_val", v.group().into())
                .set("layout_a", a.layout().as_str().into())
                .set("layout_val", v.layout().as_str().into());
            write_qmat(sw, &format!("{base}.a"), a);
            sw.add_u32(&format!("{base}.s.idx"), s.indices());
            write_qmat(sw, &format!("{base}.s.val"), v);
        }
    }
    meta
}

fn meta_usize(meta: &Json, base: &str, key: &str) -> anyhow::Result<usize> {
    meta.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing field '{key}'"))
}

fn meta_bits(meta: &Json, base: &str, key: &str) -> anyhow::Result<u32> {
    let b = meta_usize(meta, base, key)?;
    anyhow::ensure!(
        (2..=8).contains(&b),
        "projection '{base}': {key}={b} outside the packable 2..=8 range"
    );
    Ok(b as u32)
}

/// Quantization group size for one packed tensor. Absent (pre-group-sweep
/// checkpoints) defaults to [`GROUP`]; present values are validated here so
/// the error names the projection.
fn meta_group(meta: &Json, base: &str, key: &str) -> anyhow::Result<usize> {
    let g = match meta.get(key) {
        None => return Ok(GROUP),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("projection '{base}': bad field '{key}'"))?,
    };
    anyhow::ensure!(
        supported_group(g),
        "projection '{base}': {key}={g} is not a supported quantization group size"
    );
    Ok(g)
}

/// Physical code layout for one packed tensor. Absent (checkpoints written
/// before the code-planar storage rework) means the legacy row-sequential
/// stream; present values are validated here so the error names the
/// projection.
fn meta_layout(meta: &Json, base: &str, key: &str) -> anyhow::Result<QuantLayout> {
    match meta.get(key) {
        None => Ok(QuantLayout::RowSeq),
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': bad field '{key}'"))?;
            QuantLayout::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "projection '{base}': {key}='{s}' is not a known quantized layout"
                )
            })
        }
    }
}

/// Reconstruct one projection from its header metadata + sections.
fn read_weight(sr: &SectionReader, base: &str, meta: &Json) -> anyhow::Result<LinearWeight> {
    let variant = meta
        .get("variant")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("projection '{base}': missing variant tag"))?;
    match variant {
        "dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            Ok(LinearWeight::Dense(sr.mat(&format!("{base}.w"), rows, cols)?))
        }
        "low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::LowRank {
                b: sr.mat(&format!("{base}.b"), m, r)?,
                c: sr.mat(&format!("{base}.c"), r, n)?,
            })
        }
        "factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.buf::<u32>(&format!("{base}.s.idx"), ns)?;
            let val = sr.buf::<f32>(&format!("{base}.s.val"), ns)?;
            Ok(LinearWeight::Factorized {
                a: sr.mat(&format!("{base}.a"), m, k)?,
                s: ColumnSparse::from_raw_parts(k, n, s, idx, val)?,
            })
        }
        "quant_dense" => {
            let rows = meta_usize(meta, base, "rows")?;
            let cols = meta_usize(meta, base, "cols")?;
            let bits = meta_bits(meta, base, "bits")?;
            let group = meta_group(meta, base, "group")?;
            let layout = meta_layout(meta, base, "layout")?;
            Ok(LinearWeight::QuantDense(sr.qmat(
                &format!("{base}.w"),
                rows,
                cols,
                bits,
                group,
                layout,
            )?))
        }
        "quant_low_rank" => {
            let m = meta_usize(meta, base, "m")?;
            let r = meta_usize(meta, base, "r")?;
            let n = meta_usize(meta, base, "n")?;
            Ok(LinearWeight::QuantLowRank {
                b: sr.qmat(
                    &format!("{base}.b"),
                    m,
                    r,
                    meta_bits(meta, base, "bits_b")?,
                    meta_group(meta, base, "group_b")?,
                    meta_layout(meta, base, "layout_b")?,
                )?,
                c: sr.qmat(
                    &format!("{base}.c"),
                    r,
                    n,
                    meta_bits(meta, base, "bits_c")?,
                    meta_group(meta, base, "group_c")?,
                    meta_layout(meta, base, "layout_c")?,
                )?,
            })
        }
        "quant_factorized" => {
            let m = meta_usize(meta, base, "m")?;
            let k = meta_usize(meta, base, "k")?;
            let n = meta_usize(meta, base, "n")?;
            let s = meta_usize(meta, base, "s")?;
            let ns = n
                .checked_mul(s)
                .ok_or_else(|| anyhow::anyhow!("projection '{base}': n·s overflows"))?;
            let idx = sr.buf::<u32>(&format!("{base}.s.idx"), ns)?;
            let val = sr.qmat(
                &format!("{base}.s.val"),
                n,
                s,
                meta_bits(meta, base, "bits_val")?,
                meta_group(meta, base, "group_val")?,
                meta_layout(meta, base, "layout_val")?,
            )?;
            Ok(LinearWeight::QuantFactorized {
                a: sr.qmat(
                    &format!("{base}.a"),
                    m,
                    k,
                    meta_bits(meta, base, "bits_a")?,
                    meta_group(meta, base, "group_a")?,
                    meta_layout(meta, base, "layout_a")?,
                )?,
                s: QuantColumnSparse::from_raw_parts(k, idx, val)?,
            })
        }
        other => anyhow::bail!("projection '{base}': unknown variant tag '{other}'"),
    }
}

/// Structural contract the forward pass will index into: a CRC-valid
/// checkpoint whose per-tensor shapes are internally consistent could still
/// describe a block the attention/MLP code would panic on. Head widths are
/// per-block (structured pruning shrinks them) but must agree with the
/// config's global head_dim; the MLP hidden width is free (channel pruning)
/// but gate/up/down must agree with each other.
fn validate_block_shapes(i: usize, b: &Block, d: usize, head_dim: usize) -> anyhow::Result<()> {
    let check = |name: &str, got: (usize, usize), want: (usize, usize)| -> anyhow::Result<()> {
        anyhow::ensure!(
            got == want,
            "stage {i}: {name} shape {}x{} does not match the structural contract {}x{}",
            got.0,
            got.1,
            want.0,
            want.1
        );
        Ok(())
    };
    // Head counts come from the header: checked arithmetic, like every
    // other untrusted multiplication in this module.
    let qw = b
        .n_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_heads·head_dim overflows"))?;
    let kvw = b
        .n_kv_heads
        .checked_mul(head_dim)
        .ok_or_else(|| anyhow::anyhow!("stage {i}: n_kv_heads·head_dim overflows"))?;
    check("q_proj", (b.q.in_dim(), b.q.out_dim()), (d, qw))?;
    check("k_proj", (b.k.in_dim(), b.k.out_dim()), (d, kvw))?;
    check("v_proj", (b.v.in_dim(), b.v.out_dim()), (d, kvw))?;
    check("o_proj", (b.o.in_dim(), b.o.out_dim()), (qw, d))?;
    let ff = b.gate.out_dim();
    check("gate_proj", (b.gate.in_dim(), ff), (d, ff))?;
    check("up_proj", (b.up.in_dim(), b.up.out_dim()), (d, ff))?;
    check("down_proj", (b.down.in_dim(), b.down.out_dim()), (ff, d))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Model save / load.
// ---------------------------------------------------------------------------

impl Model {
    /// Serialize this model — compressed or not — as a CPT2 checkpoint.
    /// Every projection is stored in its *native* representation (packed
    /// quantized buffers included), so reloading never re-runs compression
    /// or requantization. `plan` records the compression-plan provenance in
    /// the header.
    pub fn save_compressed(&self, path: &Path, plan: Option<&str>) -> anyhow::Result<()> {
        let mut sw = SectionWriter::default();
        sw.add_f32("embed", self.embed.data());
        sw.add_f32("lm_head", self.lm_head.data());
        sw.add_f32("final_norm", &self.final_norm);
        let mut stages = Vec::with_capacity(self.stages.len());
        for (i, stage) in self.stages.iter().enumerate() {
            let mut sj = Json::obj();
            match stage {
                Stage::Block(b) => {
                    sj.set("kind", "block".into())
                        .set("n_heads", b.n_heads.into())
                        .set("n_kv_heads", b.n_kv_heads.into());
                    sw.add_f32(&format!("stages.{i}.attn_norm"), &b.attn_norm);
                    sw.add_f32(&format!("stages.{i}.mlp_norm"), &b.mlp_norm);
                    let mut projs = Json::obj();
                    for p in ProjKind::DECODER_SET {
                        let base = format!("stages.{i}.{}", p.group());
                        projs.set(p.group(), write_weight(&mut sw, &base, b.proj(p)));
                    }
                    sj.set("projections", projs);
                }
                Stage::Linear(t) => {
                    sj.set("kind", "linear".into())
                        .set("rows", t.rows().into())
                        .set("cols", t.cols().into());
                    sw.add_f32(&format!("stages.{i}.linear"), t.data());
                }
            }
            stages.push(sj);
        }
        let (records, payload) = sw.finish();
        let mut header = Json::obj();
        header
            .set("version", VERSION.into())
            .set("config", self.cfg.to_json())
            .set("align", ALIGN.into())
            .set("sections", Json::Arr(records))
            .set("stages", Json::Arr(stages));
        if let Some(p) = plan {
            header.set("plan", p.into());
        }
        let header_bytes = header.to_string().into_bytes();
        let data_start = align_up(8 + header_bytes.len(), ALIGN);

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
        f.write_all(&header_bytes)?;
        f.write_all(&vec![0u8; data_start - 8 - header_bytes.len()])?;
        f.write_all(&payload)?;
        // Flush explicitly: the drop-time flush swallows errors, and a
        // silently truncated checkpoint (disk full) must not report Ok.
        f.flush()?;
        Ok(())
    }

    /// Load a CPT2 checkpoint through the **copying** path: every section
    /// is decoded into freshly allocated owned buffers. Returns the model
    /// plus what the checkpoint recorded about its origin. No compression
    /// stage runs; packed quantized buffers are read back verbatim.
    pub fn load_compressed(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let (header, data_start, file_len) = read_header(&mut f, path)?;
        let (cfg, plan) = validate_header(&header)?;
        // Seek past the alignment pad, then pull the data region. The region
        // is bounded by the real file size, so section bounds checked
        // against its length are checked against reality.
        f.seek(std::io::SeekFrom::Start(data_start))?;
        let mut data = Vec::with_capacity((file_len - data_start) as usize);
        f.read_to_end(&mut data)?;
        let sr = SectionReader::new(&header, Payload::Copied(data))?;
        let model = read_model(cfg, &header, &sr)?;
        Ok((model, CheckpointInfo { format: "cpt2", plan, source: "owned" }))
    }

    /// Load a CPT2 checkpoint through the **zero-copy** path: open and
    /// validate the header once, map the file, and point every weight
    /// buffer straight into the mapping (CRCs checked lazily per section).
    /// Equivalent to [`MappedCheckpoint::open`] + `load_model`.
    pub fn load_compressed_mmap(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        MappedCheckpoint::open(path)?.load_model()
    }

    /// Versioned checkpoint entry point: sniffs the magic and loads either
    /// the dense `CPT1` tensor format or a `CPT2` compressed checkpoint.
    pub fn load_checkpoint(path: &Path) -> anyhow::Result<(Model, CheckpointInfo)> {
        Self::load_checkpoint_with(path, false)
    }

    /// [`load_checkpoint`](Self::load_checkpoint) with an explicit storage
    /// mode: `mmap = true` loads CPT2 weights as zero-copy views into a
    /// shared file mapping (the serve `--mmap` flag). CPT1 files carry
    /// unaligned dense tensors and do not support mapping.
    pub fn load_checkpoint_with(
        path: &Path,
        mmap: bool,
    ) -> anyhow::Result<(Model, CheckpointInfo)> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        drop(f);
        if &magic == MAGIC {
            if mmap {
                Self::load_compressed_mmap(path)
            } else {
                Self::load_compressed(path)
            }
        } else if &magic == super::weights::MAGIC {
            anyhow::ensure!(
                !mmap,
                "{path:?} is a CPT1 checkpoint; --mmap needs the aligned CPT2 format \
                 (re-save with --save-compressed)"
            );
            let model = Self::from_tensor_file(&TensorFile::load(path)?)?;
            Ok((model, CheckpointInfo { format: "cpt1", plan: None, source: "owned" }))
        } else {
            anyhow::bail!(
                "{path:?}: unknown checkpoint magic {magic:?} (expected CPT1 or CPT2)"
            )
        }
    }

    /// Total bytes the model's weight buffers borrow from checkpoint
    /// mappings (0 for an owned model) — the complement of
    /// [`resident_weight_bytes`](Model::resident_weight_bytes).
    pub fn mapped_weight_bytes(&self) -> usize {
        let mut bytes = self.embed.mapped_bytes() + self.lm_head.mapped_bytes();
        for stage in &self.stages {
            match stage {
                Stage::Block(b) => {
                    for p in ProjKind::DECODER_SET {
                        bytes += b.proj(p).mapped_bytes();
                    }
                }
                Stage::Linear(t) => bytes += t.mapped_bytes(),
            }
        }
        bytes
    }

    /// Whether any weight buffer is a zero-copy view into a checkpoint
    /// mapping.
    pub fn weights_mapped(&self) -> bool {
        self.mapped_weight_bytes() > 0
    }
}

/// Read and bound the `CPT2` preamble: magic, header JSON, aligned
/// data-region start. Touches only the header bytes — the payload stays
/// unread (and, for mapped opens, unpaged).
fn read_header(f: &mut std::fs::File, path: &Path) -> anyhow::Result<(Json, u64, u64)> {
    let file_len = f.metadata()?.len();
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?} (not a CPT2 checkpoint)");
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as u64;
    // Validate the header length against the actual file size *before*
    // allocating — a corrupt length must not drive a huge allocation.
    anyhow::ensure!(
        8 + hlen <= file_len,
        "header length {hlen} exceeds file size {file_len} — truncated checkpoint"
    );
    let mut hbytes = vec![0u8; hlen as usize];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("bad checkpoint header json: {e}"))?;
    let data_start = align_up(8 + hlen as usize, ALIGN) as u64;
    anyhow::ensure!(data_start <= file_len, "truncated checkpoint (no data region)");
    Ok((header, data_start, file_len))
}

/// Version/config/geometry checks shared by both load paths.
fn validate_header(header: &Json) -> anyhow::Result<(ModelConfig, Option<String>)> {
    let version = header.get("version").and_then(Json::as_usize).unwrap_or(0);
    anyhow::ensure!(
        version == VERSION,
        "unsupported CPT2 version {version} (this build reads version {VERSION})"
    );
    let cfg = ModelConfig::from_json(
        header.get("config").ok_or_else(|| anyhow::anyhow!("checkpoint has no config"))?,
    )?;
    // head_dim() divides by n_heads — reject a config that would panic.
    anyhow::ensure!(
        cfg.n_heads >= 1 && cfg.d_model >= 1 && cfg.d_model % cfg.n_heads == 0,
        "checkpoint config has invalid head geometry (d_model {}, n_heads {})",
        cfg.d_model,
        cfg.n_heads
    );
    let plan = header.get("plan").and_then(Json::as_str).map(String::from);
    Ok((cfg, plan))
}

/// Construct the model from a validated header plus a section reader —
/// the one stage-walking body both the copying and the zero-copy loader
/// run, so the two paths cannot drift.
fn read_model(cfg: ModelConfig, header: &Json, sr: &SectionReader) -> anyhow::Result<Model> {
    let d = cfg.d_model;
    let embed = sr.mat("embed", cfg.vocab, d)?;
    let lm_head = sr.mat("lm_head", d, cfg.vocab)?;
    let final_norm = sr.vec_f32("final_norm", d)?;
    let mut stages = Vec::new();
    for (i, sj) in header
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint header has no 'stages' array"))?
        .iter()
        .enumerate()
    {
        match sj.get("kind").and_then(Json::as_str) {
            Some("block") => {
                let n_heads = sj
                    .get("n_heads")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_heads"))?;
                let n_kv_heads = sj
                    .get("n_kv_heads")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("stage {i}: missing n_kv_heads"))?;
                anyhow::ensure!(
                    n_kv_heads >= 1 && n_heads >= n_kv_heads && n_heads % n_kv_heads == 0,
                    "stage {i}: invalid head counts {n_heads}/{n_kv_heads}"
                );
                let projs = sj
                    .get("projections")
                    .ok_or_else(|| anyhow::anyhow!("stage {i}: missing projections"))?;
                let get = |p: ProjKind| -> anyhow::Result<LinearWeight> {
                    let base = format!("stages.{i}.{}", p.group());
                    let meta = projs.get(p.group()).ok_or_else(|| {
                        anyhow::anyhow!("stage {i}: missing projection '{}'", p.group())
                    })?;
                    read_weight(sr, &base, meta)
                };
                let block = Block {
                    attn_norm: sr.vec_f32(&format!("stages.{i}.attn_norm"), d)?,
                    q: get(ProjKind::Q)?,
                    k: get(ProjKind::K)?,
                    v: get(ProjKind::V)?,
                    o: get(ProjKind::O)?,
                    mlp_norm: sr.vec_f32(&format!("stages.{i}.mlp_norm"), d)?,
                    gate: get(ProjKind::Gate)?,
                    up: get(ProjKind::Up)?,
                    down: get(ProjKind::Down)?,
                    n_heads,
                    n_kv_heads,
                };
                validate_block_shapes(i, &block, d, cfg.head_dim())?;
                stages.push(Stage::Block(block));
            }
            Some("linear") => {
                let rows = sj
                    .get("rows")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("stage {i}: missing rows"))?;
                let cols = sj
                    .get("cols")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("stage {i}: missing cols"))?;
                anyhow::ensure!(
                    rows == d && cols == d,
                    "stage {i}: linear shape {rows}x{cols} does not preserve the \
                     d={d} residual stream"
                );
                stages.push(Stage::Linear(sr.mat(&format!("stages.{i}.linear"), rows, cols)?));
            }
            other => anyhow::bail!("stage {i}: unknown stage kind {other:?}"),
        }
    }
    Ok(Model { cfg, embed, stages, final_norm, lm_head })
}

// ---------------------------------------------------------------------------
// MappedCheckpoint: open/validate once, serve zero-copy models.
// ---------------------------------------------------------------------------

/// A CPT2 checkpoint opened for zero-copy serving: the file is mapped once,
/// the header is parsed and validated once, and
/// [`load_model`](MappedCheckpoint::load_model) builds a [`Model`] whose
/// weight buffers point straight into the mapping. Section CRCs are checked
/// lazily — a corrupt payload surfaces as an error from `load_model`, while
/// `open` itself touches only header bytes (this is also what makes the
/// `compot info <ckpt>` fast path free).
pub struct MappedCheckpoint {
    map: Arc<Mapping>,
    header: Json,
    data_start: usize,
    cfg: ModelConfig,
    plan: Option<String>,
}

impl MappedCheckpoint {
    /// Map the file and validate the header (magic, version, config
    /// geometry, data-region bounds). No section payload is read or
    /// CRC-checked here.
    pub fn open(path: &Path) -> anyhow::Result<MappedCheckpoint> {
        let mut f = std::fs::File::open(path)?;
        let (header, data_start, _) = read_header(&mut f, path)?;
        drop(f);
        let (cfg, plan) = validate_header(&header)?;
        let map = Mapping::open(path)?;
        // The mapping is taken after the header read; guard against the file
        // shrinking in between (the section table is bounds-checked against
        // the mapping again in SectionReader::new).
        anyhow::ensure!(
            data_start as usize <= map.len(),
            "checkpoint truncated while opening (data region past mapped {} B)",
            map.len()
        );
        Ok(MappedCheckpoint { map, header, data_start: data_start as usize, cfg, plan })
    }

    /// Model config recorded in the header.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Compression-plan provenance recorded at save time.
    pub fn plan(&self) -> Option<&str> {
        self.plan.as_deref()
    }

    /// The raw parsed header (config, stages, sections) — what the
    /// `compot info` fast path formats without loading any payload.
    pub fn header(&self) -> &Json {
        &self.header
    }

    /// Whether the backing store is a true `mmap` (page-cache shared)
    /// rather than the aligned heap-read fallback.
    pub fn is_mmap(&self) -> bool {
        self.map.is_mmap()
    }

    /// Construct the model with every weight buffer pointing into the
    /// mapping. Each section's CRC is verified (lazily, here) before its
    /// view is handed out; reconstruction goes through the same fallible
    /// constructors as the copying loader.
    pub fn load_model(&self) -> anyhow::Result<(Model, CheckpointInfo)> {
        let sr = SectionReader::new(
            &self.header,
            Payload::Mapped { map: self.map.clone(), start: self.data_start },
        )?;
        // Every request a serve worker handles starts in the embedding
        // table and ends in the LM head — prefault those sections now so
        // the first request doesn't eat their page-fault latency.
        for name in ["embed", "lm_head"] {
            if let Some((d, _)) = sr.by_name.get(name) {
                self.map.advise(
                    self.data_start + d.offset,
                    d.len * d.dtype_size,
                    Advice::WillNeed,
                );
            }
        }
        let model = read_model(self.cfg.clone(), &self.header, &sr)?;
        // Report the fallback honestly: an operator sizing N serve workers
        // must know whether the model is page-cache-shared or a private
        // heap copy per process.
        let source = if self.map.is_mmap() { "mmap" } else { "mmap-fallback" };
        Ok((model, CheckpointInfo { format: "cpt2", plan: self.plan.clone(), source }))
    }
}

/// One-line-per-stage summary of a CPT2 header — variant tags, shapes, and
/// bit widths straight from the JSON, no section payload touched. The
/// `compot info <checkpoint>` fast path prints this.
pub fn header_summary(header: &Json) -> String {
    let mut out = String::new();
    let cfg_name = header
        .get("config")
        .and_then(|c| c.get("name"))
        .and_then(Json::as_str)
        .unwrap_or("?");
    out.push_str(&format!(
        "config: {cfg_name} | version {} | plan {}\n",
        header.get("version").and_then(Json::as_usize).unwrap_or(0),
        header.get("plan").and_then(Json::as_str).unwrap_or("none recorded"),
    ));
    let Some(stages) = header.get("stages").and_then(Json::as_arr) else {
        out.push_str("(no stages array)\n");
        return out;
    };
    for (i, sj) in stages.iter().enumerate() {
        match sj.get("kind").and_then(Json::as_str) {
            Some("block") => {
                out.push_str(&format!(
                    "stage {i:>3} block ({}h/{}kv):",
                    sj.get("n_heads").and_then(Json::as_usize).unwrap_or(0),
                    sj.get("n_kv_heads").and_then(Json::as_usize).unwrap_or(0)
                ));
                if let Some(projs) = sj.get("projections") {
                    for p in ProjKind::DECODER_SET {
                        let Some(meta) = projs.get(p.group()) else { continue };
                        let variant = meta.get("variant").and_then(Json::as_str).unwrap_or("?");
                        let dim = |k: &str| meta.get(k).and_then(Json::as_usize);
                        let shape = match variant {
                            "dense" | "quant_dense" => format!(
                                "{}x{}",
                                dim("rows").unwrap_or(0),
                                dim("cols").unwrap_or(0)
                            ),
                            "low_rank" | "quant_low_rank" => format!(
                                "{}x{}x{}",
                                dim("m").unwrap_or(0),
                                dim("r").unwrap_or(0),
                                dim("n").unwrap_or(0)
                            ),
                            _ => format!(
                                "{}x{}x{} s{}",
                                dim("m").unwrap_or(0),
                                dim("k").unwrap_or(0),
                                dim("n").unwrap_or(0),
                                dim("s").unwrap_or(0)
                            ),
                        };
                        let mut bits = String::new();
                        for key in ["bits", "bits_b", "bits_c", "bits_a", "bits_val"] {
                            if let Some(b) = dim(key) {
                                if !bits.is_empty() {
                                    bits.push('/');
                                }
                                bits.push_str(&b.to_string());
                            }
                        }
                        let group = ["group", "group_b", "group_a"]
                            .iter()
                            .find_map(|k| dim(k))
                            .map(|g| format!(" g{g}"))
                            .unwrap_or_default();
                        let layout = ["layout", "layout_b", "layout_a"]
                            .iter()
                            .find_map(|k| meta.get(k).and_then(Json::as_str))
                            .map(|l| format!(" {l}"))
                            .unwrap_or_default();
                        if bits.is_empty() {
                            out.push_str(&format!(" {}={variant}[{shape}]", p.group()));
                        } else {
                            out.push_str(&format!(
                                " {}={variant}[{shape} @{bits}b{group}{layout}]",
                                p.group()
                            ));
                        }
                    }
                }
                out.push('\n');
            }
            Some("linear") => {
                out.push_str(&format!(
                    "stage {i:>3} linear {}x{}\n",
                    sj.get("rows").and_then(Json::as_usize).unwrap_or(0),
                    sj.get("cols").and_then(Json::as_usize).unwrap_or(0)
                ));
            }
            other => out.push_str(&format!("stage {i:>3} unknown kind {other:?}\n")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::StageConfig;
    use crate::coordinator::plan::CompressionPlan;
    use crate::data::SynthLang;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("compot_cpt2_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tiny() -> Model {
        Model::random(&ModelConfig::test_tiny(), &mut Rng::new(11))
    }

    fn compressed(spec: &str) -> Model {
        let model = tiny();
        let lang = SynthLang::wiki(model.cfg.vocab);
        let calib = lang.gen_batch(6, 48, &mut Rng::new(12));
        let plan = CompressionPlan::parse(spec, &StageConfig::new(0.25, false)).unwrap();
        plan.run(&model, &calib).unwrap().0
    }

    fn assert_identical(a: &Model, b: &Model) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.resident_weight_bytes(), b.resident_weight_bytes());
        assert_eq!(a.storage_bits(), b.storage_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        // bit-identical buffers, variant included
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind changed across the round trip"),
            }
        }
        let prompt = [1u16, 2, 3, 4];
        assert_eq!(a.greedy_decode(&prompt, 8), b.greedy_decode(&prompt, 8));
    }

    #[test]
    fn dense_model_roundtrip() {
        let m = tiny();
        let path = tmp("dense.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, info) = Model::load_compressed(&path).unwrap();
        assert_eq!(info.format, "cpt2");
        assert!(info.plan.is_none());
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn every_compressed_variant_roundtrips_bit_identically() {
        // One plan per LinearWeight variant the pipeline can emit:
        // LowRank, Factorized, QuantDense, QuantLowRank, QuantFactorized.
        for (spec, name) in [
            ("svd-llm@0.2", "lowrank"),
            ("compot@0.25", "factorized"),
            ("rtn4", "quant_dense"),
            ("svd-llm@0.2+rtn4", "quant_lowrank"),
            ("compot@0.25+gptq4", "quant_factorized"),
        ] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            let (back, info) = Model::load_checkpoint(&path).unwrap();
            assert_eq!(info.plan.as_deref(), Some(spec), "{spec}");
            assert_identical(&m, &back);
            std::fs::remove_file(&path).ok();
        }
    }

    /// Bit-identity without the resident-bytes check — a mapped model keeps
    /// its weights in the file mapping, so residency *should* differ.
    fn assert_same_weights(a: &Model, b: &Model) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.storage_bits(), b.storage_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            match (sa, sb) {
                (Stage::Block(ba), Stage::Block(bb)) => {
                    assert_eq!(ba.attn_norm, bb.attn_norm);
                    assert_eq!(ba.mlp_norm, bb.mlp_norm);
                    for p in ProjKind::DECODER_SET {
                        assert_eq!(ba.proj(p), bb.proj(p), "{p:?}");
                    }
                }
                (Stage::Linear(ta), Stage::Linear(tb)) => assert_eq!(ta, tb),
                _ => panic!("stage kind changed across the round trip"),
            }
        }
        let prompt = [1u16, 2, 3, 4];
        assert_eq!(a.greedy_decode(&prompt, 8), b.greedy_decode(&prompt, 8));
    }

    #[test]
    fn mmap_load_is_bit_identical_across_all_variants() {
        // The tentpole acceptance matrix: for every LinearWeight variant,
        // the zero-copy loader reproduces the copying loader bit for bit
        // (WeightBuf equality is content equality across owned/mapped) and
        // decodes token-identically, while keeping the big buffers in the
        // mapping instead of on the heap.
        for (spec, name) in [
            ("svd-llm@0.2", "m_lowrank"),
            ("compot@0.25", "m_factorized"),
            ("rtn4", "m_quant_dense"),
            ("svd-llm@0.2+rtn4", "m_quant_lowrank"),
            ("compot@0.25+gptq4", "m_quant_factorized"),
        ] {
            let m = compressed(spec);
            let path = tmp(&format!("{name}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            let (owned, oinfo) = Model::load_compressed(&path).unwrap();
            let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
            assert_eq!(oinfo.source, "owned", "{spec}");
            assert!(minfo.source.starts_with("mmap"), "{spec}: {}", minfo.source);
            assert_eq!(minfo.plan.as_deref(), Some(spec), "{spec}");
            assert_same_weights(&m, &owned);
            assert_same_weights(&owned, &mapped);
            // mapping-aware accounting. On a true mmap the mapped model's
            // projections live in shared file-backed pages, not the heap;
            // on the heap-read fallback ("mmap-fallback") they are private
            // memory and must be reported as resident. Either way the two
            // numbers add up to the owned footprint.
            assert!(!owned.weights_mapped(), "{spec}");
            if minfo.source == "mmap" {
                assert!(mapped.weights_mapped(), "{spec}");
                assert!(mapped.mapped_weight_bytes() > 0, "{spec}");
                assert!(
                    mapped.resident_weight_bytes() < owned.resident_weight_bytes(),
                    "{spec}: mapped model should keep weight bytes off the heap"
                );
            } else {
                assert_eq!(mapped.mapped_weight_bytes(), 0, "{spec}");
            }
            assert_eq!(
                mapped.resident_weight_bytes() + mapped.mapped_weight_bytes(),
                owned.resident_weight_bytes(),
                "{spec}: resident + mapped must add up to the owned footprint"
            );
            std::fs::remove_file(&path).ok();
        }
        // the dense (uncompressed) variant round-trips through the zero-copy
        // loader too
        let m = tiny();
        let path = tmp("m_dense.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (mapped, minfo) = Model::load_compressed_mmap(&path).unwrap();
        assert_same_weights(&m, &mapped);
        assert!(minfo.source.starts_with("mmap"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapped_open_defers_crc_to_load() {
        // Lazy per-section CRC: a corrupt payload does not stop the
        // header-only open (that is the `compot info` fast path), but the
        // first load that touches the section must fail its checksum.
        let m = compressed("rtn4");
        let path = tmp("lazycrc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let ck = MappedCheckpoint::open(&path).expect("open is header-only, must succeed");
        let err = ck.load_model().unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn misaligned_section_offset_is_a_structural_error() {
        // A header claiming a non-ALIGN-multiple offset would hand out a
        // misaligned f32 view — the mmap path must reject it as such (not
        // panic, not reinterpret).
        let m = tiny();
        let path = tmp("misaligned.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"name\":\"embed\",\"offset\":0", "\"name\":\"embed\",\"offset\":2");
        let err = Model::load_compressed_mmap(&path).unwrap_err().to_string();
        assert!(err.contains("misaligned"), "{err}");
        // the copying loader flags the same corruption as a checksum error
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_mapping_is_an_error() {
        let m = compressed("rtn4");
        let path = tmp("mtrunc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 97]).unwrap();
        let err = Model::load_compressed_mmap(&path).unwrap_err().to_string();
        assert!(
            err.contains("runs past the data region") || err.contains("crc mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cpt1_rejects_mmap_cleanly() {
        let m = tiny();
        let path = tmp("old_mmap.cpt1");
        m.save(&path).unwrap();
        let err = Model::load_checkpoint_with(&path, true).unwrap_err().to_string();
        assert!(err.contains("CPT1"), "{err}");
        // without --mmap the CPT1 path still loads
        assert!(Model::load_checkpoint_with(&path, false).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn group_size_roundtrips_through_the_header() {
        // Non-default quantization groups must survive save → load on both
        // paths: the header records each packed tensor's group, the loader
        // reconstructs the exact layout, decode stays token-identical.
        for (spec, want_group) in
            [("rtn4,group_size=64", 64usize), ("compot@0.25+gptq4,group_size=256", 256)]
        {
            let m = compressed(spec);
            let path = tmp(&format!("group{want_group}.cpt2"));
            m.save_compressed(&path, Some(spec)).unwrap();
            for mmap in [false, true] {
                let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
                assert_same_weights(&m, &back);
                let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
                match &b.q {
                    LinearWeight::QuantDense(q) => assert_eq!(q.group(), want_group, "{spec}"),
                    LinearWeight::QuantFactorized { a, s } => {
                        assert_eq!(a.group(), want_group, "{spec}");
                        assert_eq!(s.values_qmat().group(), want_group, "{spec}");
                    }
                    other => panic!("{spec}: unexpected variant {other:?}"),
                }
            }
            std::fs::remove_file(&path).ok();
        }
        // an unsupported group size in the header is an error, not a panic
        let m = compressed("rtn4");
        let path = tmp("badgroup.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"group\":128", "\"group\":100");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("group"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quant_layout_roundtrips_and_legacy_headers_default_to_row_seq() {
        // Default quantization now packs planar; the header records the tag
        // and both load paths rebuild the exact layout.
        let m = compressed("rtn4");
        let path = tmp("layout.cpt2");
        m.save_compressed(&path, Some("rtn4")).unwrap();
        for mmap in [false, true] {
            let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
            assert_same_weights(&m, &back);
            let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
            let LinearWeight::QuantDense(q) = &b.q else { panic!("not quant_dense") };
            assert_eq!(q.layout(), QuantLayout::Planar, "mmap={mmap}");
        }
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert!(header_summary(ck.header()).contains("planar"));
        drop(ck);
        // an unknown layout tag is an error, not a panic or a misread
        mangle_header(&path, "\"layout\":\"planar\"", "\"layout\":\"flanar\"");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("layout"), "{err}");
        std::fs::remove_file(&path).ok();

        // A header without any layout key (every pre-planar checkpoint) must
        // load as row-sequential. Simulate one by saving a row-seq model and
        // renaming its tag so the loader sees no "layout" field at all.
        let legacy = m.with_quant_layout(QuantLayout::RowSeq);
        let path = tmp("layout_legacy.cpt2");
        legacy.save_compressed(&path, Some("rtn4")).unwrap();
        mangle_header(&path, "\"layout\":\"row_seq\"", "\"laYout\":\"row_seq\"");
        for mmap in [false, true] {
            let (back, _) = Model::load_checkpoint_with(&path, mmap).unwrap();
            assert_same_weights(&legacy, &back);
            let Stage::Block(b) = &back.stages[0] else { panic!("no block") };
            let LinearWeight::QuantDense(q) = &b.q else { panic!("not quant_dense") };
            assert_eq!(q.layout(), QuantLayout::RowSeq, "mmap={mmap}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_summary_reads_no_payload() {
        let m = compressed("compot@0.25+gptq4");
        let path = tmp("summary.cpt2");
        m.save_compressed(&path, Some("compot@0.25+gptq4")).unwrap();
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert_eq!(ck.plan(), Some("compot@0.25+gptq4"));
        assert_eq!(ck.config().name, "test-tiny");
        let summary = header_summary(ck.header());
        assert!(summary.contains("quant_factorized"), "{summary}");
        assert!(summary.contains("test-tiny"), "{summary}");
        assert!(summary.contains("g128"), "{summary}");
        // the fast path works even when every payload byte is corrupt
        let mut bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let data_start = (8 + hlen).div_ceil(ALIGN) * ALIGN;
        for b in bytes[data_start..].iter_mut() {
            *b = 0xaa;
        }
        std::fs::write(&path, &bytes).unwrap();
        let ck = MappedCheckpoint::open(&path).unwrap();
        assert!(header_summary(ck.header()).contains("quant_factorized"));
        assert!(ck.load_model().is_err(), "corrupt payload must still fail the real load");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn linear_stage_roundtrips() {
        let mut m = tiny();
        let d = m.cfg.d_model;
        m.stages[1] = Stage::Linear(Mat::randn(&mut Rng::new(13), d, d, 0.2));
        let path = tmp("linear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let (back, _) = Model::load_compressed(&path).unwrap();
        assert_identical(&m, &back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cpt1_loads_through_the_versioned_entry_point() {
        let m = tiny();
        let path = tmp("old.cpt1");
        m.save(&path).unwrap();
        let (back, info) = Model::load_checkpoint(&path).unwrap();
        assert_eq!(info.format, "cpt1");
        let prompt = [3u16, 1, 4];
        assert_eq!(m.greedy_decode(&prompt, 6), back.greedy_decode(&prompt, 6));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_is_an_error() {
        let path = tmp("junk.cpt2");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00rest of the junk").unwrap();
        assert!(Model::load_compressed(&path).is_err());
        let err = Model::load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("unknown checkpoint magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_header_and_sections_are_errors() {
        let m = tiny();
        let path = tmp("trunc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let full = std::fs::read(&path).unwrap();

        // header length field claims more bytes than the file has
        let mut huge = full.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &huge).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // file cut inside the header
        std::fs::write(&path, &full[..64]).unwrap();
        assert!(Model::load_compressed(&path).is_err());

        // file cut inside the section payloads: bounds check, no panic
        std::fs::write(&path, &full[..full.len() - 97]).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("runs past the data region") || err.contains("crc mismatch"),
            "{err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let m = compressed("rtn4");
        let path = tmp("crc.cpt2");
        m.save_compressed(&path, None).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff; // flip a bit in the last section's payload
        std::fs::write(&path, &bytes).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    fn mangle_header(path: &Path, from: &str, to: &str) {
        // Same-length textual header edits keep offsets valid so the
        // specific validator under test is the one that fires.
        assert_eq!(from.len(), to.len());
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = String::from_utf8(bytes[8..8 + hlen].to_vec()).unwrap();
        assert!(header.contains(from), "header does not contain '{from}'");
        let patched = header.replacen(from, to, 1);
        let mut out = bytes[..8].to_vec();
        out.extend_from_slice(patched.as_bytes());
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(path, &out).unwrap();
    }

    #[test]
    fn unknown_variant_tag_is_an_error() {
        let m = compressed("rtn4");
        let path = tmp("variant.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"quant_dense\"", "\"quant_blorp\"");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unknown variant tag"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bits_outside_packable_range_are_errors() {
        let m = compressed("rtn4");
        let path = tmp("bits.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"bits\":4", "\"bits\":9");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("2..=8"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_section_length_mismatch_is_an_error() {
        let m = tiny();
        let path = tmp("mismatch.cpt2");
        m.save_compressed(&path, None).unwrap();
        // final_norm has d_model = 32 elements; claim 64 → the recorded CRC
        // no longer matches the (bounds-checked, never-trusted) enlarged
        // range, or the range runs past the data region.
        mangle_header(
            &path,
            "\"len\":32,\"name\":\"final_norm\"",
            "\"len\":64,\"name\":\"final_norm\"",
        );
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(
            err.contains("final_norm"),
            "mismatch must be caught on the named section: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn structurally_inconsistent_shapes_are_rejected() {
        // Per-tensor shapes can be internally consistent (sections + CRCs
        // valid) while describing a block the forward pass would panic on:
        // the loader must reject it, never defer the panic to serve time.
        let mut m = tiny();
        let d = m.cfg.d_model;
        if let Stage::Block(b) = &mut m.stages[0] {
            // 24 ≠ n_heads · head_dim for test-tiny
            b.q = LinearWeight::Dense(Mat::zeros(d, 24));
        }
        let path = tmp("badshape.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("structural contract"), "{err}");
        std::fs::remove_file(&path).ok();

        // A linear stage that changes the residual width is rejected too.
        let mut m = tiny();
        m.stages[1] = Stage::Linear(Mat::zeros(d, d + 1));
        let path = tmp("badlinear.cpt2");
        m.save_compressed(&path, None).unwrap();
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("residual stream"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn future_version_is_rejected() {
        let m = tiny();
        let path = tmp("version.cpt2");
        m.save_compressed(&path, None).unwrap();
        mangle_header(&path, "\"version\":2", "\"version\":7");
        let err = Model::load_compressed(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported CPT2 version"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }
}
