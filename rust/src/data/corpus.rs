//! The synthetic language and corpus splits.
//!
//! Structure (mirrored bit-for-bit in semantics, not in RNG, by
//! `python/compile/corpus.py`):
//!
//! - **Successor table** — token `t` has 4 preferred successors derived by
//!   fixed arithmetic (`(a·t + b) mod V`), sampled with probabilities
//!   (0.40, 0.25, 0.15, 0.10); with probability 0.10 the next token is a
//!   Zipf(1.3) draw ("noise"/topic shift).
//! - **Copy rule** — with probability [`COPY_PROB`] at positions ≥
//!   [`COPY_LAG`], the next token instead repeats the token COPY_LAG steps
//!   back (long-range structure; basis of the lambada-like task).
//! - Two corpus flavours ("wiki", "c4") differ by their Zipf-noise rate,
//!   giving two held-out perplexity sets that move together but not
//!   identically — like the paper's WikiText vs C4 columns.

use crate::util::rng::{zipf_harmonic, Rng};

pub const COPY_LAG: usize = 16;
pub const COPY_PROB: f64 = 0.10;
pub const SUCC_PROBS: [f64; 4] = [0.40, 0.25, 0.15, 0.10];

/// The synthetic language: deterministic structure given `vocab`.
#[derive(Clone, Debug)]
pub struct SynthLang {
    pub vocab: usize,
    /// Zipf-noise probability (0.10 for "wiki", 0.18 for "c4").
    pub noise: f64,
    zipf_h: f64,
}

impl SynthLang {
    pub fn wiki(vocab: usize) -> SynthLang {
        SynthLang { vocab, noise: 0.10, zipf_h: zipf_harmonic(vocab, 1.3) }
    }

    pub fn c4(vocab: usize) -> SynthLang {
        SynthLang { vocab, noise: 0.18, zipf_h: zipf_harmonic(vocab, 1.3) }
    }

    /// The 4 preferred successors of token `t` (fixed arithmetic — identical
    /// in the Python mirror).
    pub fn successors(&self, t: u16) -> [u16; 4] {
        let v = self.vocab as u64;
        let t = t as u64;
        [
            ((7 * t + 1) % v) as u16,
            ((13 * t + 5) % v) as u16,
            ((29 * t + 11) % v) as u16,
            ((5 * t + 3) % v) as u16,
        ]
    }

    /// A token that is *not* among t's successors (distractor source).
    pub fn non_successor(&self, t: u16, rng: &mut Rng) -> u16 {
        let succ = self.successors(t);
        loop {
            let cand = rng.zipf(self.vocab, 1.3, self.zipf_h) as u16;
            if !succ.contains(&cand) {
                return cand;
            }
        }
    }

    /// Sample the next token given history (the generative rule).
    pub fn next(&self, history: &[u16], rng: &mut Rng) -> u16 {
        if history.len() >= COPY_LAG && rng.chance(COPY_PROB) {
            return history[history.len() - COPY_LAG];
        }
        let last = *history.last().unwrap_or(&0);
        if rng.chance(self.noise) {
            return rng.zipf(self.vocab, 1.3, self.zipf_h) as u16;
        }
        let succ = self.successors(last);
        succ[rng.weighted(&SUCC_PROBS)]
    }

    /// Generate a sequence of `len` tokens (first token Zipf-sampled).
    pub fn gen(&self, len: usize, rng: &mut Rng) -> Vec<u16> {
        let mut seq = Vec::with_capacity(len);
        seq.push(rng.zipf(self.vocab, 1.3, self.zipf_h) as u16);
        while seq.len() < len {
            let nxt = self.next(&seq, rng);
            seq.push(nxt);
        }
        seq
    }

    /// Generate `count` sequences.
    pub fn gen_batch(&self, count: usize, len: usize, rng: &mut Rng) -> Vec<Vec<u16>> {
        (0..count).map(|_| self.gen(len, rng)).collect()
    }
}

/// Load a token corpus written by `python/compile/corpus.py`
/// (little-endian u16 stream, chunked into sequences of `seq_len`).
pub fn load_tokens(path: &std::path::Path, seq_len: usize) -> anyhow::Result<Vec<Vec<u16>>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 2 == 0, "odd token file length");
    let tokens: Vec<u16> = bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect();
    Ok(tokens.chunks_exact(seq_len).map(|c| c.to_vec()).collect())
}

/// Corpus sequences for a preset: prefer the build-time artifact (identical
/// distribution to what the model was trained on), fall back to the Rust
/// generator (unit tests, no-artifact environments).
pub fn corpus_split(
    artifacts_dir: &std::path::Path,
    split: &str,
    vocab: usize,
    count: usize,
    seq_len: usize,
    seed: u64,
) -> Vec<Vec<u16>> {
    let path = artifacts_dir.join(format!("corpus_{split}.bin"));
    if let Ok(seqs) = load_tokens(&path, seq_len) {
        if seqs.len() >= count {
            return seqs[..count].to_vec();
        }
    }
    let lang = if split == "c4" { SynthLang::c4(vocab) } else { SynthLang::wiki(vocab) };
    let mut rng = Rng::new(seed ^ split.len() as u64);
    lang.gen_batch(count, seq_len, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_requested_shape() {
        let lang = SynthLang::wiki(256);
        let mut rng = Rng::new(1);
        let seqs = lang.gen_batch(5, 64, &mut rng);
        assert_eq!(seqs.len(), 5);
        assert!(seqs.iter().all(|s| s.len() == 64));
        assert!(seqs.iter().flatten().all(|&t| (t as usize) < 256));
    }

    #[test]
    fn language_is_predictable() {
        // The top successor must appear far above chance.
        let lang = SynthLang::wiki(256);
        let mut rng = Rng::new(2);
        let seq = lang.gen(20_000, &mut rng);
        let mut hits = 0usize;
        for w in seq.windows(2) {
            if lang.successors(w[0])[0] == w[1] {
                hits += 1;
            }
        }
        let rate = hits as f64 / (seq.len() - 1) as f64;
        assert!(rate > 0.25, "top-successor rate {rate} too low"); // chance ≈ 1/256
    }

    #[test]
    fn copy_rule_leaves_trace() {
        let lang = SynthLang::wiki(256);
        let mut rng = Rng::new(3);
        let seq = lang.gen(20_000, &mut rng);
        let mut lag_hits = 0usize;
        for t in COPY_LAG..seq.len() {
            if seq[t] == seq[t - COPY_LAG] {
                lag_hits += 1;
            }
        }
        let rate = lag_hits as f64 / (seq.len() - COPY_LAG) as f64;
        assert!(rate > COPY_PROB * 0.8, "lag-copy rate {rate}");
    }

    #[test]
    fn wiki_and_c4_differ() {
        let w = SynthLang::wiki(256);
        let c = SynthLang::c4(256);
        assert!(c.noise > w.noise);
        // same deterministic successor structure
        assert_eq!(w.successors(17), c.successors(17));
    }

    #[test]
    fn non_successor_is_never_a_successor() {
        let lang = SynthLang::wiki(64);
        let mut rng = Rng::new(4);
        for t in 0..64u16 {
            let d = lang.non_successor(t, &mut rng);
            assert!(!lang.successors(t).contains(&d));
        }
    }

    #[test]
    fn token_file_roundtrip() {
        let dir = std::env::temp_dir().join("compot_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toks.bin");
        let tokens: Vec<u16> = (0..128u16).collect();
        let bytes: Vec<u8> = tokens.iter().flat_map(|t| t.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let seqs = load_tokens(&path, 32).unwrap();
        assert_eq!(seqs.len(), 4);
        assert_eq!(seqs[1][0], 32);
        std::fs::remove_file(&path).ok();
    }
}
