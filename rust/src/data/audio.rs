//! Synthetic "audio": continuous frames emitted from a codebook over the
//! transcript tokens (2× frame rate, Gaussian channel noise) — the
//! Whisper-substitute generator (DESIGN.md §3). The codebook matrix lives in
//! the model's weight file so the build-time (JAX) training and the Rust
//! evaluation share the exact emission distribution.

use super::corpus::SynthLang;
use crate::linalg::Mat;
use crate::util::Rng;

pub const FRAMES_PER_TOKEN: usize = 2;
pub const NOISE_STD: f32 = 0.3;

/// One utterance: transcript plus emitted frames.
#[derive(Clone, Debug)]
pub struct Utterance {
    pub transcript: Vec<u16>,
    pub frames: Mat,
}

/// Emit frames for a transcript: frame 2t = codebook[y_t] + ε,
/// frame 2t+1 = midpoint(y_t, y_{t+1}) + ε.
pub fn emit_frames(codebook: &Mat, transcript: &[u16], rng: &mut Rng) -> Mat {
    let d = codebook.cols();
    let t_len = transcript.len();
    let mut frames = Mat::zeros(t_len * FRAMES_PER_TOKEN, d);
    for (t, &tok) in transcript.iter().enumerate() {
        let cur = codebook.row(tok as usize);
        let nxt = codebook.row(transcript[(t + 1).min(t_len - 1)] as usize);
        for j in 0..d {
            frames[(2 * t, j)] = cur[j] + NOISE_STD * rng.gauss32();
            frames[(2 * t + 1, j)] = 0.5 * (cur[j] + nxt[j]) + NOISE_STD * rng.gauss32();
        }
    }
    frames
}

/// Sample a test utterance (transcript from the synthetic language).
pub fn sample_utterance(
    lang: &SynthLang,
    codebook: &Mat,
    len: usize,
    rng: &mut Rng,
) -> Utterance {
    let transcript = lang.gen(len, rng);
    let frames = emit_frames(codebook, &transcript, rng);
    Utterance { transcript, frames }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_shape_and_snr() {
        let mut rng = Rng::new(1);
        let codebook = Mat::randn(&mut rng, 64, 16, 1.0);
        let lang = SynthLang::wiki(64);
        let utt = sample_utterance(&lang, &codebook, 10, &mut rng);
        assert_eq!(utt.frames.shape(), (20, 16));
        // Even frames should be closer to their token's codeword than to a
        // random other codeword (decodable signal).
        let mut correct = 0;
        for (t, &tok) in utt.transcript.iter().enumerate() {
            let frame = utt.frames.row(2 * t);
            let d_true: f32 = frame
                .iter()
                .zip(codebook.row(tok as usize))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            let other = (tok as usize + 7) % 64;
            let d_other: f32 = frame
                .iter()
                .zip(codebook.row(other))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if d_true < d_other {
                correct += 1;
            }
        }
        assert!(correct >= 9, "signal too noisy: {correct}/10");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let cb = Mat::randn(&mut Rng::new(3), 32, 8, 1.0);
        let lang = SynthLang::wiki(32);
        let u1 = sample_utterance(&lang, &cb, 5, &mut r1);
        let u2 = sample_utterance(&lang, &cb, 5, &mut r2);
        assert_eq!(u1.transcript, u2.transcript);
        assert!(u1.frames.rel_err(&u2.frames) < 1e-9);
    }
}
