//! Synthetic vision-language data: an "image" is a row of patches, each a
//! noisy codebook embedding of a concept token; the caption names the
//! concepts in order, continued by the synthetic language. Four evaluation
//! "benchmarks" mirror the paper's VLM table (MMMU / OCRBench / RealWorldQA
//! / MMStar analogues) at different difficulty knobs.

use super::corpus::SynthLang;
use super::tasks::McqItem;
use crate::linalg::Mat;
use crate::util::Rng;

pub const N_PATCHES: usize = 4;
pub const PATCH_NOISE: f32 = 0.25;

/// One VQA-style item: image patches + caption-completion MCQ.
#[derive(Clone, Debug)]
pub struct VlmItem {
    pub patches: Mat,
    pub mcq: McqItem,
}

/// Emit an image for a concept sequence.
pub fn emit_patches(codebook: &Mat, concepts: &[u16], rng: &mut Rng) -> Mat {
    let d = codebook.cols();
    let mut patches = Mat::zeros(concepts.len(), d);
    for (i, &c) in concepts.iter().enumerate() {
        for j in 0..d {
            patches[(i, j)] = codebook.row(c as usize)[j] + PATCH_NOISE * rng.gauss32();
        }
    }
    patches
}

/// The caption for an image: its concepts in order (the training target of
/// the build-time VLM pretraining).
pub fn caption_for(concepts: &[u16], lang: &SynthLang, filler: usize, rng: &mut Rng) -> Vec<u16> {
    let mut cap = concepts.to_vec();
    if filler > 0 {
        let mut cont = lang.gen(filler, rng);
        cap.append(&mut cont);
    }
    cap
}

/// VLM benchmark item generator. Benchmarks vary which concept must be
/// recalled and how confusable the distractors are:
/// - "mmmu":        recall concept 2 given concepts 0,1 as caption prefix
/// - "ocrbench":    recall concept 0 (first "glyph") with random distractors
/// - "realworldqa": recall the *last* concept, distractors = other concepts
///                  from the same image (hard)
/// - "mmstar":      full-caption ranking (4 orderings)
pub fn generate_vlm(
    bench: &str,
    codebook: &Mat,
    _lang: &SynthLang,
    count: usize,
    seed: u64,
) -> Vec<VlmItem> {
    let vocab = codebook.rows();
    let mut rng = Rng::new(seed ^ bench.len() as u64);
    (0..count)
        .map(|_| {
            let concepts: Vec<u16> = {
                let mut c = Vec::new();
                while c.len() < N_PATCHES {
                    let cand = rng.below(vocab) as u16;
                    if !c.contains(&cand) {
                        c.push(cand);
                    }
                }
                c
            };
            let patches = emit_patches(codebook, &concepts, &mut rng);
            let mcq = match bench {
                "mmmu" => {
                    let ctx = concepts[..2].to_vec();
                    let good = vec![concepts[2]];
                    let distractors: Vec<Vec<u16>> = (0..3)
                        .map(|_| loop {
                            let d = rng.below(vocab) as u16;
                            if !concepts.contains(&d) {
                                break vec![d];
                            }
                        })
                        .collect();
                    shuffle_into_ctx(ctx, good, distractors, &mut rng)
                }
                "ocrbench" => {
                    let ctx: Vec<u16> = Vec::new();
                    let good = vec![concepts[0]];
                    let distractors: Vec<Vec<u16>> = (0..3)
                        .map(|_| loop {
                            let d = rng.below(vocab) as u16;
                            if d != concepts[0] {
                                break vec![d];
                            }
                        })
                        .collect();
                    let (choices, answer) = shuffled(good, distractors, &mut rng);
                    McqItem { context: ctx, choices, answer }
                }
                "realworldqa" => {
                    let ctx = concepts[..3].to_vec();
                    let good = vec![concepts[3]];
                    // distractors = concepts of the SAME image (confusable)
                    let distractors: Vec<Vec<u16>> =
                        concepts[..3].iter().map(|&c| vec![c]).collect();
                    shuffle_into_ctx(ctx, good, distractors, &mut rng)
                }
                "mmstar" => {
                    let ctx: Vec<u16> = Vec::new();
                    let good = concepts.clone();
                    let mut d1 = concepts.clone();
                    d1.reverse();
                    let mut d2 = concepts.clone();
                    d2.swap(0, 1);
                    let mut d3 = concepts.clone();
                    d3.swap(2, 3);
                    let (choices, answer) = shuffled(good, vec![d1, d2, d3], &mut rng);
                    McqItem { context: ctx, choices, answer }
                }
                other => panic!("unknown vlm benchmark '{other}'"),
            };
            VlmItem { patches, mcq }
        })
        .collect()
}

fn shuffled(correct: Vec<u16>, mut distractors: Vec<Vec<u16>>, rng: &mut Rng) -> (Vec<Vec<u16>>, usize) {
    let pos = rng.below(distractors.len() + 1);
    distractors.insert(pos, correct);
    (distractors, pos)
}

fn shuffle_into_ctx(
    ctx: Vec<u16>,
    good: Vec<u16>,
    distractors: Vec<Vec<u16>>,
    rng: &mut Rng,
) -> McqItem {
    let (choices, answer) = shuffled(good, distractors, rng);
    McqItem { context: ctx, choices, answer }
}

pub const VLM_BENCHMARKS: [&str; 4] = ["mmmu", "ocrbench", "realworldqa", "mmstar"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_generate() {
        let mut rng = Rng::new(1);
        let cb = Mat::randn(&mut rng, 64, 8, 1.0);
        let lang = SynthLang::wiki(64);
        for b in VLM_BENCHMARKS {
            let items = generate_vlm(b, &cb, &lang, 8, 3);
            assert_eq!(items.len(), 8);
            for it in &items {
                assert_eq!(it.patches.shape(), (N_PATCHES, 8));
                assert!(it.mcq.answer < it.mcq.choices.len());
                let l0 = it.mcq.choices[0].len();
                assert!(it.mcq.choices.iter().all(|c| c.len() == l0), "{b}");
            }
        }
    }

    #[test]
    fn concepts_are_distinct() {
        let mut rng = Rng::new(2);
        let cb = Mat::randn(&mut rng, 32, 8, 1.0);
        let lang = SynthLang::wiki(32);
        for it in generate_vlm("mmstar", &cb, &lang, 10, 5) {
            let correct = &it.mcq.choices[it.mcq.answer];
            let mut sorted = correct.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), N_PATCHES);
        }
    }
}
