//! Synthetic zero-shot benchmark suite — analogues of the paper's task set
//! (PIQA, HellaSwag, LAMBADA, ARC-e/c, SciQ, RACE, MMLU), built from the
//! synthetic language's known structure so the *correct* answer is
//! well-defined and an uncompressed model scores far above chance.
//! Scoring uses length-normalized log-likelihood choice ranking, the
//! lm-evaluation-harness protocol the paper uses.

use super::corpus::{SynthLang, COPY_LAG};
use crate::util::Rng;

/// A multiple-choice item: score `choices[i]` as continuations of `context`,
/// pick the argmax; `answer` is the correct index.
#[derive(Clone, Debug)]
pub struct McqItem {
    pub context: Vec<u16>,
    pub choices: Vec<Vec<u16>>,
    pub answer: usize,
}

/// A generated task = named set of items.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: &'static str,
    pub items: Vec<McqItem>,
}

/// The task names of the paper's main table, in column order.
pub const TASK_NAMES: [&str; 8] = [
    "piqa", "hellaswag", "lambada", "arc_e", "arc_c", "sciq", "race", "mmlu",
];

/// Extra "harder benchmark" suite (Open LLM Leaderboard analogue, Table 12).
pub const HARD_TASK_NAMES: [&str; 4] = ["bbh", "gpqa", "ifeval", "musr"];

/// Greedy most-likely continuation of length `len` under the language.
fn likely_path(lang: &SynthLang, start: u16, len: usize) -> Vec<u16> {
    let mut out = Vec::with_capacity(len);
    let mut cur = start;
    for _ in 0..len {
        cur = lang.successors(cur)[0];
        out.push(cur);
    }
    out
}

/// A low-probability continuation (non-successors at each step).
fn unlikely_path(lang: &SynthLang, start: u16, len: usize, rng: &mut Rng) -> Vec<u16> {
    let mut out = Vec::with_capacity(len);
    let mut cur = start;
    for _ in 0..len {
        cur = lang.non_successor(cur, rng);
        out.push(cur);
    }
    out
}

fn shuffled_answer<T>(correct: T, mut distractors: Vec<T>, rng: &mut Rng) -> (Vec<T>, usize) {
    let pos = rng.below(distractors.len() + 1);
    distractors.insert(pos, correct);
    (distractors, pos)
}

pub fn generate(lang: &SynthLang, name: &str, count: usize, seed: u64) -> Task {
    let mut rng = Rng::new(seed ^ name.len() as u64 ^ 0x7A5);
    let items = (0..count)
        .map(|_| match name {
            // Binary physical-commonsense analogue: plausible vs implausible
            // 3-token continuation.
            "piqa" => {
                let ctx = lang.gen(24, &mut rng);
                let last = *ctx.last().unwrap();
                let good = likely_path(lang, last, 3);
                let bad = unlikely_path(lang, last, 3, &mut rng);
                let (choices, answer) = shuffled_answer(good, vec![bad], &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // 4-way long-continuation ranking.
            "hellaswag" => {
                let ctx = lang.gen(32, &mut rng);
                let last = *ctx.last().unwrap();
                let good = likely_path(lang, last, 6);
                let d1 = unlikely_path(lang, last, 6, &mut rng);
                let mut d2 = good.clone();
                rng.shuffle(&mut d2); // right tokens, wrong order
                let d3 = unlikely_path(lang, lang.non_successor(last, &mut rng), 6, &mut rng);
                let (choices, answer) = shuffled_answer(good, vec![d1, d2, d3], &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Final-word prediction requiring long-range context: the copy
            // rule guarantees the answer appeared COPY_LAG tokens earlier.
            "lambada" => {
                let mut ctx = lang.gen(47, &mut rng);
                let target = ctx[ctx.len() - COPY_LAG];
                let mut distractors = Vec::new();
                while distractors.len() < 3 {
                    let d = lang.non_successor(*ctx.last().unwrap(), &mut rng);
                    if d != target && !distractors.contains(&vec![d]) {
                        distractors.push(vec![d]);
                    }
                }
                let (choices, answer) = shuffled_answer(vec![target], distractors, &mut rng);
                ctx.truncate(47);
                McqItem { context: ctx, choices, answer }
            }
            // Single-token completion, distractors implausible (easy).
            "arc_e" => {
                let ctx = lang.gen(16, &mut rng);
                let last = *ctx.last().unwrap();
                let good = vec![lang.successors(last)[0]];
                let distractors: Vec<Vec<u16>> =
                    (0..3).map(|_| vec![lang.non_successor(last, &mut rng)]).collect();
                let (choices, answer) = shuffled_answer(good, distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Single-token completion, distractors are the *other ranked
            // successors* (hard — small probability gaps).
            "arc_c" => {
                let ctx = lang.gen(16, &mut rng);
                let last = *ctx.last().unwrap();
                let succ = lang.successors(last);
                let good = vec![succ[0]];
                let distractors: Vec<Vec<u16>> =
                    succ[1..].iter().map(|&s| vec![s]).collect();
                let (choices, answer) = shuffled_answer(good, distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // A "fact" (rare bigram) is planted early; the question replays
            // its first token — answer is the second.
            "sciq" => {
                let mut ctx = lang.gen(12, &mut rng);
                let subject = lang.non_successor(*ctx.last().unwrap(), &mut rng);
                let fact = lang.non_successor(subject, &mut rng);
                ctx.push(subject);
                ctx.push(fact);
                ctx.extend(lang.gen(10, &mut rng));
                ctx.push(subject); // replay the subject
                let good = vec![fact];
                let distractors: Vec<Vec<u16>> =
                    (0..3).map(|_| vec![lang.non_successor(subject, &mut rng)]).collect();
                let (choices, answer) = shuffled_answer(good, distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Long-context reading: lambada-style with doubled context.
            "race" => {
                let ctx = lang.gen(64, &mut rng);
                let last = *ctx.last().unwrap();
                let good = likely_path(lang, last, 4);
                let d1 = unlikely_path(lang, last, 4, &mut rng);
                let d2 = unlikely_path(lang, last, 4, &mut rng);
                let d3 = unlikely_path(lang, last, 4, &mut rng);
                let (choices, answer) = shuffled_answer(good, vec![d1, d2, d3], &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Mixed-difficulty single-token: half easy, half challenge.
            "mmlu" => {
                let ctx = lang.gen(20, &mut rng);
                let last = *ctx.last().unwrap();
                let succ = lang.successors(last);
                let good = vec![succ[0]];
                let distractors: Vec<Vec<u16>> = if rng.chance(0.5) {
                    succ[1..].iter().map(|&s| vec![s]).collect()
                } else {
                    (0..3).map(|_| vec![lang.non_successor(last, &mut rng)]).collect()
                };
                let (choices, answer) = shuffled_answer(good, distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // ---- "harder" suite: longer dependency chains ----
            // Two chained copies (multi-step reasoning analogue).
            "bbh" | "musr" => {
                let mut ctx = lang.gen(COPY_LAG + 8, &mut rng);
                let target = ctx[ctx.len() - COPY_LAG];
                ctx.push(target);
                // now require the token after the *original* occurrence
                let pos = ctx.len() - 1 - COPY_LAG;
                let follow = ctx[pos + 1];
                let distractors: Vec<Vec<u16>> =
                    (0..3).map(|_| vec![lang.non_successor(target, &mut rng)]).collect();
                let (choices, answer) = shuffled_answer(vec![follow], distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Rank 5-token continuations with subtle corruption (graduate-
            // level "google-proof" analogue: one token swapped mid-path).
            "gpqa" => {
                let ctx = lang.gen(24, &mut rng);
                let last = *ctx.last().unwrap();
                let good = likely_path(lang, last, 5);
                let mut d1 = good.clone();
                d1[2] = lang.non_successor(d1[1], &mut rng);
                let mut d2 = good.clone();
                d2[3] = lang.non_successor(d2[2], &mut rng);
                let mut d3 = good.clone();
                d3[1] = lang.non_successor(d3[0], &mut rng);
                let (choices, answer) = shuffled_answer(good, vec![d1, d2, d3], &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            // Instruction-following analogue: the "instruction" is the copy
            // key itself — answer must repeat the first context token.
            "ifeval" => {
                let first = rng.below(lang.vocab) as u16;
                let mut ctx = vec![first];
                ctx.extend(lang.gen(COPY_LAG - 1, &mut rng));
                // next token via copy rule would be `first`
                let distractors: Vec<Vec<u16>> =
                    (0..3).map(|_| vec![lang.non_successor(*ctx.last().unwrap(), &mut rng)]).collect();
                let (choices, answer) = shuffled_answer(vec![first], distractors, &mut rng);
                McqItem { context: ctx, choices, answer }
            }
            other => panic!("unknown task '{other}'"),
        })
        .collect();
    Task { name: Box::leak(name.to_string().into_boxed_str()), items }
}

/// The full standard suite.
pub fn standard_suite(lang: &SynthLang, count: usize, seed: u64) -> Vec<Task> {
    TASK_NAMES.iter().map(|n| generate(lang, n, count, seed)).collect()
}

/// The harder suite (Table 12).
pub fn hard_suite(lang: &SynthLang, count: usize, seed: u64) -> Vec<Task> {
    HARD_TASK_NAMES.iter().map(|n| generate(lang, n, count, seed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_items() {
        let lang = SynthLang::wiki(256);
        for name in TASK_NAMES.iter().chain(HARD_TASK_NAMES.iter()) {
            let task = generate(&lang, name, 10, 42);
            assert_eq!(task.items.len(), 10, "{name}");
            for item in &task.items {
                assert!(!item.context.is_empty());
                assert!(item.choices.len() >= 2);
                assert!(item.answer < item.choices.len());
                assert!(!item.choices[item.answer].is_empty());
                // all choices same length (length-normalization fairness)
                let l0 = item.choices[0].len();
                assert!(item.choices.iter().all(|c| c.len() == l0), "{name}");
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let lang = SynthLang::wiki(256);
        let a = generate(&lang, "arc_e", 5, 7);
        let b = generate(&lang, "arc_e", 5, 7);
        for (x, y) in a.items.iter().zip(b.items.iter()) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn lambada_answer_is_in_context() {
        let lang = SynthLang::wiki(256);
        let task = generate(&lang, "lambada", 20, 9);
        for item in &task.items {
            let target = item.choices[item.answer][0];
            assert!(item.context.contains(&target), "copy target must appear in context");
        }
    }

    #[test]
    fn answers_are_shuffled() {
        let lang = SynthLang::wiki(256);
        let task = generate(&lang, "arc_e", 40, 11);
        let firsts = task.items.iter().filter(|i| i.answer == 0).count();
        assert!(firsts > 0 && firsts < 40, "answer positions must vary");
    }
}
