//! Synthetic data substrate.
//!
//! The paper calibrates on RefinedWeb/WikiText and evaluates on public
//! benchmarks; neither is available here (repro band 0), so we build a
//! synthetic language with the properties those datasets exercise:
//! Zipf-skewed unigrams, deterministic-arithmetic Markov structure (so the
//! *identical* distribution is reproduced by `python/compile/corpus.py` for
//! build-time pretraining without sharing PRNG state), and fixed-lag copy
//! patterns that give long-range "LAMBADA-like" structure. See DESIGN.md §3.

pub mod audio;
pub mod corpus;
pub mod tasks;
pub mod vlm;

pub use corpus::SynthLang;
